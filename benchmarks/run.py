"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:

* table2  — CPU execution time across (dtype, backend) configs (paper Table 2)
* table3  — best time per processor (paper Table 3)
* table4  — non-linearity: Σ(per-layer) / whole-graph ratios (paper Table 4)
* fig5    — comm microbenchmark + piecewise-linear fit (paper Fig. 5)
* fig12   — single-model-group saturation multipliers: Puzzle vs Best
            Mapping vs NPU Only (paper Fig. 12)
* fig15   — multi-model-group saturation multipliers (paper Fig. 15)
* table5  — runtime ablation: tensor pool / shared buffer (paper Table 5 / Fig. 10)
* simspeed — fast-path evaluation engine: reference DES vs array-based
             fastsim µs/eval, decode-cache effect, grid vs bisection α*,
             and an end-to-end GA + saturation speedup on a deterministic
             3-group scenario (with a makespan-parity check). ``--json``
             additionally writes BENCH_simspeed.json for regression tracking.
* prescreen — static pre-screen (repro.analysis): GA simulations avoided
            by decode-time infeasibility proofs on a memory-constrained
            scenario, the pruned chromosomes adversarially re-checked by
            provisioning through a capacity-bounded TensorPool (false
            prunes must be 0), α*-probe savings from the proven deadline
            floor, and a front-identity assertion on the unconstrained
            run. ``--json`` writes BENCH_prescreen.json (CI gates
            ``prescreen_false_prunes == 0``).
* conformance — device-in-the-loop tier: replays schedules on the
            virtual-clock PuzzleRuntime and diffs task traces against the
            FastSimulator at zero tolerance (asserted), reporting µs/replay
            for both sides.
* sweep   — randomized scenario-sweep harness (repro.experiments): per-
            scenario α* for Puzzle / Best Mapping / NPU Only and the
            aggregate frequency-gain ratios (paper §6, Fig. 11).
            ``sweep --smoke`` is the CI smoke target: 2 scenarios with a
            tiny GA, well under a minute. The default all-sections pass
            also uses smoke sizing; explicit selection (``run.py sweep``)
            or ``--full`` runs the full-size variant.
* arrivals — the arrival-process axis: the same scenario compositions
            under periodic vs jittered vs Poisson traffic, with each
            method's α*, frequency-gain ratios and satisfaction rates per
            process (smoke sizing on the default pass, like sweep).
* faults  — fault injection + graceful degradation: one deterministic
            scenario run on the virtual-clock runtime clean, faulted
            without recovery (raw drops) and faulted with the
            RecoveryPolicy (timeout/retry + dropout remap), reporting
            deadline satisfaction and dropped-request counts for each,
            the remap's recovery latency, and the analyzer-side
            ``score_under_faults`` robustness objective. Smoke sizing on
            the default pass, like sweep.
* roofline — per (arch × shape) roofline terms from the dry-run artifacts
             (EXPERIMENTS.md §Roofline)
* kernels — Pallas kernel oracle agreement

Sections can be selected positionally (``run.py sweep --smoke``) or via
``--only``. ``--full`` runs all 10 random scenarios per group setting
(default 3) — the paper's full protocol (sweep: 10 scenarios instead of 4).
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import statistics
import time
from typing import Dict, Tuple

import numpy as np

from repro.core import (
    AnalyzerConfig,
    GAConfig,
    PAPER_COMM_MODEL,
    PiecewiseLinearCommModel,
    Profiler,
    Solution,
    StaticAnalyzer,
    TableBackend,
    build_scenario,
    decode_solution,
    microbenchmark_host,
    mobile_processors,
    random_scenarios,
    whole_model_placement,
)
from repro.core.profiler import AnalyticMobileBackend, JaxExecBackend
from repro.zoo import (
    MODEL_NAMES,
    TABLE4_RATIO,
    all_cost_graphs,
    executable_zoo,
    paper_profile_tables,
)

ROW = "{name},{us:.2f},{derived}"


def emit(name: str, us: float, derived: str = "") -> None:
    print(ROW.format(name=name, us=us, derived=derived), flush=True)


def _profiler():
    procs = mobile_processors()
    backend = TableBackend(
        processors=procs, tables=paper_profile_tables(),
        fallback=AnalyticMobileBackend(procs),
    )
    return procs, Profiler(backend)


def _analyzer(groups, name="bench", seed=0):
    graphs = all_cost_graphs()
    procs, prof = _profiler()
    scen = build_scenario(name, groups, graphs)
    cfg = AnalyzerConfig(ga=GAConfig(pop_size=20, max_generations=30,
                                     min_generations=10, seed=seed))
    return StaticAnalyzer(scen, procs, prof, PAPER_COMM_MODEL, cfg)


# ---------------------------------------------------------------------------

def bench_table2(args) -> None:
    """CPU times by (dtype, backend); derived = ratio to the row minimum."""
    tables = paper_profile_tables()
    for model in MODEL_NAMES:
        cpu_rows = {k: v for k, v in tables[model].items() if k[0] == "cpu"}
        best = min(cpu_rows.values())
        for (kind, dt, be), t in sorted(cpu_rows.items()):
            emit(f"table2.{model}.{dt}.{be}", t * 1e6, f"x{t / best:.2f}")


def bench_table3(args) -> None:
    """Best configuration per processor; derived = ratio to best processor."""
    procs, prof = _profiler()
    graphs = all_cost_graphs()
    from repro.core import best_model_times
    bt = best_model_times(list(graphs.values()), procs, prof)
    for i, model in enumerate(graphs):
        best = min(t for t, _, _ in bt[i].values())
        for pid, (t, di, bi) in sorted(bt[i].items()):
            emit(f"table3.{model}.{procs[pid].name}", t * 1e6,
                 f"x{t / best:.2f}")


def bench_table4(args) -> None:
    """Non-linearity: Σ single-layer subgraphs vs whole graph (calibrated),
    plus a REAL device-in-the-loop measurement on reduced models."""
    procs, prof = _profiler()
    graphs = all_cost_graphs()
    for model in MODEL_NAMES:
        g = graphs[model]
        whole = prof.subgraph_time(whole_model_placement(g, 0, 2, 1, 0))
        sol = Solution(partition=[[1] * g.num_edges],
                       mapping=[[2] * g.num_layers],
                       priority=[0], dtype=[1], backend=[0])
        placed = decode_solution(sol, [g])[0]
        summed = sum(prof.subgraph_time(p) for p in placed)
        paper = TABLE4_RATIO[model]["npu"]
        emit(f"table4.{model}.npu", whole * 1e6,
             f"est_ratio={summed / whole:.2f};paper={paper:.2f}")
    # live measurement on this host's CPU device (real XLA fusion loss)
    zoo = executable_zoo(names=["selfie_seg"], channels=4, spatial=8)
    live = Profiler(JaxExecBackend(zoo, repeats=3))
    g = zoo["selfie_seg"].graph
    whole = live.subgraph_time(whole_model_placement(g, 0, 0, 0, 0))
    sol = Solution(partition=[[1] * g.num_edges], mapping=[[0] * g.num_layers],
                   priority=[0], dtype=[0], backend=[0])
    placed = decode_solution(sol, [g])[0]
    summed = sum(live.subgraph_time(p) for p in placed)
    emit("table4.live_cpu.selfie_seg", whole * 1e6,
         f"est_ratio={summed / whole:.2f}")


def bench_fig5(args) -> None:
    """Comm microbenchmark on this host + fitted piecewise model."""
    t0 = time.perf_counter()
    samples = microbenchmark_host()
    fit = PiecewiseLinearCommModel.fit(samples)
    for n, t in samples:
        emit(f"fig5.sample.{int(n)}B", t * 1e6, f"fit={fit.cost(n) * 1e6:.1f}us")
    emit("fig5.fit", (time.perf_counter() - t0) * 1e6,
         f"a_lo={fit.a_lo:.2e};b_lo={fit.b_lo:.2e};a_hi={fit.a_hi:.2e};"
         f"b_hi={fit.b_hi:.2e}")


def _saturation_experiment(num_groups: int, count: int, tag: str) -> None:
    scenarios = random_scenarios(
        MODEL_NAMES, count=count, models_per_scenario=6,
        num_groups=num_groups, seed=2025,
    )
    results = {"puzzle": [], "bm": [], "npu": []}
    cap = 6.0
    for i, groups in enumerate(scenarios):
        t0 = time.perf_counter()
        an = _analyzer(groups, name=f"{tag}{i}", seed=i)
        ga = an.run_ga()
        pz = an.median_saturation(ga.pareto)
        bm = an.median_saturation(an.best_mapping(max_evals=120))
        npu = an.saturation(an.npu_only()).alpha_star
        vals = {"puzzle": pz, "bm": bm, "npu": npu}
        for k, v in vals.items():
            results[k].append(min(v, cap))
        dt = time.perf_counter() - t0
        emit(f"{tag}.scenario{i}", dt * 1e6,
             f"puzzle={pz};best_mapping={bm};npu_only={npu};"
             f"ga_evals={ga.evaluations}")
    mean = {k: statistics.mean(v) for k, v in results.items()}
    sd = {k: statistics.pstdev(v) for k, v in results.items()}
    emit(f"{tag}.mean_puzzle", mean["puzzle"] * 1e6, f"sd={sd['puzzle']:.2f}")
    emit(f"{tag}.mean_best_mapping", mean["bm"] * 1e6, f"sd={sd['bm']:.2f}")
    emit(f"{tag}.mean_npu_only", mean["npu"] * 1e6, f"sd={sd['npu']:.2f}")
    paper_npu = "3.63x" if num_groups > 1 else "2.00x"
    paper_bm = "2.36x" if num_groups > 1 else "1.50x"
    emit(f"{tag}.freq_gain_vs_npu", 0.0,
         f"{mean['npu'] / mean['puzzle']:.2f}x (paper {paper_npu})")
    emit(f"{tag}.freq_gain_vs_best_mapping", 0.0,
         f"{mean['bm'] / mean['puzzle']:.2f}x (paper {paper_bm})")


def bench_fig12(args) -> None:
    """Single model group: saturation multipliers across random scenarios."""
    _saturation_experiment(1, 10 if args.full else 3, "fig12")


def bench_fig15(args) -> None:
    """Two model groups: saturation multipliers across random scenarios."""
    _saturation_experiment(2, 10 if args.full else 3, "fig15")


def bench_table5(args) -> None:
    """Runtime ablation: tensor pool / shared buffer (real execution)."""
    from repro.runtime import PuzzleRuntime, RuntimeConfig
    zoo = executable_zoo(names=["face_det", "selfie_seg", "hand_det"],
                         channels=4, spatial=8)
    graphs = [zoo[n].graph for n in ("face_det", "selfie_seg", "hand_det")]
    # split each model in two; mixed dtypes force dtype-boundary staging
    parts = []
    for g in graphs:
        bits = [0] * g.num_edges
        bits[g.num_layers // 2] = 1
        parts.append(bits)
    sol = Solution(
        partition=parts,
        mapping=[[2] * g.num_layers for g in graphs],
        priority=[0, 1, 2], dtype=[0, 1, 0], backend=[0, 0, 0],
    )
    procs = mobile_processors()
    base_ms = None
    for pool, shared, label in [(False, False, "no_opt"),
                                (True, False, "pool"),
                                (True, True, "pool+shared")]:
        rt = PuzzleRuntime(graphs, sol, procs, zoo,
                           RuntimeConfig(tensor_pool=pool, shared_buffer=shared))
        try:
            res = rt.run_periodic([[0, 1, 2]], [0.02], num_requests=12)
            ms = statistics.mean(s.makespan for s in res[0])
            stats = rt.stats()
        finally:
            rt.close()
        if base_ms is None:
            base_ms = ms
        emit(f"table5.{label}", ms * 1e6,
             f"rel_makespan={ms / base_ms:.3f};mallocs={stats['pool']['mallocs']};"
             f"memcpy_bytes={stats['pool']['memcpy_bytes']};"
             f"staged={stats['transport']['staged_copies']}")


def bench_simspeed(args) -> None:
    """Old-vs-new evaluation engine: parity, µs/eval, end-to-end speedup."""
    groups = random_scenarios(
        MODEL_NAMES, count=1, models_per_scenario=6, num_groups=3, seed=7,
    )[0]
    record: Dict[str, object] = {"scenario": [list(g) for g in groups]}

    def make_analyzer(engine: str, saturation_mode: str) -> StaticAnalyzer:
        graphs = all_cost_graphs()
        procs, prof = _profiler()
        scen = build_scenario("simspeed", groups, graphs)
        # "reference" emulates the seed path end to end: generator-coroutine
        # DES, per-simulation re-decode, pure-Python NSGA, 117-point α grid.
        cfg = AnalyzerConfig(
            engine=engine, saturation_mode=saturation_mode,
            ga=GAConfig(pop_size=20, max_generations=30, min_generations=10,
                        seed=0, vectorized_nsga=(engine == "fast")),
        )
        return StaticAnalyzer(scen, procs, prof, PAPER_COMM_MODEL, cfg)

    an = make_analyzer("fast", "bisect")
    an.factory.rng = __import__("random").Random(123)
    sols = [an.factory.random_solution() for _ in range(12)]

    # 1) parity: identical makespans on the deterministic scenario, clean
    #    and measured (noisy + dispatch overhead) paths.
    max_diff = 0.0
    for measured in (False, True):
        ref = an.simulate(sols[0], 1.0, 24, measured=measured, seed=5,
                          engine="reference")
        fast = an.simulate(sols[0], 1.0, 24, measured=measured, seed=5,
                           engine="fast")
        pairs = list(zip(ref.makespans(), fast.makespans()))
        assert pairs, "no requests simulated"
        # dropped requests are inf on both sides: inf == inf is agreement,
        # not a nan-poisoned diff
        diff = max(
            0.0 if math.isinf(a) and math.isinf(b) else abs(a - b)
            for a, b in pairs
        )
        max_diff = max(max_diff, diff)
    emit("simspeed.parity", 0.0,
         f"max_makespan_diff={max_diff:.3e};ok={max_diff == 0.0}")
    record["parity_max_diff"] = max_diff

    # 2) µs per objectives() evaluation across distinct solutions (cold
    #    decode each time for both engines).
    def time_evals(engine: str) -> float:
        a = make_analyzer(engine, "bisect")
        t0 = time.perf_counter()
        for s in sols:
            a.objectives(s, engine=engine)
        return (time.perf_counter() - t0) / len(sols)

    ref_us = time_evals("reference") * 1e6
    fast_us = time_evals("fast") * 1e6
    emit("simspeed.eval_reference", ref_us, "per objectives() call")
    emit("simspeed.eval_fastsim", fast_us,
         f"per objectives() call;speedup=x{ref_us / fast_us:.2f}")
    record["eval_us_reference"] = ref_us
    record["eval_us_fastsim"] = fast_us

    # 3) per-α score cost for a fixed solution: the decode cache amortizes
    #    decoding + cost annotation across the whole α sweep.
    alphas = [round(0.5 + 0.25 * i, 4) for i in range(16)]
    t0 = time.perf_counter()
    for a_ in alphas:
        an.score(sols[1], a_)
    sweep_fast_us = (time.perf_counter() - t0) / len(alphas) * 1e6
    an_ref = make_analyzer("reference", "grid")
    t0 = time.perf_counter()
    for a_ in alphas:
        an_ref.score(sols[1], a_)
    sweep_ref_us = (time.perf_counter() - t0) / len(alphas) * 1e6
    emit("simspeed.score_per_alpha_reference", sweep_ref_us, "36-request sims")
    emit("simspeed.score_per_alpha_fastsim", sweep_fast_us,
         f"speedup=x{sweep_ref_us / sweep_fast_us:.2f}")
    record["score_per_alpha_us_reference"] = sweep_ref_us
    record["score_per_alpha_us_fastsim"] = sweep_fast_us

    # 4) α*-search: 117-point grid vs bracket+bisect (both on fastsim).
    #    The NPU-only baseline has a well-behaved finite α*.
    sat_sol = an.npu_only()
    t0 = time.perf_counter()
    grid = an.saturation(sat_sol, mode="grid")
    grid_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    bis = an.saturation(sat_sol, mode="bisect")
    bis_s = time.perf_counter() - t0
    emit("simspeed.alpha_star_grid", grid_s * 1e6,
         f"alpha_star={grid.alpha_star};evals={len(grid.scores)}")
    emit("simspeed.alpha_star_bisect", bis_s * 1e6,
         f"alpha_star={bis.alpha_star};evals={len(bis.scores)};"
         f"agrees={bis.alpha_star == grid.alpha_star}")
    record["alpha_star_grid"] = grid.alpha_star
    record["alpha_star_bisect"] = bis.alpha_star
    record["alpha_star_evals_grid"] = len(grid.scores)
    record["alpha_star_evals_bisect"] = len(bis.scores)

    # 5) end-to-end: GA search + one saturation sweep, seed path (reference
    #    DES, per-sim re-decode, pure-Python NSGA, 117-point grid scan) vs
    #    fast path (fastsim + decode/objective caches + bisection). Wall
    #    clock is min-of-N, interleaved, with the collector paused during
    #    each timed leg (timeit-style hygiene, applied to both paths) to
    #    damp scheduler/GC noise.
    import gc

    def end_to_end(engine: str, mode: str) -> Tuple[float, float, int]:
        a = make_analyzer(engine, mode)
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            ga = a.run_ga()
            sat = a.saturation(ga.pareto[0])
            dt = time.perf_counter() - t0
        finally:
            gc.enable()
        return dt, sat.alpha_star, ga.evaluations

    old_s = new_s = float("inf")
    for _ in range(2):  # interleave repeats so CPU-clock drift hits both paths
        t, old_alpha, old_evals = end_to_end("reference", "grid")
        old_s = min(old_s, t)
        t, new_alpha, new_evals = end_to_end("fast", "bisect")
        new_s = min(new_s, t)
    emit("simspeed.e2e_seed_path", old_s * 1e6,
         f"alpha_star={old_alpha};ga_evals={old_evals}")
    emit("simspeed.e2e_fast_path", new_s * 1e6,
         f"alpha_star={new_alpha};ga_evals={new_evals};"
         f"speedup=x{old_s / new_s:.2f}")
    record["e2e_seconds_seed_path"] = old_s
    record["e2e_seconds_fast_path"] = new_s
    record["e2e_speedup"] = old_s / new_s
    record["e2e_alpha_star"] = {"seed_path": old_alpha, "fast_path": new_alpha}

    # 6) generation-batched population evaluation (core/batchsim): evaluate
    #    one GA-realistic generation (pop_size 40 -> 40 parents + 40
    #    offspring) through (a) the per-solution fast path, (b) one
    #    in-process lock-step batch pass, (c) the batch pass sharded across
    #    a 2-process pool. All three produce bit-identical objectives
    #    (asserted); the recorded numbers are the honest population-eval
    #    throughput comparison on this host.
    import random as _random

    gen_an = make_analyzer("fast", "bisect")
    gen_an.factory.rng = _random.Random(4242)
    parents = [gen_an.factory.random_solution() for _ in range(40)]
    offspring = []
    for i in range(0, 40, 2):
        a, b = parents[i], parents[i + 1]
        c1, c2 = gen_an.factory.crossover(a, b)
        offspring.append(gen_an.factory.mutate(c1))
        offspring.append(gen_an.factory.mutate(c2))
    generation = parents + offspring

    def time_population(fn, an) -> Tuple[float, object]:
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            out = fn(an)
            return time.perf_counter() - t0, out
        finally:
            gc.enable()

    per_s, objs_loop = time_population(
        lambda a: [a.objectives(s) for s in generation],
        make_analyzer("fast", "bisect"))
    bat_s, objs_batch = time_population(
        lambda a: a.objectives_batch(generation),
        make_analyzer("fast", "bisect"))
    # The sharded number is the *raw* 2-process cost: a GA generation sits
    # below batchsim.SHARD_MIN_LANES (the measured crossover where pickling
    # lanes across the pool starts paying), so run_batch would normally keep
    # it in-process. Force-lower the threshold for this timing only, so the
    # recorded row shows what sharding would actually cost here.
    import repro.core.batchsim as _batchsim

    an_sh = make_analyzer("fast", "bisect")
    an_sh.cfg.batch_workers = 2
    an_sh2 = make_analyzer("fast", "bisect")
    an_sh2.cfg.batch_workers = 2
    _saved_min = _batchsim.SHARD_MIN_LANES
    _batchsim.SHARD_MIN_LANES = 0
    try:
        an_sh.objectives_batch(generation[:4])  # warm the pool + caches
        an_sh2._batch_pool = an_sh._batch_pool  # reuse the live pool
        shard_s, objs_shard = time_population(
            lambda a: a.objectives_batch(generation), an_sh2)
    finally:
        _batchsim.SHARD_MIN_LANES = _saved_min
        an_sh2._batch_pool = None
        an_sh.close()
    assert objs_loop == objs_batch == objs_shard, "batch parity violated"
    n = len(generation)
    per_us, bat_us, shard_us = (x / n * 1e6 for x in (per_s, bat_s, shard_s))
    best_us = min(bat_us, shard_us)
    speedup = per_us / best_us
    emit("simspeed.pop_eval_per_solution", per_us,
         f"{n}-candidate generation;evals_per_s={1e6 / per_us:.0f}")
    emit("simspeed.pop_eval_batch", bat_us,
         f"one lock-step pass;speedup=x{per_us / bat_us:.2f}")
    emit("simspeed.pop_eval_batch_sharded", shard_us,
         f"2-process shards (forced below SHARD_MIN_LANES="
         f"{_saved_min});speedup=x{per_us / shard_us:.2f}")
    record["eval_us_population_per_solution"] = per_us
    record["eval_us_batch"] = best_us
    record["eval_us_batch_inprocess"] = bat_us
    record["eval_us_batch_sharded"] = shard_us
    record["batch_speedup"] = speedup
    record["batch_parity_ok"] = True
    record["shard_min_lanes"] = _saved_min

    # 6b) compiled (jax) leg, full 6-model scenario: the same generation
    #     through the jitted jax.lax.while_loop core. First pass pays the
    #     XLA compile (recorded separately); the warm pass is the
    #     steady-state GA cost. last_stats is asserted so a silent numpy
    #     fallback cannot fake the number, and the objective drift vs the
    #     bit-exact loop is measured and bounded by the documented
    #     tolerance. On this scenario the per-request event count is large
    #     and GA cut-count variance makes lanes heterogeneous, so the
    #     lock-step pass (max-lane iterations × full-width element work)
    #     does NOT beat the scalar loop — recorded honestly as
    #     compiled_speedup_full_scenario; the crossover leg below (6c)
    #     times all three engines on one workload and carries the gated
    #     compiled_speedup (compiled vs the numpy lock-step tier).
    try:
        import jax as _jax  # noqa: F401
        _have_jax = True
    except Exception:
        _have_jax = False
    if _have_jax:
        import repro.core.batchsim_compiled as _bsc
        from repro.core import COMPILED_ABS_TOL, COMPILED_REL_TOL

        an_c = make_analyzer("fast", "bisect")
        an_c.cfg.batch_engine = "compiled"
        cold_s, _ = time_population(
            lambda a: a.objectives_batch(generation), an_c)
        an_c2 = make_analyzer("fast", "bisect")
        an_c2.cfg.batch_engine = "compiled"
        comp_s, objs_comp = time_population(
            lambda a: a.objectives_batch(generation), an_c2)
        assert _bsc.last_stats.get("fallback") is False, _bsc.last_stats
        comp_diff = 0.0
        for row_a, row_b in zip(objs_loop, objs_comp):
            for x, y in zip(row_a, row_b):
                if math.isinf(x) or math.isinf(y):
                    assert math.isinf(x) and math.isinf(y), "inf mismatch"
                    continue
                comp_diff = max(comp_diff, abs(x - y))
                assert abs(x - y) <= (
                    COMPILED_ABS_TOL
                    + COMPILED_REL_TOL * max(abs(x), abs(y))
                ), "compiled tolerance violated"
        comp_us = comp_s / n * 1e6
        comp_speedup = per_us / comp_us
        emit("simspeed.pop_eval_batch_compiled", comp_us,
             f"jitted while_loop;speedup=x{comp_speedup:.2f};"
             f"max_diff={comp_diff:.3e};compile_s={cold_s - comp_s:.2f}")
        record["eval_us_batch_compiled"] = comp_us
        record["compiled_speedup_full_scenario"] = comp_speedup
        record["compiled_max_diff"] = comp_diff
        record["compiled_cold_compile_s"] = cold_s - comp_s
        record["eval_us_batch"] = min(best_us, comp_us)

        # 6c) compiled crossover leg: a compact 2-group scenario at GA
        #     width (80 lanes, measured noise + dispatch, 20 requests),
        #     timed through all three batch-capable paths on identical
        #     lanes. The gated compiled_speedup is compiled vs the numpy
        #     lock-step tier it replaces on the batch path (>1 everywhere
        #     measured, ~2.5-3x here). The scalar-loop comparison is
        #     recorded separately as compiled_speedup_vs_scalar and is < 1
        #     on this CPU: FastSimulator handles an event in ~0.75 µs of
        #     python while the compiled core's masked full-width iteration
        #     has a ~2 µs/lane floor at ~1.5 events per iteration — which
        #     is the measured crossover, and why the scalar loop (not any
        #     batch tier) remains the default CPU evaluation path.
        from repro.core import (
            BatchLane,
            BatchSimulator,
            FastSimulator,
            NoiseModel,
            SolutionFactory,
            build_spec,
            chain_graph,
        )
        from repro.core.batchsim_compiled import run_batch_compiled

        procs_x, prof_x = _profiler()
        nets_x = [
            chain_graph("m0", [("conv", 6e6, 2500, 7500)] * 3),
            chain_graph("m1", [("conv", 9e6, 3000, 9000)] * 4),
            chain_graph("m2", [("fc", 4e6, 2000, 5000)] * 3),
            chain_graph("m3", [("conv", 7e6, 2800, 8000)] * 3),
        ]
        groups_x = [[0, 1], [2, 3]]
        periods_x = (0.033, 0.05)
        fac_x = SolutionFactory(nets_x, num_processors=len(procs_x),
                                rng=_random.Random(9), cut_prob=0.3)
        lanes_x = []
        for i in range(80):
            spec_x = build_spec(decode_solution(fac_x.random_solution(),
                                                nets_x),
                                procs_x, prof_x, PAPER_COMM_MODEL)
            lanes_x.append(BatchLane(
                spec=spec_x, periods=periods_x, num_requests=20,
                noise=NoiseModel(seed=i), dispatch_overhead=150e-6))
        run_batch_compiled(lanes_x, groups_x, procs_x)  # pay the compile
        gc.collect()
        t0 = time.perf_counter()
        res_x = run_batch_compiled(lanes_x, groups_x, procs_x)
        comp_x_s = time.perf_counter() - t0
        assert res_x is not None, _bsc.last_stats
        assert _bsc.last_stats.get("fallback") is False, _bsc.last_stats
        t0 = time.perf_counter()
        fast_x = [
            FastSimulator(ln.spec, groups=groups_x, periods=ln.periods,
                          num_requests=ln.num_requests, noise=ln.noise,
                          dispatch_overhead=ln.dispatch_overhead).run()
            for ln in lanes_x
        ]
        scal_x_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        BatchSimulator(lanes_x, groups_x, procs_x).run()
        np_x_s = time.perf_counter() - t0
        diff_x = 0.0
        for i, fr in enumerate(fast_x):
            for a, b in zip([q.makespan for q in fr.requests],
                            [q.makespan for q in res_x.result(i).requests]):
                if math.isinf(a) or math.isinf(b):
                    assert math.isinf(a) and math.isinf(b), "inf mismatch"
                    continue
                diff_x = max(diff_x, abs(a - b))
                assert abs(a - b) <= (
                    COMPILED_ABS_TOL + COMPILED_REL_TOL * max(abs(a), abs(b))
                ), "compiled tolerance violated"
        emit("simspeed.compiled_crossover", comp_x_s / 80 * 1e6,
             f"compact 2-group scenario;scalar_us="
             f"{scal_x_s / 80 * 1e6:.0f};numpy_us={np_x_s / 80 * 1e6:.0f};"
             f"vs_numpy=x{np_x_s / comp_x_s:.2f};"
             f"vs_scalar=x{scal_x_s / comp_x_s:.2f};"
             f"max_diff={diff_x:.3e}")
        record["compiled_speedup"] = np_x_s / comp_x_s
        record["compiled_speedup_vs_scalar"] = scal_x_s / comp_x_s
        record["compiled_crossover_us_scalar"] = scal_x_s / 80 * 1e6
        record["compiled_crossover_us_compiled"] = comp_x_s / 80 * 1e6
        record["compiled_crossover_us_numpy"] = np_x_s / 80 * 1e6
    else:
        emit("simspeed.pop_eval_batch_compiled", 0.0, "jax unavailable")
        record["eval_us_batch_compiled"] = None
        record["compiled_speedup"] = None
        record["compiled_speedup_full_scenario"] = None
        record["compiled_max_diff"] = None

    # batched population α*-search over a candidate set (Pareto-front shape)
    sat_cands = parents[:8]
    sat_per_s, sat_loop = time_population(
        lambda a: [a.saturation(s) for s in sat_cands],
        make_analyzer("fast", "bisect"))
    sat_bat_s, sat_batch = time_population(
        lambda a: a.population_saturation(sat_cands),
        make_analyzer("fast", "bisect"))
    assert [r.alpha_star for r in sat_loop] ==\
        [r.alpha_star for r in sat_batch], "saturation parity violated"
    emit("simspeed.pop_alpha_star_per_solution", sat_per_s / 8 * 1e6,
         "bisect per candidate")
    emit("simspeed.pop_alpha_star_batch", sat_bat_s / 8 * 1e6,
         f"batched rounds;speedup=x{sat_per_s / sat_bat_s:.2f}")
    record["alpha_star_us_population_per_solution"] = sat_per_s / 8 * 1e6
    record["alpha_star_us_population_batch"] = sat_bat_s / 8 * 1e6
    record["batch_notes"] = (
        "numpy batchsim is bit-identical to the per-solution fast path "
        "(asserted above and by the differential property suite) but each "
        "lock-step event still touches ~30 scalars, so per-solution python "
        "remains competitive at GA widths; the compiled (jax) leg fuses the "
        "whole frontier advance into one jitted while_loop and beats the "
        "numpy lock-step tier ~2.5-3x on every measured workload, but the "
        "scalar loop keeps a ~0.75 us/event floor the full-width masked "
        "iteration cannot undercut on CPU, so the scalar path stays the "
        "default and compiled is the opt-in batch backend - see "
        "ARCHITECTURE.md (engines) for the measured crossover analysis")

    if getattr(args, "json", False):
        record["timestamp"] = time.time()

        def _finite(v):
            if isinstance(v, float) and not np.isfinite(v):
                return None
            if isinstance(v, dict):
                return {k: _finite(x) for k, x in v.items()}
            if isinstance(v, list):
                return [_finite(x) for x in v]
            return v

        safe = {k: _finite(v) for k, v in record.items()}
        out = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_simspeed.json")
        with open(os.path.abspath(out), "w") as f:
            json.dump(safe, f, indent=2, sort_keys=True)
        emit("simspeed.json", 0.0, os.path.abspath(out))


def bench_conformance(args) -> None:
    """Runtime↔simulator conformance: zero-diff assertion + replay cost.

    Replays deterministic schedules of a 2-group scenario on the
    virtual-clock PuzzleRuntime (the device-in-the-loop tier's exact-replay
    mode) and diffs release/start/finish timestamps and makespans against
    FastSimulator under measured (noise + dispatch) conditions. The diff
    must be zero; the emitted rows compare the per-replay cost of the two
    tiers.
    """
    import random as _random

    from repro.core import SolutionFactory

    an = _analyzer([["face_det", "selfie_seg"], ["yolov8n", "fast_scnn"]],
                   name="conformance", seed=0)
    fac = SolutionFactory(an.scenario.graphs, num_processors=3,
                          rng=_random.Random(7))
    solutions = [fac.random_solution() for _ in range(4)]
    nr = 12 if getattr(args, "smoke", False) else 24

    reports = []
    t0 = time.perf_counter()
    for sol in solutions:
        reports.append(an.validate_on_runtime(
            sol, alpha=1.0, num_requests=nr, measured=True, seed=0))
    t_validate = (time.perf_counter() - t0) / len(solutions)
    assert all(r.passed for r in reports), "virtual runtime diverged"
    max_diff = max(max(r.max_release_diff, r.max_start_diff,
                       r.max_finish_diff, r.max_makespan_diff)
                   for r in reports)
    tasks = sum(r.runtime_tasks for r in reports)

    # replay-cost split: simulator vs virtual-clock runtime on the same spec
    t0 = time.perf_counter()
    for sol in solutions:
        an.simulate(sol, 1.0, nr, measured=True, collect_tasks=True)
    t_sim = (time.perf_counter() - t0) / len(solutions)
    from repro.runtime.conformance import run_virtual_schedule
    t0 = time.perf_counter()
    for sol in solutions:
        run_virtual_schedule(
            an.scenario.graphs, sol, an.processors, an.solution_spec(sol),
            an.scenario.groups, an.base_periods, nr,
            noise=an.cfg.noise, dispatch_overhead=an.cfg.dispatch_overhead)
    t_rt = (time.perf_counter() - t0) / len(solutions)

    emit("conformance.zero_diff", t_validate * 1e6,
         f"ok=True;max_abs_diff={max_diff};tasks={tasks}")
    emit("conformance.fastsim_replay", t_sim * 1e6, f"requests={nr}")
    emit("conformance.virtual_runtime_replay", t_rt * 1e6,
         f"overhead=x{t_rt / t_sim:.2f} vs fastsim")


def _sweep_sizing(args, section: str, explicit_count: int,
                  full_count: int = 10):
    """(scenario count, SweepConfig) for a sweep-harness-backed section.

    Full sizing when the section is selected explicitly or ``--full`` asks
    for the paper's full protocol (matching fig12/fig15); otherwise — on
    the default all-sections pass or with ``--smoke`` — a 2-scenario tiny
    GA keeps the pass quick.
    """
    from repro.experiments import SweepConfig

    explicit = getattr(args, "full", False) or section in (
        getattr(args, "section", None), getattr(args, "only", None))
    if getattr(args, "smoke", False) or not explicit:
        return 2, SweepConfig(
            pop_size=8, max_generations=6, min_generations=2, bm_max_evals=30,
        )
    return (full_count if args.full else explicit_count), SweepConfig()


def bench_sweep(args) -> None:
    """Scenario-sweep harness smoke/regression: per-scenario α* + aggregates.

    ``--smoke``: 2 scenarios, tiny GA — a sub-minute regression check that
    the harness end-to-end (generation → evaluation → aggregation) still
    works and stays deterministic. Smoke sizing is also used when this
    section runs as part of the default all-sections pass, so ``run.py``
    with no arguments stays quick; selecting the section explicitly
    (``run.py sweep`` / ``--only sweep``) runs 4 scenarios at the harness's
    real GA sizing, and ``--full`` (with or without section selection,
    matching fig12/fig15) runs 10. Always evaluates into a fresh temp run
    dir so timings reflect real compute, not a resumed directory.
    """
    import tempfile

    from repro.experiments import METHODS, generate_scenario_specs
    from repro.experiments.sweep import run_sweep

    count, config = _sweep_sizing(args, "sweep", explicit_count=4)
    specs = generate_scenario_specs(count, seed=2025)
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="puzzle_sweep_bench_") as run_dir:
        doc = run_sweep(specs, config, run_dir=run_dir, workers=1)
    wall = time.perf_counter() - t0
    for row in doc["scenarios"]:
        stars = ";".join(
            f"{m}={'never' if row['alpha_star'][m] is None else row['alpha_star'][m]}"
            for m in METHODS
        )
        emit(f"sweep.{row['spec']['name']}", row["wall_s"] * 1e6, stars)
    agg = doc["aggregate"]
    emit("sweep.gain_vs_npu_only", wall * 1e6 / count,
         f"{agg['speedup_geomean']['vs_npu_only']:.2f}x (paper 3.7x)")
    emit("sweep.gain_vs_best_mapping", wall * 1e6 / count,
         f"{agg['speedup_geomean']['vs_best_mapping']:.2f}x (paper 2.2x)")
    sat = agg["satisfaction_rate"]
    emit("sweep.satisfaction", wall * 1e6,
         ";".join(f"{m}={sat[m]:.2f}" for m in METHODS))
    # determinism canary: regenerating the specs must reproduce the stored
    # scenario compositions bit-for-bit
    again = [s.to_json() for s in generate_scenario_specs(count, seed=2025)]
    stored = [row["spec"] for row in doc["scenarios"]]
    emit("sweep.deterministic", 0.0, f"ok={again == stored}")


def bench_arrivals(args) -> None:
    """Puzzle vs baselines under bursty load (the arrival-process axis).

    Evaluates the same randomly drawn scenario compositions under three
    arrival processes — periodic (the paper's sources), jittered (uniform
    ±25% of Φ) and Poisson (exponential inter-arrivals at rate 1/Φ) — and
    reports each method's median α*, the geo-mean frequency gains and the
    deadline-satisfaction rate at α = 1. The compositions are identical
    across processes (only the traffic changes), so the drop from the
    ``periodic`` rows to the ``poisson`` rows is the price of burstiness,
    and the gain ratios show whether Puzzle's advantage survives it.
    Smoke sizing applies on the default all-sections pass (explicit
    selection or ``--full`` runs the harness's real GA sizing).
    """
    import tempfile

    from repro.experiments import METHODS, generate_scenario_specs
    from repro.experiments.sweep import run_sweep

    count, config = _sweep_sizing(args, "arrivals", explicit_count=3,
                                  full_count=6)
    for kind in ("periodic", "jittered", "poisson"):
        specs = generate_scenario_specs(count, seed=2025, arrival=kind)
        t0 = time.perf_counter()
        with tempfile.TemporaryDirectory(
                prefix=f"puzzle_arrivals_{kind}_") as run_dir:
            doc = run_sweep(specs, config, run_dir=run_dir, workers=1)
        wall = time.perf_counter() - t0
        for row in doc["scenarios"]:
            stars = ";".join(
                f"{m}={'never' if row['alpha_star'][m] is None else row['alpha_star'][m]}"
                for m in METHODS)
            emit(f"arrivals.{kind}.{row['spec']['name']}",
                 row["wall_s"] * 1e6, stars)
        agg = doc["aggregate"]
        sat = agg["satisfaction_rate"]
        emit(f"arrivals.{kind}.gain", wall * 1e6 / count,
             f"vs_npu={agg['speedup_geomean']['vs_npu_only']:.2f}x;"
             f"vs_bm={agg['speedup_geomean']['vs_best_mapping']:.2f}x;"
             + ";".join(f"sat_{m}={sat[m]:.2f}" for m in METHODS))


def bench_faults(args) -> None:
    """Fault injection + graceful degradation on the virtual runtime.

    One deterministic 2-group scenario, one solution that places work on
    processor 2, three virtual-clock runs:

    * ``clean``      — no faults, no recovery (baseline satisfaction)
    * ``raw``        — a mid-run permanent dropout of processor 2 plus
                       heavy-tailed stragglers, no recovery: every request
                       needing the dead processor is dropped
    * ``recovered``  — same ensemble with the RecoveryPolicy: the dropout
                       triggers the fallback remap, stragglers hit the
                       timeout/retry watchdog, and no request is dropped

    Emitted per run: pooled deadline satisfaction (at a feasible α,
    calibrated so the clean baseline meets its deadlines — otherwise the
    comparison is degenerate) and the dropped count.
    ``faults.recovery_latency`` is the remap's drain time — the
    last finish among requests already in flight at the drop instant,
    minus the drop instant. The analyzer-side ``score_under_faults`` rows
    report the same degradation measured by the simulator tiers (the
    robustness objective the GA sees when a scenario carries faults).
    """
    import random as _random

    from repro.core import FaultSpec, SolutionFactory
    from repro.core.scoring import deadline_satisfaction
    from repro.runtime import PuzzleRuntime, RecoveryPolicy, RuntimeConfig

    explicit = getattr(args, "full", False) or "faults" in (
        getattr(args, "section", None), getattr(args, "only", None))
    nr = 16 if explicit and not getattr(args, "smoke", False) else 8

    an = _analyzer([["face_det", "selfie_seg"], ["yolov8n"]],
                   name="faults", seed=0)
    graphs = list(an.scenario.graphs)
    groups = [list(g) for g in an.scenario.groups]
    base_periods = list(an.base_periods)

    # a draw that actually uses processor 2, so the dropout bites
    sol = None
    for seed in range(64):
        fac = SolutionFactory(graphs, num_processors=len(an.processors),
                              rng=_random.Random(seed))
        cand = fac.random_solution()
        if any(p.processor == 2
               for pl in decode_solution(cand, graphs) for p in pl):
            sol = cand
            break
    assert sol is not None, "no draw places work on processor 2"
    spec = an.solution_spec(sol)

    def run(periods, faults, recovery):
        rt = PuzzleRuntime(
            graphs, sol, an.processors,
            config=RuntimeConfig(virtual=True, faults=faults,
                                 recovery=recovery),
            spec=spec,
        )
        t0 = time.perf_counter()
        with rt:
            states = rt.run_periodic(groups, periods, num_requests=nr)
        return rt, states, time.perf_counter() - t0

    # arrivals stay at the paper's base periods — congested, so work is
    # genuinely in flight when the dropout hits. Satisfaction deadlines
    # are calibrated per group from the clean run (at α=1 this solution
    # misses every deadline and all three numbers degenerate to 0).
    _, clean_states, t_clean = run(base_periods, None, None)
    deadlines = [1.2 * max(st.makespan for st in gl) for gl in clean_states]
    alpha = round(max(d / p for d, p in zip(deadlines, base_periods)), 2)
    emit("faults.deadlines", 0.0,
         ";".join(f"g{g}={d:.4f}s" for g, d in enumerate(deadlines))
         + f";alpha_equiv={alpha}")

    def sat(states):
        per_group = [[float("inf") if st.makespan is None else st.makespan
                      for st in gl] for gl in states]
        dropped = sum(st.makespan is None for gl in states for st in gl)
        return deadline_satisfaction(per_group, deadlines), dropped

    sat_clean, drop_clean = sat(clean_states)
    horizon = max(st.last_finish or 0.0
                  for gl in clean_states for st in gl)
    t_drop = round(0.35 * horizon, 6)
    faults = FaultSpec(dropouts=((2, t_drop, None),),
                       straggler_prob=0.1, straggler_shape=1.5, seed=2025)

    rt_raw, raw_states, t_raw = run(base_periods, faults, None)
    sat_raw, drop_raw = sat(raw_states)
    rt_rec, rec_states, t_rec = run(base_periods, faults, RecoveryPolicy())
    sat_rec, drop_rec = sat(rec_states)

    emit("faults.clean", t_clean * 1e6,
         f"satisfaction={sat_clean:.2f};dropped={drop_clean};requests={nr * 3}")
    emit("faults.raw", t_raw * 1e6,
         f"satisfaction={sat_raw:.2f};dropped={drop_raw};"
         f"delta_vs_clean={sat_clean - sat_raw:+.2f}")
    remaps = [e for e in rt_rec.recovery_events if e.kind == "remap"]
    retries = [e for e in rt_rec.recovery_events if e.kind == "retry"]
    emit("faults.recovered", t_rec * 1e6,
         f"satisfaction={sat_rec:.2f};dropped={drop_rec};"
         f"delta_vs_clean={sat_clean - sat_rec:+.2f};"
         f"remaps={len(remaps)};retries={len(retries)}")

    # recovery latency: drain time of the requests in flight at the drop
    inflight = [st for gl in rec_states for st in gl
                if st.submitted <= t_drop
                and (st.last_finish is None or st.last_finish > t_drop)]
    if inflight and all(st.last_finish is not None for st in inflight):
        latency = max(st.last_finish for st in inflight) - t_drop
        emit("faults.recovery_latency", latency * 1e6,
             f"t_drop={t_drop};inflight={len(inflight)}")
    else:
        emit("faults.recovery_latency", 0.0,
             f"t_drop={t_drop};inflight={len(inflight)};drained=False")

    # analyzer-side robustness objective (simulator tiers, measured path)
    rep = an.score_under_faults(sol, faults=faults, alpha=alpha,
                                num_requests=nr)
    emit("faults.score_under_faults", 0.0,
         f"sat_clean={rep['satisfaction_clean']:.2f};"
         f"sat_faulted={rep['satisfaction_faulted']:.2f};"
         f"dropped_clean={rep['dropped_clean']:.0f};"
         f"dropped_faulted={rep['dropped_faulted']:.0f};"
         f"score_delta={rep['score_delta']:.3f}")


def bench_roofline(args) -> None:
    """Roofline terms per (arch × shape) from the dry-run artifacts."""
    pat = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun",
                       "*__single.json")
    files = sorted(glob.glob(pat))
    if not files:
        emit("roofline.missing", 0.0, "run repro.launch.dryrun first")
        return
    for f in files:
        d = json.load(open(f))
        if not d.get("ok"):
            emit(f"roofline.{d['arch']}.{d['shape']}", 0.0, "FAILED")
            continue
        dom = max(("t_compute", "t_memory", "t_collective"),
                  key=lambda k: d[k])
        emit(
            f"roofline.{d['arch']}.{d['shape']}",
            d[dom] * 1e6,
            f"bottleneck={d['bottleneck']};compute={d['t_compute']:.4f}s;"
            f"memory={d['t_memory']:.4f}s;collective={d['t_collective']:.4f}s;"
            f"useful={d['useful_ratio']:.2f}",
        )


def bench_kernels(args) -> None:
    """Kernel oracle agreement + wall time of the jnp reference path."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import attention_ref, flash_attention
    from repro.models import blockwise_attention
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (8, 512, 128))
    k = jax.random.normal(key, (2, 512, 128))
    v = jax.random.normal(key, (2, 512, 128))
    got = flash_attention(q, k, v, q_heads_per_kv=4, interpret=True,
                          block_q=128, block_k=128)
    want = attention_ref(q, k, v, q_heads_per_kv=4)
    err = float(jnp.abs(got - want).max())
    # time the production jnp path (the kernel itself is interpret-only here)
    qb = q.reshape(1, 8, 512, 128).transpose(0, 2, 1, 3)
    kb = k.reshape(1, 2, 512, 128).transpose(0, 2, 1, 3)
    fn = jax.jit(lambda a, b: blockwise_attention(a, b, b))
    jax.block_until_ready(fn(qb, kb))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(fn(qb, kb))
    emit("kernels.flash_attention", (time.perf_counter() - t0) / 5 * 1e6,
         f"max_err_vs_ref={err:.2e}")


def bench_prescreen(args) -> None:
    """Static pre-screen (repro.analysis): simulations avoided per GA run.

    One deterministic 2-group scenario, twice:

    1. **Unconstrained** — prescreen on vs off must yield bit-identical
       Pareto fronts and evaluation counts (nothing is provable, so the
       pre-screen may not perturb the search). Asserted.
    2. **Memory-constrained** — the NPU gets a tensor-memory budget that
       many chromosomes provably exceed (SL020): reports how many GA
       simulations the pre-screen avoided, and adversarially re-checks
       every pruned chromosome by *actually provisioning* it through a
       capacity-bounded TensorPool — a prune whose provisioning succeeds
       would be a soundness bug (``false_prunes``, must be 0; CI gates it).

    Also measures the α*-search probe savings from the proven deadline
    lower bound (``skip_below``), asserting α* itself is unchanged.
    ``--json`` writes BENCH_prescreen.json for the CI gate.
    """
    import dataclasses

    from repro.analysis import provision_memory
    from repro.core.graph import chain_graph
    from repro.core.scenarios import Scenario

    nets = (
        chain_graph("alpha", [("conv", 4e6, 1000, 4000)] * 4),
        chain_graph("beta", [("fc", 8e6, 2000, 8000)] * 3),
        chain_graph("gamma", [("dw", 1.5e6, 600, 1800)] * 5),
    )
    scenario = Scenario(name="prescreen_bench", graphs=nets,
                        groups=((0, 1), (2,)))
    procs = mobile_processors()
    profiler = Profiler(AnalyticMobileBackend(procs))

    def make_analyzer(processors, prescreen):
        return StaticAnalyzer(
            scenario, processors, profiler, PAPER_COMM_MODEL,
            AnalyzerConfig(
                prescreen=prescreen,
                ga=GAConfig(pop_size=16, max_generations=10,
                            min_generations=5, seed=7, prescreen=prescreen),
            ),
        )

    def front_keys(result):
        return sorted(s.key() for s in result.pareto)

    # 1. unconstrained: the pre-screen must be a no-op
    t0 = time.perf_counter()
    off = make_analyzer(procs, False).run_ga()
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    on = make_analyzer(procs, True).run_ga()
    t_on = time.perf_counter() - t0
    fronts_identical = (front_keys(off) == front_keys(on)
                        and off.evaluations == on.evaluations)
    assert fronts_identical, "prescreen perturbed an unconstrained GA run"
    assert on.prescreen_stats["pruned"] == 0
    emit("prescreen.unconstrained.off", t_off * 1e6,
         f"evals={off.evaluations}")
    emit("prescreen.unconstrained.on", t_on * 1e6,
         f"evals={on.evaluations};checked={on.prescreen_stats['checked']};"
         f"fronts_identical={fronts_identical}")

    # 2. NPU memory budget below what whole-model-resident schedules need:
    # chromosomes packing everything onto the NPU provably OOM (SL020)
    tight_procs = [
        dataclasses.replace(p, memory_capacity=20480) if p.kind == "npu"
        else p
        for p in procs
    ]
    t0 = time.perf_counter()
    c_off = make_analyzer(tight_procs, False).run_ga()
    tc_off = time.perf_counter() - t0
    an_c = make_analyzer(tight_procs, True)
    linter = an_c.linter()
    pruned_solutions = []
    orig_prescreen = an_c.prescreen_objectives

    def recording_prescreen(sol):
        obj = orig_prescreen(sol)
        if obj is not None:
            pruned_solutions.append(sol)
        return obj

    an_c.prescreen_objectives = recording_prescreen
    t0 = time.perf_counter()
    c_on = an_c.run_ga()
    tc_on = time.perf_counter() - t0
    stats = c_on.prescreen_stats
    # adversarial ground truth: every pruned chromosome must fail to
    # provision through a real capacity-bounded TensorPool
    false_prunes = 0
    for sol in pruned_solutions:
        ok = provision_memory(linter.builder.decode(sol),
                              linter.capacities())
        if all(ok.values()):
            false_prunes += 1
    avoided_fraction = (stats["simulations_avoided"]
                        / max(1, stats["simulations_avoided"]
                              + c_on.evaluations))
    emit("prescreen.constrained.off", tc_off * 1e6,
         f"evals={c_off.evaluations}")
    emit("prescreen.constrained.on", tc_on * 1e6,
         f"evals={c_on.evaluations};pruned={stats['pruned']};"
         f"checked={stats['checked']}")
    emit("prescreen.simulations_avoided", 0.0,
         f"{stats['simulations_avoided']} ({avoided_fraction * 100:.1f}% "
         f"of GA evaluations)")
    emit("prescreen.false_prunes", 0.0, f"{false_prunes} (gate: 0)")

    # 3. α*-probe skipping: the proven deadline floor answers probes below
    # it as 0.0 without simulating. Two regimes: a feasible front solution
    # (floor below the probe path — searches must be identical), and an
    # overloaded regime (periods ÷ 8: the CPU seed's floor clears the whole
    # α lattice, so α* = inf is proven without a single simulation).
    def count_probes(an, sol):
        calls = 0
        orig_score = an.score

        def counting_score(s, alpha, **kw):
            nonlocal calls
            calls += 1
            return orig_score(s, alpha, **kw)

        an.score = counting_score
        sat = an.saturation(sol)
        an.score = orig_score
        return calls, sat.alpha_star

    probe_sol = sorted(off.pareto, key=lambda s: s.key())[0]
    counts = {}
    alpha_stars = {}
    overload = {}
    for label, prescreen in (("off", False), ("on", True)):
        an = make_analyzer(procs, prescreen)
        counts[label], alpha_stars[label] = count_probes(an, probe_sol)
        an_tight = make_analyzer(procs, prescreen)
        # overloaded regime: same scenario at 8x the request rate
        an_tight.base_periods = [p / 8.0 for p in an_tight.base_periods]
        overload[label] = count_probes(
            an_tight, an_tight.factory.seeded_solution(0))
    assert alpha_stars["off"] == alpha_stars["on"],\
        "probe skipping changed alpha*"
    assert overload["off"][1] == overload["on"][1] == float("inf")
    emit("prescreen.alpha_probes.front", 0.0,
         f"off={counts['off']};on={counts['on']};"
         f"alpha_star={alpha_stars['on']};identical=True")
    emit("prescreen.alpha_probes.overloaded", 0.0,
         f"off={overload['off'][0]};on={overload['on'][0]};"
         f"alpha_star=inf (proven without simulation)")

    if getattr(args, "json", False):
        record = {
            "timestamp": time.time(),
            "unconstrained": {
                "evals_off": off.evaluations,
                "evals_on": on.evaluations,
                "checked": on.prescreen_stats["checked"],
                "fronts_identical": fronts_identical,
            },
            "constrained": {
                "evals_off": c_off.evaluations,
                "evals_on": c_on.evaluations,
                "prescreen_stats": dict(stats),
                "prescreen_false_prunes": false_prunes,
                "simulations_avoided_fraction": avoided_fraction,
            },
            "alpha_probes": {
                "front_off": counts["off"],
                "front_on": counts["on"],
                "front_alpha_star": alpha_stars["on"],
                "overloaded_off": overload["off"][0],
                "overloaded_on": overload["on"][0],
            },
        }
        out = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_prescreen.json")
        with open(os.path.abspath(out), "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        emit("prescreen.json", 0.0, os.path.abspath(out))


SECTIONS = {
    "table2": bench_table2,
    "table3": bench_table3,
    "table4": bench_table4,
    "fig5": bench_fig5,
    "fig12": bench_fig12,
    "fig15": bench_fig15,
    "table5": bench_table5,
    "simspeed": bench_simspeed,
    "prescreen": bench_prescreen,
    "conformance": bench_conformance,
    "sweep": bench_sweep,
    "arrivals": bench_arrivals,
    "faults": bench_faults,
    "roofline": bench_roofline,
    "kernels": bench_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("section", nargs="?", choices=sorted(SECTIONS),
                    default=None, help="run just this section")
    ap.add_argument("--only", choices=sorted(SECTIONS), default=None)
    ap.add_argument("--full", action="store_true",
                    help="all 10 random scenarios per group setting")
    ap.add_argument("--smoke", action="store_true",
                    help="sweep section: 2 scenarios, tiny GA (<1 min)")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_simspeed.json (simspeed section)")
    args = ap.parse_args()
    if args.section and args.only and args.section != args.only:
        ap.error(f"conflicting sections: positional {args.section!r} "
                 f"vs --only {args.only!r}")
    selected = args.section or args.only
    print("name,us_per_call,derived")
    for name, fn in SECTIONS.items():
        if selected and name != selected:
            continue
        t0 = time.perf_counter()
        fn(args)
        emit(f"section.{name}.total", (time.perf_counter() - t0) * 1e6)


if __name__ == "__main__":
    main()
