"""Unit tests for the pluggable arrival-process layer (core/arrivals.py).

Engine-tier parity under randomized arrival specs lives in
``tests/test_batchsim_properties.py`` and ``tests/test_golden_traces.py``;
this file covers the generator's own contract: determinism, the periodic
byte-identity guarantee, the strictly-increasing realized-event-time
invariant, JSON round-trips and distribution sanity.
"""
import json
import random
import statistics

import pytest

from repro.core import (
    ArrivalSpec,
    absolute_deadlines,
    arrival_horizon,
    draw_arrivals,
)


# -- spec construction / serialization ---------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="unknown arrival kind"):
        ArrivalSpec(kind="bursty")
    with pytest.raises(ValueError, match="unknown jitter distribution"):
        ArrivalSpec(kind="jittered", distribution="pareto")
    with pytest.raises(ValueError, match="explicit timestamps"):
        ArrivalSpec(kind="trace")


def test_spec_canonicalization_and_equality():
    # fields the kind does not consume are canonicalized, so specs compare
    # (and hash, and cache-key) by what they actually mean
    assert ArrivalSpec(kind="poisson", jitter=0.4, seed=1) == \
        ArrivalSpec(kind="poisson", jitter=0.9, seed=1)
    assert ArrivalSpec(kind="jittered", jitter=0.2, sigma=0.7) == \
        ArrivalSpec(kind="jittered", jitter=0.2, sigma=0.1)  # uniform: no σ
    assert ArrivalSpec(kind="jittered", jitter=0.2) != \
        ArrivalSpec(kind="jittered", jitter=0.3)
    a = ArrivalSpec(kind="trace", trace=[[0.0, 1.0]], seed=5)
    assert a.trace == ((0.0, 1.0),)  # normalized to tuples -> hashable
    hash(a)
    assert a.key() != ArrivalSpec(kind="poisson", seed=5).key()


@pytest.mark.parametrize("spec", [
    ArrivalSpec(),
    ArrivalSpec(kind="jittered", jitter=0.3, seed=2),
    ArrivalSpec(kind="jittered", jitter=0.2, distribution="lognormal",
                sigma=0.4, seed=3),
    ArrivalSpec(kind="poisson", seed=9),
    ArrivalSpec(kind="trace", trace=((0.0, 0.004, 0.005), (0.001,))),
])
def test_spec_json_roundtrip(spec):
    wire = json.loads(json.dumps(spec.to_json()))
    assert ArrivalSpec.from_json(wire) == spec


# -- draw_arrivals contract ---------------------------------------------------

def test_periodic_is_exactly_rid_times_period():
    """The default path must be byte-identical to the pre-arrival engines,
    which computed ``arrival = rid * period`` inline."""
    periods = [0.005, 0.0037]
    for spec in (None, ArrivalSpec()):
        tables = draw_arrivals(spec, periods, 9)
        for gid, period in enumerate(periods):
            assert tables[gid] == [rid * period for rid in range(9)]


def test_draw_is_deterministic_and_seeded():
    spec = ArrivalSpec(kind="poisson", seed=11)
    a = draw_arrivals(spec, [0.004, 0.006], 12)
    b = draw_arrivals(spec, [0.004, 0.006], 12)
    assert a == b
    c = draw_arrivals(ArrivalSpec(kind="poisson", seed=12), [0.004, 0.006], 12)
    assert a != c
    # group-major draw order: a one-group draw equals the first group of a
    # two-group draw (prefix property of the shared stream)
    solo = draw_arrivals(spec, [0.004], 12)
    assert solo[0] == a[0]


@pytest.mark.parametrize("spec", [
    ArrivalSpec(kind="jittered", jitter=0.9, seed=4),
    ArrivalSpec(kind="jittered", jitter=2.5, seed=4),  # wider than Φ
    ArrivalSpec(kind="jittered", distribution="lognormal", jitter=0.8,
                sigma=1.0, seed=4),
    ArrivalSpec(kind="poisson", seed=4),
    ArrivalSpec(kind="trace", trace=((0.003, 0.001, 0.001, 0.002),
                                     (0.0, 0.0, 0.0))),
])
def test_realized_event_chain_strictly_increases(spec):
    """The invariant every engine's float recurrence relies on: arrivals
    are non-negative and ``t_e(i) = t_e(i-1) + (a_i - t_e(i-1))`` strictly
    increases, even for regressing/tied raw timestamps."""
    for tab in draw_arrivals(spec, [0.004, 0.002], 30):
        assert tab[0] >= 0.0
        te = tab[0]
        for a in tab[1:]:
            assert a > te
            nxt = te + (a - te)
            assert nxt > te
            te = nxt


def test_poisson_mean_interarrival_matches_period():
    phi = 0.01
    tab = draw_arrivals(ArrivalSpec(kind="poisson", seed=0), [phi], 4000)[0]
    gaps = [b - a for a, b in zip(tab, tab[1:])]
    assert statistics.mean(gaps) == pytest.approx(phi, rel=0.1)
    # bursty: the gap distribution has exponential spread, not a spike
    assert statistics.pstdev(gaps) == pytest.approx(phi, rel=0.2)
    assert tab[0] == 0.0


def test_uniform_jitter_bounded():
    phi = 0.01
    j = 0.3
    spec = ArrivalSpec(kind="jittered", jitter=j, seed=1)
    tab = draw_arrivals(spec, [phi], 500)[0]
    offsets = [t - i * phi for i, t in enumerate(tab)]
    assert max(abs(o) for o in offsets[1:]) <= j * phi * (1 + 1e-12)
    assert min(offsets[1:]) < 0 < max(offsets[1:])  # two-sided


def test_lognormal_jitter_positive_delay():
    spec = ArrivalSpec(kind="jittered", jitter=0.5,
                       distribution="lognormal", sigma=0.4, seed=2)
    tab = draw_arrivals(spec, [0.01], 200)[0]
    offsets = [t - i * 0.01 for i, t in enumerate(tab)]
    assert all(o >= 0.0 for o in offsets)
    assert statistics.mean(offsets) == pytest.approx(0.5 * 0.01, rel=0.25)


def test_trace_extension_and_truncation():
    spec = ArrivalSpec(kind="trace", trace=((0.0, 0.005), ()))
    tabs = draw_arrivals(spec, [0.01, 0.02], 4)
    # short trace extends periodically past its last timestamp
    assert tabs[0] == [0.0, 0.005, 0.005 + 0.01, 0.005 + 0.01 + 0.01]
    # empty group trace degenerates to the periodic lattice from t=0
    assert tabs[1][0] == 0.0
    assert all(b > a for a, b in zip(tabs[1], tabs[1][1:]))
    long = ArrivalSpec(kind="trace", trace=((0.0, 0.1, 0.2, 0.3, 0.4),))
    assert len(draw_arrivals(long, [0.01], 2)[0]) == 2


# -- deadlines ----------------------------------------------------------------

def test_absolute_deadlines_match_relative_check():
    """``absolute_deadlines`` is the explicit form of the scoring contract:
    last_finish ≤ arrival_i + Φ  ⟺  arrival-relative makespan ≤ Φ."""
    phi = 0.01
    tab = draw_arrivals(ArrivalSpec(kind="poisson", seed=3), [phi], 50)[0]
    deadlines = absolute_deadlines(tab, phi)
    assert deadlines == [a + phi for a in tab]
    rng = random.Random(0)
    for arrival, deadline in zip(tab, deadlines):
        last_finish = arrival + rng.uniform(0.0, 2.0 * phi)
        makespan = last_finish - arrival
        assert (last_finish <= deadline) == (makespan <= phi)


# -- horizon ------------------------------------------------------------------

def test_horizon_periodic_matches_historical_expression():
    periods = [0.005, 0.0037]
    nr = 12
    tables = draw_arrivals(None, periods, nr)
    assert arrival_horizon(tables, periods, nr) == \
        max((nr + 2) * max(periods) * 4.0, 1.0)


def test_horizon_extends_past_late_arrivals():
    periods = [0.001]
    nr = 3
    spec = ArrivalSpec(kind="trace", trace=((0.0, 0.5, 9.0),))
    tables = draw_arrivals(spec, periods, nr)
    h = arrival_horizon(tables, periods, nr)
    assert h >= 9.0 + 8 * 0.001
    # but never shrinks below the periodic expression
    assert h >= max((nr + 2) * max(periods) * 4.0, 1.0)
