"""Compiled-tier conformance: golden traces, differentials, fallbacks.

The jitted ``jax.lax.while_loop`` core (:mod:`repro.core.batchsim_compiled`)
is contractually *tolerance-bounded* against the bit-exact tiers:
``COMPILED_REL_TOL`` relative / ``COMPILED_ABS_TOL`` absolute per reported
float, integer fields (done counts) exact, and ``inf`` agreeing with
``inf``. These tests replay every committed golden trace and a
differential sweep (clean / measured / non-periodic arrivals / fault
ensembles) through the compiled tier against the numpy and fastsim tiers,
and pin the transparent-fallback contract of
``run_batch(engine="compiled")``. In practice the observed diff is exactly
0.0 on x86-64 (the tolerance is the contract, the zero is the
measurement); ``last_stats`` is asserted on so a silent numpy fallback
cannot masquerade as compiled coverage.
"""
import json
import math
import os
import random

import pytest

jax = pytest.importorskip("jax")

from repro.core import (
    COMPILED_ABS_TOL,
    COMPILED_REL_TOL,
    BatchLane,
    BatchSimulator,
    FastSimulator,
    FaultSpec,
    NoiseModel,
    PAPER_COMM_MODEL,
    SolutionFactory,
    build_spec,
    decode_solution,
    run_batch,
    run_batch_compiled,
)
import repro.core.batchsim_compiled as bsc
from test_batchsim_properties import (
    PROCS,
    PROFILER,
    _random_arrival,
    _random_problem,
)
from test_golden_traces import (
    GOLDEN_DIR,
    SCENARIOS,
    _solution,
)
from test_golden_traces import PROCS as GPROCS
from test_golden_traces import PROFILER as GPROFILER


def _close(a, b):
    """The documented compiled-tier tolerance, inf-aware."""
    if math.isinf(a) or math.isinf(b):
        return math.isinf(a) and math.isinf(b)
    return abs(a - b) <= COMPILED_ABS_TOL + COMPILED_REL_TOL * max(
        abs(a), abs(b))


def _assert_lane_close(ref_res, comp_res, tag):
    """Per-lane SimResult comparison under the tolerance contract."""
    assert ref_res.busy_time.keys() == comp_res.busy_time.keys(), tag
    for pid in ref_res.busy_time:
        assert _close(ref_res.busy_time[pid], comp_res.busy_time[pid]), (
            tag, "busy", pid)
    assert len(ref_res.requests) == len(comp_res.requests), tag
    for qa, qb in zip(ref_res.requests, comp_res.requests):
        assert qa.done_tasks == qb.done_tasks, (tag, qa, qb)
        assert qa.total_tasks == qb.total_tasks, (tag, qa, qb)
        assert _close(qa.arrival, qb.arrival), (tag, qa, qb)
        assert _close(qa.first_start, qb.first_start), (tag, qa, qb)
        assert _close(qa.last_finish, qb.last_finish), (tag, qa, qb)
        assert _close(qa.makespan, qb.makespan), (tag, qa, qb)


# -- golden traces ---------------------------------------------------------


def _golden_lane(name):
    (nets_fn, groups, periods, nr, noise_seed, dispatch, pin, arrivals,
     faults) = SCENARIOS[name]
    nets = nets_fn()
    sol = _solution(nets, seed=11, pin=pin)
    spec = build_spec(decode_solution(sol, nets), GPROCS, GPROFILER,
                      PAPER_COMM_MODEL)
    noise = NoiseModel(seed=noise_seed) if noise_seed is not None else None
    lane = BatchLane(spec=spec, periods=periods, num_requests=nr,
                     noise=noise, dispatch_overhead=dispatch,
                     arrivals=arrivals, faults=faults)
    return lane, groups


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_compiled_reproduces_golden_trace(name):
    """Every committed golden trace replays through the compiled tier
    within the documented tolerance (done counts exact, inf == inf)."""
    lane, groups = _golden_lane(name)
    comp = run_batch_compiled([lane], groups, GPROCS)
    assert comp is not None
    assert bsc.last_stats["fallback"] is False, bsc.last_stats
    res = comp.result(0)
    with open(os.path.join(GOLDEN_DIR, f"{name}.json")) as f:
        golden = json.load(f)
    assert _close(res.horizon, golden["horizon"])
    assert {str(p) for p in res.busy_time} == set(golden["busy_time"])
    for pid, t in res.busy_time.items():
        assert _close(t, golden["busy_time"][str(pid)]), ("busy", pid)
    assert len(res.requests) == len(golden["requests"])
    for r, row in zip(res.requests, golden["requests"]):
        group, request, arrival, first_start, last_finish, done, total = row
        assert (r.group, r.request) == (group, request)
        assert r.done_tasks == done and r.total_tasks == total
        assert _close(r.arrival, arrival)
        assert _close(r.first_start, first_start)
        assert _close(r.last_finish, last_finish)
    for r, gm in zip(res.requests, golden["makespans"]):
        if gm is None:
            assert math.isinf(r.makespan)
        else:
            assert _close(r.makespan, gm)


# -- differential sweep: compiled vs numpy vs fastsim ----------------------


def _make_lanes(rng, n_lanes, measured, arrivals_on, faults_on):
    nets, groups, periods = _random_problem(rng)
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(rng.randrange(1 << 30)),
                          cut_prob=rng.uniform(0.1, 0.5))
    lanes = []
    for _ in range(n_lanes):
        sol = fac.random_solution()
        spec = build_spec(decode_solution(sol, nets), PROCS, PROFILER,
                          PAPER_COMM_MODEL)
        nr = rng.randint(3, 6)
        noise = NoiseModel(seed=rng.randrange(1 << 16)) if measured else None
        arr = (_random_arrival(rng, groups, periods, nr)
               if arrivals_on else None)
        faults = None
        if faults_on and rng.random() < 0.7:
            faults = FaultSpec(
                dropouts=((rng.randrange(len(PROCS)), rng.uniform(0, 0.01),
                           None if rng.random() < 0.5
                           else rng.uniform(0.001, 0.01)),),
                throttles=((rng.randrange(len(PROCS)), 0.0,
                            rng.uniform(0.002, 0.02),
                            rng.uniform(1.5, 4.0)),),
                straggler_prob=rng.choice([0.0, 0.2, 0.5]),
                straggler_shape=1.5,
                seed=rng.randrange(1 << 16),
            )
        lanes.append(BatchLane(
            spec=spec, periods=periods, num_requests=nr, noise=noise,
            dispatch_overhead=150e-6 if measured else 0.0,
            arrivals=arr, faults=faults))
    return lanes, groups


def _compare_three_tiers(tag, lanes, groups):
    ref = BatchSimulator(lanes, groups, PROCS).run()
    comp = run_batch_compiled(lanes, groups, PROCS)
    assert comp is not None, (tag, bsc.last_stats)
    assert bsc.last_stats["fallback"] is False, (tag, bsc.last_stats)
    for i, lane in enumerate(lanes):
        _assert_lane_close(ref.result(i), comp.result(i), (tag, i))
        fast = FastSimulator(
            lane.spec, groups=groups, periods=lane.periods,
            num_requests=lane.num_requests, noise=lane.noise,
            dispatch_overhead=lane.dispatch_overhead,
            arrivals=lane.arrivals, faults=lane.faults,
        ).run()
        _assert_lane_close(fast, comp.result(i), (tag, i, "fastsim"))


@pytest.mark.parametrize("seed", [0, 1])
def test_compiled_differential_clean(seed):
    rng = random.Random(5000 + seed)
    lanes, groups = _make_lanes(rng, 4, measured=False, arrivals_on=False,
                                faults_on=False)
    _compare_three_tiers(f"clean-{seed}", lanes, groups)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_compiled_differential_arrivals(seed):
    """Jittered / poisson / trace arrivals + noise + dispatch tokens."""
    rng = random.Random(6000 + seed)
    lanes, groups = _make_lanes(rng, 4, measured=True, arrivals_on=True,
                                faults_on=False)
    _compare_three_tiers(f"arrivals-{seed}", lanes, groups)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_compiled_differential_faults(seed):
    """Dropout + throttle + straggler ensembles on top of noise."""
    rng = random.Random(7000 + seed)
    lanes, groups = _make_lanes(rng, 4, measured=True, arrivals_on=True,
                                faults_on=True)
    _compare_three_tiers(f"faults-{seed}", lanes, groups)


def test_compiled_overload_inf_parity():
    """Deep-queue overload: dropped requests (inf makespans) and partial
    done counts agree with the numpy tier — the FIFO rings must not
    overflow at the host-computed capacity bound."""
    rng = random.Random(99)
    nets, groups, periods = _random_problem(rng)
    periods = tuple(p * 0.01 for p in periods)  # ~100x arrival rate
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(2), cut_prob=0.3)
    lanes = []
    for _ in range(6):
        sol = fac.random_solution()
        spec = build_spec(decode_solution(sol, nets), PROCS, PROFILER,
                          PAPER_COMM_MODEL)
        lanes.append(BatchLane(spec=spec, periods=periods, num_requests=20,
                               dispatch_overhead=150e-6))
    ref = BatchSimulator(lanes, groups, PROCS).run()
    comp = run_batch_compiled(lanes, groups, PROCS)
    assert comp is not None
    assert bsc.last_stats["fallback"] is False, bsc.last_stats
    dropped = 0
    for i in range(len(lanes)):
        _assert_lane_close(ref.result(i), comp.result(i), ("overload", i))
        dropped += sum(math.isinf(m) for m in ref.makespans(i))
    assert dropped, "overload scenario dropped no requests"


# -- fallback contract -----------------------------------------------------


def test_run_batch_compiled_collect_tasks_falls_back_bitexact():
    """engine="compiled" with collect_tasks routes to numpy (task traces
    are python-side by design) — results bit-identical, not just close."""
    rng = random.Random(31)
    lanes, groups = _make_lanes(rng, 3, measured=True, arrivals_on=False,
                                faults_on=False)
    ref = run_batch(lanes, groups, PROCS, collect_tasks=True)
    via = run_batch(lanes, groups, PROCS, collect_tasks=True,
                    engine="compiled")
    for i in range(len(lanes)):
        assert ref.makespans(i) == via.makespans(i)
        assert ref.result(i).busy_time == via.result(i).busy_time


def test_run_batch_compiled_queue_bound_fallback():
    """A workload whose released-task bound exceeds QUEUE_CAP_MAX is
    declined before compilation; run_batch reruns it on numpy."""
    rng = random.Random(32)
    lanes, groups = _make_lanes(rng, 2, measured=False, arrivals_on=False,
                                faults_on=False)
    big = [BatchLane(spec=ln.spec, periods=ln.periods, num_requests=4000)
           for ln in lanes]
    assert run_batch_compiled(big, groups, PROCS) is None
    assert bsc.last_stats["fallback"] is True
    assert bsc.last_stats["reason"] == "queue-bound"


def test_run_batch_unknown_engine_rejected():
    rng = random.Random(33)
    lanes, groups = _make_lanes(rng, 1, measured=False, arrivals_on=False,
                                faults_on=False)
    with pytest.raises(ValueError, match="unknown batch engine"):
        run_batch(lanes, groups, PROCS, engine="bogus")


def test_objectives_batch_compiled_engine_close_to_scalar():
    """Analyzer integration: cfg.batch_engine="compiled" yields objectives
    within the documented tolerance of the scalar loop."""
    from test_ga_determinism import _analyzer

    an = _analyzer()
    an.cfg.batch_engine = "compiled"
    an.factory.rng = random.Random(77)
    sols = [an.factory.random_solution() for _ in range(6)]
    batch = an.objectives_batch(sols)
    assert bsc.last_stats["fallback"] is False, bsc.last_stats
    scalar = [_analyzer().objectives(s) for s in sols]
    for b, s in zip(batch, scalar):
        assert len(b) == len(s)
        for x, y in zip(b, s):
            assert _close(x, y)
