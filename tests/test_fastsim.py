"""Fast-path evaluation engine: fastsim parity, decode cache, bisection α*."""
import math
import random


from repro.core import (
    AnalyzerConfig,
    FastSimulator,
    NoiseModel,
    PAPER_COMM_MODEL,
    Profiler,
    RuntimeSimulator,
    SolutionFactory,
    StaticAnalyzer,
    branching_graph,
    build_scenario,
    build_spec,
    chain_graph,
    decode_solution,
    mobile_processors,
    saturation_multiplier,
    saturation_multiplier_bisect,
)
from repro.core.profiler import AnalyticMobileBackend


def _problem():
    """Deterministic multi-group scenario: 4 nets (chains + diamonds), 2 groups."""
    nets = [
        chain_graph("a", [("conv", 4e6, 1000, 4000)] * 5),
        branching_graph(
            "b", [("conv", 2e6, 800, 2000)] * 4,
            [(0, 1), (0, 2), (1, 3), (2, 3)],
        ),
        chain_graph("c", [("fc", 8e6, 2000, 8000)] * 3),
        branching_graph(
            "d", [("conv", 3e6, 500, 1500)] * 5,
            [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)],
        ),
    ]
    procs = mobile_processors()
    prof = Profiler(AnalyticMobileBackend(procs))
    groups = [[0, 1], [2, 3]]
    periods = [0.004, 0.006]
    return nets, procs, prof, groups, periods


def _solutions(nets, num_processors, count=6, seed=11):
    fac = SolutionFactory(nets, num_processors=num_processors,
                          rng=random.Random(seed), cut_prob=0.35)
    return [fac.random_solution() for _ in range(count)]


def _run_pair(sol, nets, procs, prof, groups, periods, **kw):
    placed = decode_solution(sol, nets)
    ref = RuntimeSimulator(
        placed=placed, processors=procs, profiler=prof,
        comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods, **kw,
    ).run()
    fast = FastSimulator.from_placed(
        placed, procs, prof, PAPER_COMM_MODEL, groups, periods,
        input_home_pid=kw.get("input_home_pid", 0),
        num_requests=kw.get("num_requests", 20),
        overlap_comm=kw.get("overlap_comm", False),
        noise=kw.get("noise"),
        dispatch_overhead=kw.get("dispatch_overhead", 0.0),
        dispatch_pid=kw.get("dispatch_pid", 0),
    ).run()
    return ref, fast


def _assert_identical(ref, fast):
    # requests: same order, bit-identical record fields and makespans
    assert len(ref.requests) == len(fast.requests)
    for a, b in zip(ref.requests, fast.requests):
        assert (a.group, a.request) == (b.group, b.request)
        assert a.arrival == b.arrival
        assert a.first_start == b.first_start
        assert a.last_finish == b.last_finish
        assert a.done_tasks == b.done_tasks
        assert a.total_tasks == b.total_tasks
        assert a.makespan == b.makespan or (
            math.isinf(a.makespan) and math.isinf(b.makespan)
        )
    # tasks: same release/start/finish trace, same costs, same placement
    assert len(ref.tasks) == len(fast.tasks)
    for a, b in zip(ref.tasks, fast.tasks):
        assert (a.group, a.request, a.network, a.sg_index, a.processor) == (
            b.group, b.request, b.network, b.sg_index, b.processor
        )
        assert a.released == b.released
        assert a.started == b.started
        assert a.finished == b.finished
        assert a.comm_time == b.comm_time
        assert a.quant_time == b.quant_time
        assert a.exec_time == b.exec_time
    assert ref.busy_time == fast.busy_time
    assert ref.horizon == fast.horizon


def test_parity_clean():
    nets, procs, prof, groups, periods = _problem()
    for sol in _solutions(nets, len(procs)):
        ref, fast = _run_pair(sol, nets, procs, prof, groups, periods,
                              num_requests=10)
        _assert_identical(ref, fast)


def test_parity_noise_and_dispatch():
    nets, procs, prof, groups, periods = _problem()
    for seed, sol in enumerate(_solutions(nets, len(procs), count=4, seed=23)):
        ref, fast = _run_pair(
            sol, nets, procs, prof, groups, periods,
            num_requests=8, noise=NoiseModel(seed=seed),
            dispatch_overhead=150e-6, dispatch_pid=0,
        )
        _assert_identical(ref, fast)


def test_parity_overlap_comm_and_input_home():
    nets, procs, prof, groups, periods = _problem()
    sol = _solutions(nets, len(procs), count=1, seed=5)[0]
    ref, fast = _run_pair(sol, nets, procs, prof, groups, periods,
                          num_requests=6, overlap_comm=True, input_home_pid=2)
    _assert_identical(ref, fast)


def test_parity_overloaded_dropped_requests():
    # tight periods force unfinished requests at the horizon (inf makespans)
    nets, procs, prof, groups, _ = _problem()
    sol = _solutions(nets, len(procs), count=1, seed=9)[0]
    ref, fast = _run_pair(sol, nets, procs, prof, groups, [1e-4, 1e-4],
                          num_requests=400)
    assert any(math.isinf(m) for m in ref.makespans())
    _assert_identical(ref, fast)


def test_collect_tasks_off_keeps_request_results():
    nets, procs, prof, groups, periods = _problem()
    sol = _solutions(nets, len(procs), count=1)[0]
    placed = decode_solution(sol, nets)
    spec = build_spec(placed, procs, prof, PAPER_COMM_MODEL)
    kw = dict(groups=groups, periods=periods, num_requests=6,
              noise=NoiseModel(seed=3), dispatch_overhead=150e-6)
    with_tasks = FastSimulator(spec, **kw).run(collect_tasks=True)
    without = FastSimulator(spec, **kw).run(collect_tasks=False)
    assert without.tasks == []
    assert with_tasks.makespans() == without.makespans()
    assert with_tasks.busy_time == without.busy_time


# -- analyzer integration ----------------------------------------------------

def _analyzer(engine="fast", **cfg_kw):
    nets, procs, prof, groups, _ = _problem()
    scen = build_scenario(
        "fastsim-test",
        [["a", "b"], ["c", "d"]],
        {g.name: g for g in nets},
    )
    cfg = AnalyzerConfig(engine=engine, **cfg_kw)
    return StaticAnalyzer(scen, procs, prof, PAPER_COMM_MODEL, cfg)


def test_analyzer_engines_agree():
    an = _analyzer()
    sol = an.factory.random_solution()
    for measured in (False, True):
        fast = an.simulate(sol, 1.0, 8, measured=measured, seed=2, engine="fast")
        ref = an.simulate(sol, 1.0, 8, measured=measured, seed=2,
                          engine="reference")
        assert fast.makespans() == ref.makespans()
        assert an.objectives(sol, engine="fast") == an.objectives(
            sol, engine="reference")


def test_decode_cache_reused_across_alpha_and_seed():
    an = _analyzer()
    sol = an.factory.random_solution()
    an.simulate(sol, 1.0, 6)
    assert an.spec_cache_misses == 1
    an.simulate(sol, 2.0, 6)
    an.simulate(sol, 2.0, 12, measured=True, seed=7)
    assert an.spec_cache_misses == 1
    assert an.spec_cache_hits == 2
    other = an.factory.random_solution()
    an.simulate(other, 1.0, 6)
    assert an.spec_cache_misses == 2


def test_decode_cache_lru_bound():
    an = _analyzer(decode_cache_size=2)
    sols = [an.factory.random_solution() for _ in range(4)]
    for s in sols:
        an.simulate(s, 1.0, 4)
    assert len(an._spec_cache) == 2


# -- bisection α*-search -----------------------------------------------------

def _grid_vs_bisect(evaluate):
    grid = saturation_multiplier(evaluate)
    bis = saturation_multiplier_bisect(evaluate)
    return grid, bis


def test_bisect_matches_grid_monotone():
    for mid in (0.3, 1.17, 2.5, 5.95):
        def evaluate(a, _mid=mid):
            return 1.0 / (1.0 + math.exp(-40.0 * (a - _mid)))

        grid, bis = _grid_vs_bisect(evaluate)
        assert bis.alpha_star == grid.alpha_star
        # grid scans 117 points; bisection needs only a handful
        assert len(bis.scores) <= 20


def test_bisect_never_saturates():
    grid, bis = _grid_vs_bisect(lambda a: 0.5)
    assert math.isinf(grid.alpha_star) and math.isinf(bis.alpha_star)
    assert len(bis.scores) == 1  # one probe at the top of the range


def test_bisect_confirmation_catches_dip():
    # saturated from 1.0 except a contention dip at [1.05, 1.1]: the "stays
    # saturated" semantics means α* must land above the dip, like the grid.
    def evaluate(a):
        if a < 1.0:
            return 0.2
        if 1.05 <= a <= 1.1:
            return 0.9
        return 1.0

    grid, bis = _grid_vs_bisect(evaluate)
    assert grid.alpha_star == bis.alpha_star == 1.15


def test_bisect_on_analyzer_matches_grid():
    an = _analyzer()
    sol = an.factory.seeded_solution(2)  # everything on the NPU: well-behaved
    grid = an.saturation(sol, mode="grid")
    bis = an.saturation(sol, mode="bisect")
    assert bis.alpha_star == grid.alpha_star
    assert len(bis.scores) < len(grid.scores) / 4


def test_nsga_vectorized_matches_reference():
    # differential test: numpy NSGA machinery vs the seed's pure-Python path.
    # The non-dominated sort is exact arithmetic → must agree front-for-front.
    # Niching involves fp distance ties, so for selection we check the
    # front-rank composition rather than identical index picks.
    from repro.core.nsga import fast_non_dominated_sort, nsga3_select

    rng = random.Random(0)
    for n_obj in (2, 4, 6):
        fits = [
            [rng.choice([rng.uniform(0, 1), rng.uniform(0, 1), 1e6])
             for _ in range(n_obj)]
            for _ in range(40)
        ]
        fronts_v = fast_non_dominated_sort(fits, vectorized=True)
        fronts_p = fast_non_dominated_sort(fits, vectorized=False)
        assert fronts_v == fronts_p
        rank = {i: r for r, front in enumerate(fronts_v) for i in front}
        sel_v = nsga3_select(fits, 15, rng=random.Random(1), vectorized=True)
        sel_p = nsga3_select(fits, 15, rng=random.Random(1), vectorized=False)
        assert len(sel_v) == len(sel_p) == 15
        assert sorted(rank[i] for i in sel_v) == sorted(rank[i] for i in sel_p)


def test_ga_oracle_drift_zero():
    from repro.core import GAConfig
    an = _analyzer(ga=GAConfig(pop_size=6, max_generations=3,
                               min_generations=1, oracle_interval=1, seed=4))
    res = an.run_ga()
    assert res.oracle_drift, "oracle checks should have run"
    assert all(d == 0.0 for _, d in res.oracle_drift)
