"""XRBench scoring + communication cost model."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    PAPER_COMM_MODEL,
    PiecewiseLinearCommModel,
    microbenchmark_host,
    percentile,
    qoe_score,
    quantization_cost,
    rt_score,
    saturation_multiplier,
    scenario_score,
)
from repro.core.comm import MIB


def test_qoe():
    assert qoe_score([1, 2, 3, 4], deadline=2.5) == 0.5
    assert qoe_score([], 1.0) == 0.0


def test_rt_score_limits():
    assert rt_score(0.0, 1.0) > 0.999
    assert rt_score(1.0, 1.0) == pytest.approx(0.5)
    assert rt_score(10.0, 1.0) < 1e-6
    assert rt_score(float("inf"), 1.0) == 0.0


def test_rt_score_scale_invariance():
    # deadline-normalized: same ratio -> same score at any time scale
    assert rt_score(0.010, 0.020) == pytest.approx(rt_score(10.0, 20.0))


def test_scenario_score_perfect_and_zero():
    assert scenario_score([[0.1] * 5], [1.0]) > 0.995
    assert scenario_score([[10.0] * 5], [1.0]) < 1e-4
    # two groups, one perfect one failed -> 0.5-ish
    s = scenario_score([[0.1] * 5, [10.0] * 5], [1.0, 1.0])
    assert 0.45 < s < 0.55


def test_percentile():
    vals = list(range(1, 11))
    assert percentile(vals, 0) == 1
    assert percentile(vals, 100) == 10
    assert percentile(vals, 50) == pytest.approx(5.5)
    assert percentile(vals, 90) == pytest.approx(9.1)


def test_percentile_inf_safe():
    # odd length, q=50 lands exactly on the middle sample: must not become
    # NaN via vals[lo] + 0.0 * inf (unsaturated alpha* candidate sets)
    inf = float("inf")
    assert percentile([1.0, 2.0, inf], 50.0) == 2.0
    assert percentile([1.0, inf, inf], 100.0) == inf
    assert percentile([inf], 50.0) == inf
    # interpolation that straddles the inf boundary is unsaturated
    assert percentile([1.0, inf], 50.0) == inf
    assert not math.isnan(percentile([1.0, 2.0, 3.0, inf, inf], 50.0))


def test_saturation_multiplier_monotone_score():
    # score saturates above alpha=2 exactly
    res = saturation_multiplier(lambda a: 1.0 if a >= 2.0 else 0.5,
                                alphas=[1.0, 1.5, 2.0, 2.5, 3.0])
    assert res.alpha_star == 2.0


def test_saturation_requires_staying_saturated():
    # dips back below threshold -> earlier saturation doesn't count
    scores = {1.0: 1.0, 1.5: 0.6, 2.0: 1.0, 2.5: 1.0}
    res = saturation_multiplier(lambda a: scores[a], alphas=[1.0, 1.5, 2.0, 2.5])
    assert res.alpha_star == 2.0


def test_comm_piecewise_regions():
    m = PAPER_COMM_MODEL
    assert m.cost(0) == 0.0
    small, large = m.rpc_overhead(1000), m.rpc_overhead(10 * MIB)
    assert small < large
    assert m.cost(MIB) >= m.transfer_time(MIB)


def test_comm_fit_recovers_synthetic():
    true = PiecewiseLinearCommModel(a_lo=1e-4, b_lo=1e-11, a_hi=2e-4, b_hi=3e-11)
    sizes = [2**k for k in range(8, 26)]
    samples = [(float(n), true.cost(n)) for n in sizes]
    fit = PiecewiseLinearCommModel.fit(samples)
    for n in (1e3, 1e5, 5e6, 5e7):
        assert fit.cost(n) == pytest.approx(true.cost(n), rel=0.05)


def test_microbenchmark_host_monotone():
    samples = microbenchmark_host(sizes=(1 << 12, 1 << 18, 1 << 22), repeats=3)
    assert len(samples) == 3
    assert samples[-1][1] > samples[0][1]  # bigger copies take longer
    fit = PiecewiseLinearCommModel.fit(samples)
    assert fit.cost(1 << 20) > 0


@settings(max_examples=50, deadline=None)
@given(st.floats(1.0, 1e9))
def test_quantization_cost_positive_monotone(n):
    assert quantization_cost(n) > 0
    assert quantization_cost(2 * n) > quantization_cost(n)
