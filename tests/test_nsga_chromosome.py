"""NSGA machinery + chromosome operators."""
import random

from _hypothesis_compat import given, settings, st

from repro.core import (
    SolutionFactory,
    chain_graph,
    das_dennis,
    decode_solution,
    dominates,
    fast_non_dominated_sort,
    nsga3_select,
    subgraph_processor,
)
from repro.core.chromosome import upmx


def test_dominates():
    assert dominates((1, 1), (2, 2))
    assert dominates((1, 2), (1, 3))
    assert not dominates((1, 3), (2, 2))
    assert not dominates((1, 1), (1, 1))


def test_fronts_simple():
    fits = [(1, 1), (2, 2), (0, 3), (3, 0), (2, 0.5)]
    fronts = fast_non_dominated_sort(fits)
    assert set(fronts[0]) == {0, 2, 3, 4}
    assert set(fronts[1]) == {1}


def test_das_dennis_count():
    # C(n+d-1, d) points for d divisions, n objectives
    pts = das_dennis(3, 4)
    assert len(pts) == 15
    for p in pts:
        assert abs(sum(p) - 1.0) < 1e-9


def test_nsga3_preserves_first_front():
    fits = [(1.0, 4.0), (2.0, 3.0), (3.0, 2.0), (4.0, 1.0),  # front 0
            (5.0, 5.0), (6.0, 6.0)]
    sel = nsga3_select(fits, 4, rng=random.Random(0))
    assert sorted(sel) == [0, 1, 2, 3]


def test_nsga3_niching_spreads():
    # 8 points on front 0; select 4 -> should cover spread, not cluster
    fits = [(i, 7 - i) for i in range(8)]
    sel = nsga3_select(fits, 4, rng=random.Random(0))
    assert len(sel) == 4
    assert len(set(sel)) == 4


@settings(max_examples=80, deadline=None)
@given(st.integers(2, 12), st.integers(0, 10_000))
def test_upmx_permutation_property(n, seed):
    rng = random.Random(seed)
    p1 = list(range(n)); rng.shuffle(p1)
    p2 = list(range(n)); rng.shuffle(p2)
    c1, c2 = upmx(p1, p2, rng)
    assert sorted(c1) == list(range(n))
    assert sorted(c2) == list(range(n))


def _factory(n_models=3, n_layers=5):
    graphs = [chain_graph(f"m{i}", [("conv", 1e6, 10, 100)] * n_layers)
              for i in range(n_models)]
    return graphs, SolutionFactory(graphs, num_processors=3, rng=random.Random(1))


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 1000))
def test_crossover_mutation_validity(seed):
    graphs, fac = _factory()
    fac.rng = random.Random(seed)
    a, b = fac.random_solution(), fac.random_solution()
    c1, c2 = fac.crossover(a, b)
    for c in (c1, c2):
        m = fac.mutate(c)
        assert sorted(m.priority) == list(range(len(graphs)))
        for net, g in enumerate(graphs):
            assert len(m.partition[net]) == g.num_edges
            assert all(bit in (0, 1) for bit in m.partition[net])
            assert all(0 <= p < 3 for p in m.mapping[net])
        # decoding never crashes and covers all layers
        placed = decode_solution(m, graphs)
        for net, plist in enumerate(placed):
            layers = sorted(lid for p in plist for lid in p.subgraph.layer_ids)
            assert layers == list(range(graphs[net].num_layers))


def test_majority_vote_mapping():
    g = chain_graph("m", [("conv", 1e6, 10, 100)] * 3)
    sg = g.partition([0, 0])[0]
    assert subgraph_processor(sg, [2, 2, 0]) == 2
    assert subgraph_processor(sg, [0, 1, 2]) == 0  # tie -> smallest pid
