"""Model-stack correctness: oracles, decode-vs-prefill consistency, smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    blockwise_attention,
    forward_decode,
    forward_prefill,
    forward_train,
    init_params,
    moe_ffn,
    moe_ffn_dense,
    ssd_chunked,
)
from repro.models.moe import init_moe
from repro.configs import ALIASES, get_config, get_smoke_config

KEY = jax.random.PRNGKey(0)


# -- attention oracle ---------------------------------------------------------

def naive_attention(q, k, v, causal=True, window=None):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    reps = h // k.shape[2]
    k = jnp.repeat(k, reps, axis=2)
    v = jnp.repeat(v, reps, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * hd ** -0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("sq,h,kv,window", [
    (64, 4, 4, None), (64, 4, 2, None), (100, 4, 2, None), (64, 4, 2, 16),
])
def test_blockwise_attention_matches_naive(sq, h, kv, window):
    hd = 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, hd))
    k = jax.random.normal(ks[1], (2, sq, kv, hd))
    v = jax.random.normal(ks[2], (2, sq, kv, hd))
    got = blockwise_attention(q, k, v, causal=True, window=window,
                              q_block=32, kv_block=32)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# -- SSD oracle ------------------------------------------------------------

def naive_ssd(x, dt, A, Bm, Cm):
    """Token-by-token linear recurrence: the SSD ground truth."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    reps = h // g
    Bh = jnp.repeat(Bm, reps, axis=2)
    Ch = jnp.repeat(Cm, reps, axis=2)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * A[None, :])              # (B, H)
        outer = jnp.einsum("bh,bhn,bhp->bhpn", dt[:, t], Bh[:, t], x[:, t])
        state = decay[:, :, None, None] * state + outer
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch[:, t], state))
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("s,chunk", [(32, 8), (32, 32), (64, 16)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    b, h, p, g, n = 2, 4, 8, 1, 16
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[0], (b, s, g, n)) * 0.3
    y, st = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, st_ref = naive_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    """Same output for any chunk size (associativity of the scan)."""
    b, s, h, p, g, n = 1, 64, 2, 4, 1, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[0], (b, s, g, n)) * 0.3
    y16, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y64, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), rtol=1e-4, atol=1e-4)


# -- MoE dispatch oracle ------------------------------------------------------

def test_moe_sort_dispatch_matches_dense_oracle():
    d, e, k, ff = 32, 8, 2, 64
    params = init_moe(KEY, d, e, ff)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d)) * 0.5
    # generous capacity -> no drops -> must match the dense oracle exactly
    got = moe_ffn(params, x, e, k, capacity_factor=8.0)
    want = moe_ffn_dense(params, x, e, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    d, e, k, ff = 16, 4, 2, 32
    params = init_moe(KEY, d, e, ff)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, d))
    tight = moe_ffn(params, x, e, k, capacity_factor=0.5)
    loose = moe_ffn(params, x, e, k, capacity_factor=8.0)
    # tight capacity drops tokens -> output differs but stays finite
    assert np.all(np.isfinite(np.asarray(tight)))
    assert not np.allclose(np.asarray(tight), np.asarray(loose))


# -- decode vs prefill consistency -----------------------------------------

@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "qwen3-14b", "qwen2.5-32b",
                                  "mamba2-1.3b", "olmoe-1b-7b"])
def test_decode_matches_train_logits(arch):
    """Greedy decode logits at position t must equal the full-sequence
    forward at position t (cache correctness)."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.uses_moe:
        # capacity drops are computed over the routed token count, which
        # differs between full-sequence and single-token calls; remove
        # drops so the comparison is exact.
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = init_params(cfg, KEY)
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    full = forward_train(params, cfg, tokens, remat=False)
    prefix = 8
    _, caches, clen = forward_prefill(params, cfg, tokens[:, :prefix], S + 4)
    lg = None
    for t in range(prefix, S):
        lg, caches, clen = forward_decode(params, cfg, tokens[:, t:t+1], caches, clen)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full[:, t], np.float32),
            rtol=2e-3, atol=2e-3,
        )


def test_vlm_cross_attention_uses_image():
    cfg = get_smoke_config("llama-3.2-vision-11b")
    params = init_params(cfg, KEY)
    # make the gate non-zero so the image path is active
    blocks = list(params["blocks"])
    cross_ix = list(cfg.layout_pattern).index("cross")
    blk = dict(blocks[cross_ix])
    xattn = dict(blk["xattn"])
    xattn["attn_gate"] = jnp.ones_like(xattn["attn_gate"]) * 2.0
    blk["xattn"] = xattn
    blocks[cross_ix] = blk
    params["blocks"] = tuple(blocks)
    tokens = jnp.ones((1, 8), jnp.int32)
    img1 = jnp.ones((1, cfg.num_image_tokens, cfg.d_model)) * 0.1
    img2 = -img1
    l1 = forward_train(params, cfg, tokens, img1, remat=False)
    l2 = forward_train(params, cfg, tokens, img2, remat=False)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_whisper_encoder_decoder():
    cfg = get_smoke_config("whisper-medium")
    params = init_params(cfg, KEY)
    tokens = jnp.ones((1, 8), jnp.int32)
    frames1 = jnp.ones((1, cfg.encoder_seq_len, cfg.d_model)) * 0.1
    frames2 = frames1 * -3.0
    l1 = forward_train(params, cfg, tokens, frames1, remat=False)
    l2 = forward_train(params, cfg, tokens, frames2, remat=False)
    assert l1.shape == (1, 8, cfg.vocab_size)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


# -- per-arch smoke: fwd + one train step, shapes + no NaNs ----------------

@pytest.mark.parametrize("arch", list(ALIASES))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.uses_moe:
        assert cfg.num_experts <= 4
    params = init_params(cfg, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    cross = None
    if cfg.arch_type == "vlm":
        cross = jnp.ones((B, cfg.num_image_tokens, cfg.d_model)) * 0.01
    if cfg.is_encoder_decoder:
        cross = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model)) * 0.01

    def loss_fn(p):
        logits = forward_train(p, cfg, tokens, cross, remat=False)
        targets = jnp.roll(tokens, -1, axis=1)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, targets[..., None], axis=-1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    # one SGD step changes the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", list(ALIASES))
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.param_count() > 0
    if arch == "kimi-k2-1t-a32b":
        assert 0.9e12 < cfg.param_count() < 1.15e12
        assert 25e9 < cfg.active_param_count() < 40e9
    if arch == "jamba-1.5-large-398b":
        assert 350e9 < cfg.param_count() < 450e9
