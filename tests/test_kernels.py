"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    attention_ref,
    flash_attention,
    flash_attention_bshd,
    quantize_int8,
    quantize_ref,
    ssd_bshp,
    ssd_ref,
    ssd_scan,
)

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# -- flash attention ------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,sq,sk,hd,g", [
    (2, 128, 128, 64, 1),
    (4, 256, 256, 128, 2),
    (2, 100, 100, 64, 1),     # ragged: padding path
    (3, 64, 192, 32, 3),      # cross-length + GQA 3
])
def test_flash_attention_matches_ref(bh, sq, sk, hd, g, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (bh, sq, hd), dtype)
    k = jax.random.normal(ks[1], (bh // g, sk, hd), dtype)
    v = jax.random.normal(ks[2], (bh // g, sk, hd), dtype)
    causal = sq == sk
    got = flash_attention(q, k, v, q_heads_per_kv=g, causal=causal,
                          block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, q_heads_per_kv=g, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_sliding_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 64))
    k = jax.random.normal(ks[1], (2, 256, 64))
    v = jax.random.normal(ks[2], (2, 256, 64))
    got = flash_attention(q, k, v, causal=True, window=64,
                          block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_q_offset_continuation():
    """Prefill continuation: q is a suffix of the sequence."""
    ks = jax.random.split(KEY, 3)
    k = jax.random.normal(ks[1], (1, 128, 64))
    v = jax.random.normal(ks[2], (1, 128, 64))
    q_full = jax.random.normal(ks[0], (1, 128, 64))
    full = flash_attention(q_full, k, v, causal=True, block_q=32, block_k=32,
                           interpret=True)
    tail = flash_attention(q_full[:, 96:], k, v, causal=True, q_offset=96,
                           block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 96:]),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bshd_wrapper_matches_model_path():
    from repro.models import blockwise_attention
    ks = jax.random.split(KEY, 3)
    b, s, h, kv, hd = 2, 128, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    got = flash_attention_bshd(q, k, v, causal=True)
    want = blockwise_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# -- SSD scan -----------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 64, 32, 16, 16),
    (4, 128, 64, 32, 32),
    (2, 128, 64, 128, 64),
])
def test_ssd_scan_matches_recurrence(bh, s, p, n, chunk, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (bh, s, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (bh, s, n)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[0], (bh, s, n)) * 0.3).astype(dtype)
    y, st = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, st_ref = ssd_ref(x, dt, A, Bm, Cm)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    # ssd_ref returns state as (BH, N, P)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), **tol)


def test_ssd_bshp_wrapper_matches_model_ssd():
    from repro.models import ssd_chunked
    ks = jax.random.split(KEY, 4)
    b, s, h, p, g, n = 2, 64, 4, 16, 1, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[0], (b, s, g, n)) * 0.3
    y_kernel, st_kernel = ssd_bshp(x, dt, A, Bm, Cm, chunk=16)
    y_model, st_model = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_kernel), np.asarray(st_model),
                               rtol=2e-4, atol=2e-4)


# -- int8 quantization ------------------------------------------------------

@pytest.mark.parametrize("r,c", [(16, 64), (100, 128), (256, 32)])
def test_quantize_matches_ref(r, c):
    x = jax.random.normal(KEY, (r, c)) * 3.0
    q, s = quantize_int8(x, block_rows=64, interpret=True)
    q_ref, s_ref = quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)


def test_quantize_roundtrip_error_bounded():
    from repro.kernels import dequantize_int8
    x = jax.random.normal(KEY, (64, 128)) * 5.0
    q, s = quantize_int8(x, interpret=True)
    back = dequantize_int8(q, s)
    err = np.abs(np.asarray(back) - np.asarray(x)).max()
    scale_max = float(np.asarray(s).max())
    assert err <= scale_max  # quantization error bounded by one step
