"""Property tests for the scoring/α*-search layer (paper §6.2).

Three families:

* ``saturation_multiplier_bisect`` ≡ the 117-point grid scan on randomized
  score curves (within the bisection's documented contract: non-final
  saturated runs no longer than ``confirm`` grid points — exactly the
  contention-dip shape the confirmation scan exists for);
* RtScore/scenario-score monotonicity in the period multiplier α (and in
  the makespan);
* ``deadline_satisfaction`` bounds and monotonicity.

Runs under hypothesis when installed, else the deterministic fallback
(tests/_hypothesis_compat.py).
"""
import math
import random

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.scoring import (
    ALPHA_GRID,
    deadline_satisfaction,
    rt_score,
    saturation_multiplier,
    saturation_multiplier_bisect,
    scenario_score,
)

CONFIRM = 4  # the bisection's confirmation-scan width (its default)


def _random_curve(rng: random.Random):
    """Score values over ALPHA_GRID: alternating saturated/unsaturated runs.

    Non-final saturated runs are kept ≤ CONFIRM long (the bisection's
    equivalence contract); a saturated tail — the usual physical shape —
    is appended with high probability and may be arbitrarily long.
    """
    n = len(ALPHA_GRID)
    scores = []
    sat = rng.random() < 0.3
    while len(scores) < n:
        if sat:
            length = rng.randint(1, CONFIRM)
            scores.extend(rng.uniform(0.996, 1.0) for _ in range(length))
        else:
            length = rng.randint(1, 30)
            scores.extend(rng.uniform(0.0, 0.99) for _ in range(length))
        sat = not sat
    scores = scores[:n]
    if rng.random() < 0.6:
        tail = rng.randint(1, 60)
        for i in range(n - tail, n):
            scores[i] = rng.uniform(0.996, 1.0)
    return scores


@given(st.integers(min_value=0, max_value=10**9))
@settings(max_examples=60, deadline=None)
def test_bisect_equals_grid_on_random_curves(seed):
    rng = random.Random(seed)
    scores = dict(zip(ALPHA_GRID, _random_curve(rng)))
    def evaluate(a):
        return scores[a]

    grid = saturation_multiplier(evaluate)
    bisect = saturation_multiplier_bisect(evaluate)
    assert bisect.alpha_star == grid.alpha_star, (
        seed, grid.alpha_star, bisect.alpha_star)
    # the bisection probes a subset of the same lattice
    assert {a for a, _ in bisect.scores} <= set(ALPHA_GRID)
    assert len(bisect.scores) <= len(grid.scores)


def test_bisect_equals_grid_edge_curves():
    for curve in (
        {a: 1.0 for a in ALPHA_GRID},                       # always saturated
        {a: 0.0 for a in ALPHA_GRID},                       # never saturated
        {a: (1.0 if a >= 3.0 else 0.5) for a in ALPHA_GRID},  # clean step
        {a: (1.0 if a >= ALPHA_GRID[-1] else 0.2)
         for a in ALPHA_GRID},                              # last point only
        {a: (0.3 if a == ALPHA_GRID[-1] else 1.0)
         for a in ALPHA_GRID},                              # dip at the end
    ):
        grid = saturation_multiplier(lambda a: curve[a])
        bisect = saturation_multiplier_bisect(lambda a: curve[a])
        assert bisect.alpha_star == grid.alpha_star


@given(
    st.floats(min_value=1e-6, max_value=10.0),
    st.floats(min_value=1e-6, max_value=10.0),
    st.floats(min_value=1.0, max_value=4.0),
)
@settings(max_examples=60, deadline=None)
def test_rt_score_monotone_in_alpha_and_makespan(makespan, deadline, stretch):
    # larger α (longer deadline) never lowers the score of a fixed makespan
    assert rt_score(makespan, deadline * stretch) >= rt_score(makespan, deadline)
    # a slower request never scores higher under a fixed deadline
    assert rt_score(makespan * stretch, deadline) <= rt_score(makespan, deadline)
    # bounds + degenerate cases
    assert 0.0 <= rt_score(makespan, deadline) <= 1.0
    assert rt_score(float("inf"), deadline) == 0.0
    assert rt_score(makespan, 0.0) == 0.0


@given(st.integers(min_value=0, max_value=10**9))
@settings(max_examples=30, deadline=None)
def test_scenario_score_monotone_in_alpha(seed):
    rng = random.Random(seed)
    groups = [
        [rng.uniform(1e-4, 5e-2) for _ in range(rng.randint(1, 8))]
        for _ in range(rng.randint(1, 3))
    ]
    base = [rng.uniform(1e-3, 2e-2) for _ in groups]
    prev = -1.0
    for alpha in (0.2, 0.5, 1.0, 2.0, 6.0):
        score = scenario_score(groups, [alpha * p for p in base])
        assert 0.0 <= score <= 1.0
        assert score >= prev - 1e-12, "score not monotone in α"
        prev = score


@given(st.integers(min_value=0, max_value=10**9))
@settings(max_examples=40, deadline=None)
def test_deadline_satisfaction_bounds_and_monotonicity(seed):
    rng = random.Random(seed)
    groups = [
        [rng.uniform(1e-4, 5e-2) if rng.random() < 0.9 else float("inf")
         for _ in range(rng.randint(1, 8))]
        for _ in range(rng.randint(1, 4))
    ]
    deadlines = [rng.uniform(1e-3, 2e-2) for _ in groups]
    rate = deadline_satisfaction(groups, deadlines)
    assert 0.0 <= rate <= 1.0
    # longer deadlines never lower the hit rate
    relaxed = deadline_satisfaction(groups, [3.0 * d for d in deadlines])
    assert relaxed >= rate
    # extremes
    assert deadline_satisfaction(groups, [float("inf")] * len(groups)) == \
        pytest.approx(
            sum(1 for ms in groups for m in ms if not math.isinf(m))
            / sum(len(ms) for ms in groups))
    assert deadline_satisfaction(groups, [0.0] * len(groups)) == 0.0


def test_deadline_satisfaction_group_mismatch_raises():
    with pytest.raises(ValueError):
        deadline_satisfaction([[1.0], [2.0]], [1.0])
    assert deadline_satisfaction([], []) == 0.0
