"""Regression tests for the hot-path bugfix sweep.

Long-standing bugs, each with a test that fails on the pre-fix code:

* **GA mating** (``ga.py``): with an odd ``pop_size``,
  ``zip(parents[0::2], parents[1::2])`` silently dropped the last shuffled
  parent from mating every generation.
* **TensorPool aliasing** (``runtime/tensorpool.py``): double-releasing a
  buffer enqueued it twice, so two later ``acquire`` calls aliased one
  backing store; foreign releases created unservable free-list buckets;
  pooled frees were never counted.
* **Best Mapping frontier** (``core/baselines.py``): keys whose archive
  entries got dominated stayed in the hillclimb frontier, burning the
  evaluation budget expanding dead mappings.
* **Objective-cache LRU** (``core/analyzer.py``): ``objectives`` cache hits
  never refreshed recency, so the "LRU" evicted by insertion order — the
  incumbent Pareto front, re-scored every generation, was exactly what got
  evicted under pressure; ``objectives_batch`` hits were neither counted
  nor refreshed, so batch-mode stats undercounted and eviction order
  diverged from the scalar path.
* **Batch sharding** (``core/batchsim.py``): the sharded path measured
  *slower* than in-process at GA widths, yet ``workers > 1`` always
  sharded; ``run_batch`` now stays in-process below ``SHARD_MIN_LANES``.
"""
import random

import numpy as np
import pytest

from repro.core import (
    PAPER_COMM_MODEL,
    SHARD_MIN_LANES,
    AnalyzerConfig,
    GAConfig,
    GeneticScheduler,
    Profiler,
    SolutionFactory,
    StaticAnalyzer,
    build_scenario,
    chain_graph,
    mobile_processors,
    run_batch,
)
from repro.core.profiler import AnalyticMobileBackend
from repro.core.baselines import _whole_model_solution, best_mapping_solutions
from repro.core.nsga import fast_non_dominated_sort
from repro.experiments import generate_scenario_specs
from repro.experiments.evaluate import default_context
from repro.runtime.tensorpool import CHUNK, TensorPool


# -- GA: odd pop_size mating --------------------------------------------------

def _nets(n=2):
    return [chain_graph(f"m{i}", [("conv", 2e6, 500, 2000)] * 3)
            for i in range(n)]


def _scheduler(pop_size, **cfg_kw):
    nets = _nets()
    fac = SolutionFactory(nets, num_processors=3, rng=random.Random(7))

    def ev(sol):
        # cheap deterministic objective: genes only, no simulation
        return (float(sum(map(sum, sol.mapping))), float(sum(sol.dtype)))

    cfg_kw.setdefault("max_generations", 3)
    cfg_kw.setdefault("min_generations", 1)
    return GeneticScheduler(
        factory=fac, evaluate_fast=ev,
        config=GAConfig(pop_size=pop_size, seed=3, **cfg_kw),
    ), fac


def test_ga_odd_population_mates_every_parent():
    """The leftover shuffled parent must participate in mating.

    With crossover and mutation disabled, offspring are verbatim parent
    copies — so every parent's chromosome must appear among the offspring.
    Pre-fix, an odd population produced only ``pop_size - 1`` offspring and
    the last shuffled parent's genes were guaranteed absent.
    """
    sched, fac = _scheduler(5, cx_prob=0.0, p_bit=0.0, p_map=0.0,
                            p_prio=0.0, p_cfg=0.0)
    parents = [fac.random_solution() for _ in range(5)]
    offspring = sched._mate(parents)
    assert len(offspring) == 6  # pre-fix: 4
    child_keys = {c.key() for c in offspring}
    for p in parents:
        assert p.key() in child_keys, "a parent sat the generation out"


def test_ga_even_population_mating_unchanged():
    sched, fac = _scheduler(6, cx_prob=0.0, p_bit=0.0, p_map=0.0,
                            p_prio=0.0, p_cfg=0.0)
    parents = [fac.random_solution() for _ in range(6)]
    state = sched.rng.getstate()
    offspring = sched._mate(parents)
    assert len(offspring) == 6
    # even path draws exactly one rng value per pair (the cx_prob gate),
    # exactly like the pre-fix loop — no extra partner draw, so even-sized
    # populations reproduce historical GA runs bit for bit
    replay = random.Random()
    replay.setstate(state)
    for _ in range(3):
        replay.random()
    assert replay.getstate() == sched.rng.getstate()


def test_ga_runs_with_odd_pop_size():
    """End to end: an odd population searches without losing candidates.

    With local search off and crossover forced, generation 1 evaluates the
    5 initial candidates, the offspring, and the accurate front-0 re-evals.
    The mating fix produces 6 offspring per generation where the pre-fix
    loop produced 4, so the fast-evaluation count before the accurate pass
    is 11 distinct solutions vs at most 9 — the evaluator-call counter
    (which also includes the accurate pass) must clear the post-fix floor.
    """
    sched, _ = _scheduler(5, cx_prob=1.0, p_local=0.0, max_generations=1,
                          min_generations=1)
    fast_calls = []
    inner = sched.evaluate_fast
    sched.evaluate_fast = lambda s: (fast_calls.append(s.key()), inner(s))[1]
    result = sched.run()
    assert result.generations == 1
    assert result.pareto
    # 5 initial + 6 offspring distinct fast evaluations (pre-fix: 5 + 4)
    assert len(set(fast_calls)) >= 11


def test_ga_singleton_population_survives():
    sched, fac = _scheduler(1, cx_prob=0.0, p_bit=0.0, p_map=0.0,
                            p_prio=0.0, p_cfg=0.0)
    parents = [fac.random_solution()]
    offspring = sched._mate(parents)
    assert len(offspring) == 2
    assert all(c.key() == parents[0].key() for c in offspring)


# -- TensorPool: double/foreign release ---------------------------------------

def _base(arr):
    while arr.base is not None:
        arr = arr.base
    return arr


def test_tensorpool_double_release_does_not_alias():
    pool = TensorPool()
    a = pool.acquire((100,), np.float32)
    pool.release(a)
    pool.release(a)  # double release: must be ignored
    x = pool.acquire((100,), np.float32)
    y = pool.acquire((100,), np.float32)
    assert _base(x) is not _base(y), (
        "two live buffers share one backing store")
    # writes through one view must not corrupt the other
    x.fill(1.0)
    y.fill(2.0)
    assert float(x[0]) == 1.0 and float(y[0]) == 2.0
    assert pool.stats.rejected_frees == 1


def test_tensorpool_foreign_release_ignored():
    pool = TensorPool()
    foreign = np.zeros(100, np.uint8)  # not chunk-rounded, never acquired
    pool.release(foreign)
    assert pool.stats.rejected_frees == 1
    # no unservable bucket keyed by the unrounded nbytes
    assert 100 not in pool._free
    # and the free list still serves normally afterwards
    a = pool.acquire((10,), np.float32)
    pool.release(a)
    b = pool.acquire((10,), np.float32)
    assert pool.stats.reuses == 1
    assert _base(b) is _base(a)


def test_tensorpool_counts_pooled_frees():
    pool = TensorPool()
    bufs = [pool.acquire((CHUNK // 4,), np.float32) for _ in range(3)]
    for b in bufs:
        pool.release(b)
    # pre-fix: frees stayed 0 on the pooled path, so §5.3 free-time
    # accounting could not be audited
    assert pool.stats.frees == 3
    assert pool.stats.rejected_frees == 0
    # release calls = honored + rejected, always
    pool.release(bufs[0])
    assert pool.stats.frees + pool.stats.rejected_frees == 4


def test_tensorpool_disabled_counts_frees():
    pool = TensorPool(enabled=False)
    a = pool.acquire((10,), np.float32)
    pool.release(a)
    assert pool.stats.frees == 1
    assert pool.stats.mallocs == 1


def test_tensorpool_reuse_roundtrip_still_works():
    pool = TensorPool()
    a = pool.acquire((64, 64), np.float32)
    pool.release(a)
    b = pool.acquire((32, 32), np.float32)  # smaller, same rounded class?
    # whatever the bucket, acquire/release cycles keep working and tracked
    pool.release(b)
    c = pool.stage(np.ones((8, 8), np.float32))
    assert float(c[0, 0]) == 1.0
    pool.release(c)
    assert pool.stats.frees >= 3


# -- Best Mapping: frontier pruning -------------------------------------------

def _prefix_best_mapping(graphs, processors, best_times, evaluate,
                         max_evals, seed):
    """Faithful reimplementation of the PRE-fix hillclimb (no pruning, no
    dedup) — the behavior the committed-seed comparison runs against."""
    rng = random.Random(seed)
    n = len(graphs)

    def make(key):
        cfgs = [(best_times[m][key[m]][1], best_times[m][key[m]][2])
                for m in range(n)]
        return _whole_model_solution(graphs, list(key), cfgs)

    start = tuple(min(best_times[m], key=lambda pid: best_times[m][pid][0])
                  for m in range(n))
    evaluated = {}

    def ev(key):
        if key not in evaluated:
            evaluated[key] = evaluate(make(key))
        return evaluated[key]

    archive = [(start, ev(start))]
    frontier = [start]
    while frontier and len(evaluated) < max_evals:
        base = frontier.pop(0)
        neighbors = []
        for m in range(n):
            for p in processors:
                if p != base[m]:
                    neighbors.append(
                        tuple(p if i == m else base[i] for i in range(n)))
        rng.shuffle(neighbors)
        for cand in neighbors:
            if len(evaluated) >= max_evals:
                break
            if cand in evaluated:
                continue
            obj = ev(cand)
            fits = [o for _, o in archive] + [obj]
            fronts = fast_non_dominated_sort(fits)
            if len(archive) in fronts[0]:
                items = archive + [(cand, obj)]
                archive = [items[i] for i in fronts[0]]
                frontier.append(cand)
    return archive


#: Synthetic 3-model × 3-processor landscape. With neighbor-shuffle seed 3
#: the hillclimb discovers X=(1,0,0) before Y=(0,1,0) while expanding the
#: start; expanding X then finds (1,1,0), which dominates Y. Pre-fix, the
#: dead Y stays in the frontier and its private neighborhood
#: {(0,1,1), (0,1,2)} is evaluated anyway; with pruning it never is.
_LANDSCAPE = {
    (0, 0, 0): (10.0, 10.0),
    (1, 0, 0): (5.0, 10.0),
    (0, 1, 0): (10.0, 5.0),
    (1, 1, 0): (6.0, 4.0),
}
_DEAD_NEIGHBORHOOD = ((0, 1, 1), (0, 1, 2))


def test_best_mapping_prunes_dominated_frontier_keys():
    graphs = _nets(3)
    best_times = [{p: (float(m + p + 1), 0, 0) for p in (0, 1, 2)}
                  for m in range(3)]  # argmin pid 0 -> start = (0, 0, 0)
    calls = []

    def ev(sol):
        key = tuple(sol.mapping[m][0] for m in range(3))
        calls.append(key)
        return _LANDSCAPE.get(key, (20.0, 20.0))

    sols = best_mapping_solutions(graphs, [0, 1, 2], best_times, ev,
                                  max_evals=30, seed=3)
    ix = {k: i for i, k in enumerate(calls)}
    # precondition of the scenario: X discovered before Y during the start's
    # expansion (fails loudly if the shuffle stream ever changes)
    assert ix[(1, 0, 0)] < ix[(0, 1, 0)], "landscape precondition broken"
    dead = [k for k in _DEAD_NEIGHBORHOOD if k in ix]
    assert not dead, (
        f"budget spent expanding a dominated frontier key: {dead}")
    archive_keys = {tuple(s.mapping[m][0] for m in range(3)) for s in sols}
    assert archive_keys == {(1, 0, 0), (1, 1, 0)}


@pytest.mark.parametrize("index", [1, 2, 4])
def test_best_mapping_unchanged_or_better_on_committed_seeds(index):
    """On the committed ``RESULTS_sweep.json`` seeds the pruned hillclimb's
    archive is unchanged-or-better: no fixed-archive entry is dominated by
    any pre-fix entry (never worse), while the freed budget lets it
    dominate pre-fix entries on some scenarios (strictly better)."""
    ctx = default_context()
    spec = generate_scenario_specs(8, seed=0)[index]
    scen = build_scenario(spec.name, [list(g) for g in spec.groups],
                          ctx.graphs)
    an = StaticAnalyzer(scen, ctx.processors, ctx.profiler, ctx.comm_model,
                        AnalyzerConfig(ga=GAConfig(seed=spec.seed)))
    def ev(s):
        return an.objectives(s, num_requests=an.cfg.fast_requests)

    fixed = [tuple(s.fitness)
             for s in an.best_mapping(max_evals=120, seed=spec.seed)]
    pre = [o for _, o in _prefix_best_mapping(
        scen.graphs, [p.pid for p in an.processors], an.best_times,
        ev, 120, spec.seed)]

    def dominates(a, b):
        return (all(x <= y for x, y in zip(a, b))
                and any(x < y for x, y in zip(a, b)))

    worse = [f for f in fixed if any(dominates(p, f) for p in pre)]
    assert not worse, "pruning made an archive entry strictly worse"
    if index == 4:
        # this scenario's pre-fix run provably wasted budget: the fixed
        # archive strictly dominates several of its entries
        assert any(any(dominates(f, p) for f in fixed) for p in pre)


# -- Objective cache: LRU recency -----------------------------------------


def _cache_analyzer(cache_size=1):
    """Analyzer with a tiny objective cache (cap = 4 * cache_size)."""
    nets = [chain_graph(f"n{i}", [("conv", (2 + i) * 1e6, 500, 2000)] * 3)
            for i in range(2)]
    scen = build_scenario("lru", [["n0", "n1"]], {g.name: g for g in nets})
    procs = mobile_processors()
    prof = Profiler(AnalyticMobileBackend(procs))
    cfg = AnalyzerConfig(decode_cache_size=cache_size, ga=GAConfig(seed=5))
    return StaticAnalyzer(scen, procs, prof, PAPER_COMM_MODEL, cfg)


def _distinct_solutions(an, n):
    """Solutions with pairwise-distinct spec signatures (distinct memo keys)."""
    an.factory.rng = random.Random(11)
    sols, seen = [], set()
    while len(sols) < n:
        s = an.factory.random_solution()
        sig = an.solution_spec(s).signature()
        if sig not in seen:
            seen.add(sig)
            sols.append(s)
    return sols


def test_objective_cache_hot_key_survives_eviction():
    """A repeatedly-hit key must outlive colder insertions.

    Pre-fix, ``objectives`` hits never called ``move_to_end``, so eviction
    degraded to insertion order: the oldest-inserted key was evicted even
    while being hit every generation — exactly the incumbent Pareto front's
    access pattern.
    """
    an = _cache_analyzer()  # objective cache cap = 4
    sol_a, sol_b, sol_c, sol_d, sol_e = _distinct_solutions(an, 5)
    for s in (sol_a, sol_b, sol_c, sol_d):
        an.objectives(s)               # 4 misses: cache exactly full
    assert an.objective_cache_misses == 4
    an.objectives(sol_a)               # hit: must refresh recency
    assert an.objective_cache_hits == 1
    an.objectives(sol_e)               # evicts the true LRU (B) — not A
    misses = an.objective_cache_misses
    an.objectives(sol_a)
    assert an.objective_cache_hits == 2, (
        "hot key evicted: cache degraded to insertion order")
    assert an.objective_cache_misses == misses


def test_objectives_batch_hit_accounting_and_recency():
    """Batch dedup/read-back hits count and refresh like the scalar path."""
    an = _cache_analyzer()
    sol_a, sol_b, sol_c, sol_d, sol_e = _distinct_solutions(an, 5)
    an.objectives(sol_a)
    assert (an.objective_cache_hits, an.objective_cache_misses) == (0, 1)
    out = an.objectives_batch([sol_a, sol_b])   # A: cached; B: fresh lane
    assert an.objective_cache_hits == 1, "batch cache hit went uncounted"
    assert an.objective_cache_misses == 2
    assert out[0] == an.objectives(sol_a)       # agrees with scalar path
    # recency through the batch path only: fill the cap, touch A via a
    # pure-hit batch, then force one eviction — A must survive it
    an.objectives_batch([sol_c, sol_d])         # cache now {A,B,C,D} (cap 4)
    an.objectives_batch([sol_a])
    hits = an.objective_cache_hits
    an.objectives(sol_e)                        # evicts true LRU (B)
    an.objectives(sol_a)
    assert an.objective_cache_hits == hits + 1, (
        "batch hit did not refresh LRU recency")


def test_objectives_batch_duplicate_counts_as_hit():
    """An in-generation duplicate is a hit (the scalar loop's second call
    would hit the cache) and must not be simulated twice."""
    an = _cache_analyzer(cache_size=64)
    sol_a, sol_b = _distinct_solutions(an, 2)
    out = an.objectives_batch([sol_a, sol_b, sol_a.copy()])
    assert an.objective_cache_misses == 2
    assert an.objective_cache_hits == 1
    assert out[0] == out[2]


# -- run_batch: sharding threshold ----------------------------------------


class _PoisonPool:
    """Stands in for a process pool that must not be used."""

    def map(self, *a, **k):  # pragma: no cover - failure path
        raise AssertionError("sharded below the measured lane threshold")


def test_run_batch_small_batch_stays_in_process():
    """Below SHARD_MIN_LANES, workers > 1 must not engage the (measured
    slower) sharded path; an explicit threshold override re-enables it."""
    an = _cache_analyzer(cache_size=64)
    sols = _distinct_solutions(an, 6)
    lanes = [an._lane(s, 1.0, 4, False) for s in sols]
    assert len(lanes) < SHARD_MIN_LANES
    res = run_batch(lanes, an.scenario.groups, an.processors,
                    workers=4, pool=_PoisonPool())
    ref = run_batch(lanes, an.scenario.groups, an.processors)
    for i in range(len(lanes)):
        assert res.makespans(i) == ref.makespans(i)
    with pytest.raises(AssertionError, match="sharded"):
        run_batch(lanes, an.scenario.groups, an.processors,
                  workers=2, pool=_PoisonPool(), shard_min_lanes=0)


# -- heuristic seed capability (core/chromosome.py) ---------------------------
# Surfaced by the static analyzer (SL010) over every committed
# RESULTS_sweep.json scenario: `seeded_solution(npu)` hardcoded
# (dtype, backend) = (fp32, default), which the NPU does not support — the
# "everything on the NPU" GA seed simulated under the 30x capability
# fallback penalty on all of its layers, making the heuristic seed useless
# exactly where the paper's NPU-heavy schedules come from.

def _seed_analyzer():
    nets = [chain_graph(f"s{i}", [("conv", 4e6, 1000, 4000)] * 4)
            for i in range(2)]
    scen = build_scenario("seed_fix", [["s0"], ["s1"]],
                          {f"s{i}": nets[i] for i in range(2)})
    procs = mobile_processors()
    prof = Profiler(AnalyticMobileBackend(procs))
    return StaticAnalyzer(scen, list(procs), prof, PAPER_COMM_MODEL,
                          AnalyzerConfig())


def test_npu_seed_uses_supported_config():
    """Pre-fix: the NPU seed carried fp32/default (unsupported on the NPU),
    so every layer simulated at the 30x fallback penalty."""
    an = _seed_analyzer()
    npu = next(p for p in an.processors if p.kind == "npu")
    sol = an.factory.seeded_solution(npu.pid)
    from repro.core.chromosome import BACKENDS, DTYPES
    for net in range(len(an.scenario.graphs)):
        dt, be = DTYPES[sol.dtype[net]], BACKENDS[sol.backend[net]]
        assert npu.thr(dt, be) is not None, (
            f"NPU seed pinned to unsupported config ({dt}, {be})")
    # and the analyzer confirms: no capability warning on the seed
    assert an.lint(sol).by_code("SL010") == []


def test_fixed_npu_seed_dominates_prefix_fp32_seed():
    """The supported-config seed must be strictly faster than the pre-fix
    fp32 seed it replaces (which paid the fallback penalty everywhere)."""
    an = _seed_analyzer()
    npu = next(p for p in an.processors if p.kind == "npu")
    fixed = an.factory.seeded_solution(npu.pid)
    prefix = fixed.copy()
    prefix.dtype = [0] * len(an.scenario.graphs)
    prefix.backend = [0] * len(an.scenario.graphs)
    alpha = an.saturation(fixed).alpha_star
    assert alpha < an.saturation(prefix).alpha_star, (
        "fixed seed should saturate at a strictly smaller alpha*")
    assert an.score(fixed, alpha) >= an.score(prefix, alpha)


def test_supported_processor_seeds_unchanged():
    """Behavior-preserving everywhere else: processors that do support
    (fp32, default) keep the exact pre-fix seed genes, and a factory
    without capability knowledge is bit-identical to the old code."""
    an = _seed_analyzer()
    for p in an.processors:
        if p.thr("fp32", "default") is None:
            continue
        sol = an.factory.seeded_solution(p.pid)
        assert sol.dtype == [0] * len(an.scenario.graphs)
        assert sol.backend == [0] * len(an.scenario.graphs)
    blind = SolutionFactory(_nets(), num_processors=3,
                            rng=random.Random(7))
    sol = blind.seeded_solution(2)  # no processors: legacy (0, 0) genes
    assert sol.dtype == [0, 0] and sol.backend == [0, 0]


def test_seed_config_does_not_touch_rng_stream():
    """The capability lookup is deterministic: seeding with and without
    capability knowledge must leave the factory RNG in the same state, so
    downstream random_solution() draws are unperturbed."""
    nets = _nets()
    procs = mobile_processors()
    with_caps = SolutionFactory(nets, num_processors=3,
                                rng=random.Random(11), processors=procs)
    without = SolutionFactory(nets, num_processors=3, rng=random.Random(11))
    for pid in (0, 1, 2):
        with_caps.seeded_solution(pid)
        without.seeded_solution(pid)
    assert with_caps.random_solution().key() == without.random_solution().key()
