"""Device-in-the-loop tier: runtime↔simulator conformance + measured-cost
feedback (StaticAnalyzer.validate_on_runtime / apply_measured_costs /
GAConfig.device_in_loop_interval)."""
import random

import pytest

from repro.core import (
    AnalyzerConfig,
    GAConfig,
    GeneticScheduler,
    PAPER_COMM_MODEL,
    Profiler,
    SolutionFactory,
    StaticAnalyzer,
    branching_graph,
    chain_graph,
    decode_solution,
    mobile_processors,
)
from repro.core.profiler import AnalyticMobileBackend
from repro.core.scenarios import Scenario

PROCS = mobile_processors()


def _nets():
    return [
        chain_graph("cfa", [("conv", 4e6, 1000, 4000)] * 5),
        branching_graph("cfb", [("conv", 2e6, 800, 2000)] * 4,
                        [(0, 1), (0, 2), (1, 3), (2, 3)]),
    ]


def _analyzer(groups=((0,), (1,)), arrival=None, **cfg_kw):
    nets = _nets()
    scenario = Scenario(name="conf", graphs=nets,
                        groups=[list(g) for g in groups], arrival=arrival)
    return StaticAnalyzer(
        scenario, PROCS, Profiler(AnalyticMobileBackend(PROCS)),
        PAPER_COMM_MODEL, AnalyzerConfig(**cfg_kw),
    )


def _solutions(nets, count, seed=0):
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(seed))
    return [fac.random_solution() for _ in range(count)]


# -- virtual conformance ------------------------------------------------------

@pytest.mark.parametrize("measured", [False, True])
def test_validate_on_runtime_virtual_zero_diff(measured):
    an = _analyzer()
    for sol in _solutions(an.scenario.graphs, 3, seed=2):
        rep = an.validate_on_runtime(sol, alpha=1.0, num_requests=8,
                                     measured=measured, seed=6)
        assert rep.mode == "virtual"
        assert rep.passed, rep.summary()
        assert rep.ordering_match
        assert rep.runtime_tasks == rep.sim_tasks > 0
        assert rep.max_release_diff == 0.0
        assert rep.max_start_diff == 0.0
        assert rep.max_finish_diff == 0.0
        assert rep.max_makespan_diff == 0.0
        assert rep.max_busy_diff == 0.0


@pytest.mark.parametrize("arrival_kind", ["jittered", "poisson"])
def test_validate_on_runtime_nonperiodic_zero_diff(arrival_kind):
    """The conformance path honors the scenario's arrival process: the
    virtual runtime and the simulator replay the same bursty sources and
    still diff to zero (measured conditions: noise + dispatch tokens)."""
    from repro.core import ArrivalSpec

    an = _analyzer(arrival=ArrivalSpec(kind=arrival_kind, jitter=0.5,
                                       seed=13))
    for sol in _solutions(an.scenario.graphs, 2, seed=8):
        rep = an.validate_on_runtime(sol, alpha=1.0, num_requests=8,
                                     measured=True, seed=6)
        assert rep.passed, rep.summary()
        assert rep.ordering_match
    # the replay really used the bursty sources: group-0 arrivals in the
    # runtime trace are not equally spaced
    arrivals = [r[2] for r in rep.runtime_trace["requests"] if r[0] == 0]
    gaps = {round(b - a, 12) for a, b in zip(arrivals, arrivals[1:])}
    assert len(gaps) > 1, "conformance replay ignored the arrival spec"


def test_validate_on_runtime_overload_drops_match():
    """Dropped requests (overload) must drop identically on both sides."""
    an = _analyzer(groups=((0, 1),))
    sol = _solutions(an.scenario.graphs, 1, seed=4)[0]
    # everything cut apart and pinned to one processor: maximal queueing
    sol.partition = [[1] * g.num_edges for g in an.scenario.graphs]
    sol.mapping = [[0] * g.num_layers for g in an.scenario.graphs]
    rep = an.validate_on_runtime(sol, alpha=0.001, num_requests=700,
                                 measured=True, seed=1)
    assert rep.passed, rep.summary()
    dropped = [m for m in rep.sim_trace["makespans"] if m is None]
    assert dropped, "overload scenario dropped nothing; not exercising drops"
    assert rep.runtime_trace["makespans"] == rep.sim_trace["makespans"]


def test_conformance_trace_uses_golden_schema():
    an = _analyzer()
    sol = _solutions(an.scenario.graphs, 1)[0]
    rep = an.validate_on_runtime(sol, num_requests=4)
    for trace in (rep.runtime_trace, rep.sim_trace):
        assert set(trace) == {"horizon", "busy_time", "requests",
                              "makespans", "tasks"}
        assert all(len(t) == 11 for t in trace["tasks"])
        assert all(len(r) == 7 for r in trace["requests"])
    doc = rep.to_json()
    assert doc["passed"] is True
    assert "runtime_trace" in doc and "sim_trace" in doc
    assert "runtime_trace" not in rep.to_json(include_traces=False)


def test_build_report_detects_divergence():
    """A perturbed trace must fail the zero-tolerance comparison."""
    from repro.runtime.conformance import build_report
    an = _analyzer()
    sol = _solutions(an.scenario.graphs, 1, seed=9)[0]
    a = an.simulate(sol, 1.0, 6, collect_tasks=True)
    b = an.simulate(sol, 1.0, 6, collect_tasks=True)
    ok = build_report("virtual", a, b)
    assert ok.passed
    b.tasks[3].started += 1e-9
    bad = build_report("virtual", a, b)
    assert not bad.passed
    assert bad.max_start_diff > 0


# -- measured-cost feedback ---------------------------------------------------

def test_apply_measured_costs_invalidates_and_changes_objectives():
    an = _analyzer()
    sol = _solutions(an.scenario.graphs, 1, seed=5)[0]
    before = an.objectives(sol, num_requests=8)
    placed = decode_solution(sol, an.scenario.graphs)
    key = placed[0][0].profile_key()
    old = an.profiler.db.get(key)
    assert old is not None  # profiled during the first evaluation

    # same value -> no invalidation, caches stay warm
    assert an.apply_measured_costs({key: old}) == 0
    hits_before = an.objective_cache_hits
    assert an.objectives(sol, num_requests=8) == before
    assert an.objective_cache_hits == hits_before + 1

    # measured value 10x slower -> caches flushed, objectives move
    assert an.apply_measured_costs({key: old * 10.0}) == 1
    assert an.profiler.db.get(key) == old * 10.0
    after = an.objectives(sol, num_requests=8)
    assert after != before
    assert sum(after) > sum(before)

    # and the new objectives equal a fresh analyzer over the updated DB
    fresh = _analyzer()
    fresh.profiler.db.update(key, old * 10.0)
    assert fresh.objectives(sol, num_requests=8) == after


def test_apply_measured_costs_only_affected_solutions_change():
    an = _analyzer()
    sols = _solutions(an.scenario.graphs, 6, seed=7)
    before = [an.objectives(s, num_requests=6) for s in sols]
    # perturb one profile key used by sols[0]
    placed = decode_solution(sols[0], an.scenario.graphs)
    key = placed[1][0].profile_key()
    old = an.profiler.db.get(key)
    an.apply_measured_costs({key: old * 7.5})
    after = [an.objectives(s, num_requests=6) for s in sols]
    uses = [key in {p.profile_key()
                    for plist in decode_solution(s, an.scenario.graphs)
                    for p in plist} for s in sols]
    for u, b, a in zip(uses, before, after):
        if u:
            assert a != b
        else:
            assert a == b  # untouched keys re-derive identical costs


def test_conformance_holds_after_measured_update():
    """The virtual runtime replays whatever costs the analyzer now holds —
    conformance is preserved across feedback rounds."""
    an = _analyzer()
    sol = _solutions(an.scenario.graphs, 1, seed=8)[0]
    placed = decode_solution(sol, an.scenario.graphs)
    key = placed[0][0].profile_key()
    an.objectives(sol)  # populate DB
    an.apply_measured_costs({key: an.profiler.db.get(key) * 3.0})
    rep = an.validate_on_runtime(sol, num_requests=8, measured=True)
    assert rep.passed, rep.summary()


def test_ga_device_in_loop_interval_reranks():
    """Measurement rounds flush the GA's fitness memo and re-rank on the
    fed-back costs (stubbed measurement: no real execution needed)."""
    an = _analyzer(ga=GAConfig(pop_size=8, max_generations=6,
                               min_generations=6, patience=99, seed=3,
                               device_in_loop_interval=2))
    factor = [2.0]

    def fake_measure(front):
        total = 0
        for s in front[:1]:
            placed = decode_solution(s, an.scenario.graphs)
            key = placed[0][0].profile_key()
            old = an.profiler.db.get(key)
            if old is None:
                continue
            total += an.apply_measured_costs({key: old * factor[0]})
            factor[0] *= 1.5
        return total

    sched = GeneticScheduler(
        factory=an.factory,
        evaluate_fast=lambda s: an.objectives(s, num_requests=6),
        config=an.cfg.ga,
        measure_device=fake_measure,
    )
    res = sched.run(seeds=_solutions(an.scenario.graphs, 4, seed=1))
    assert res.device_updates, "no measurement round updated the DB"
    gens = [g for g, _ in res.device_updates]
    assert all(g % 2 == 0 for g in gens)
    # population fitness was recomputed on the updated costs
    for s in res.pareto:
        assert s.fitness == an.objectives(s, num_requests=6)


def test_rerank_pareto_refreshes_fitness():
    an = _analyzer()
    sols = _solutions(an.scenario.graphs, 5, seed=12)
    for s in sols:
        s.fitness = an.objectives(s, num_requests=6)
    placed = decode_solution(sols[0], an.scenario.graphs)
    key = placed[0][0].profile_key()
    an.apply_measured_costs({key: an.profiler.db.get(key) * 20.0})
    front = an.rerank_pareto(sols, num_requests=8)
    assert front and all(any(f is s for s in sols) for f in front)
    for s in sols:
        assert s.fitness == an.objectives(s, num_requests=8, measured=True)


# -- sweep integration --------------------------------------------------------

def test_sweep_validate_runtime_records_conformance(tmp_path):
    from repro.experiments import (
        ScenarioResult, SweepConfig, evaluate_scenario,
        generate_scenario_specs,
    )
    spec = generate_scenario_specs(1, seed=3)[0]
    config = SweepConfig(pop_size=6, max_generations=4, min_generations=2,
                         bm_max_evals=20, satisfaction_requests=10,
                         validate_runtime=True)
    result = evaluate_scenario(spec, config)
    assert result.runtime_conformance is not None
    assert result.runtime_conformance["passed"] is True
    assert result.runtime_conformance["max_release_diff"] == 0.0
    # round-trips through JSON
    doc = result.to_json()
    assert doc["runtime_conformance"]["passed"] is True
    back = ScenarioResult.from_json(doc)
    assert back.runtime_conformance == result.runtime_conformance
    # and the default config records nothing
    result2 = evaluate_scenario(
        spec, SweepConfig(pop_size=6, max_generations=4, min_generations=2,
                          bm_max_evals=20, satisfaction_requests=10))
    assert result2.runtime_conformance is None
    assert "runtime_conformance" not in result2.to_json()
