"""Fault recovery: dropout → backup remap, straggler retries, worker
hardening (graceful degradation on top of the raw fault layer).

The raw fault layer is parity-tested in test_fault_differential /
test_golden_traces; everything here runs with a RecoveryPolicy, which is
explicitly *not* bit-comparable to the simulator tiers (retries and remaps
consume extra stream draws). Assertions are therefore behavioural:
requests survive, events are recorded, placements move off dead
processors, worker threads stay alive.
"""
import math
import random
import threading

import pytest

from repro.core import (
    PAPER_COMM_MODEL,
    FaultSpec,
    Profiler,
    SolutionFactory,
    build_spec,
    decode_solution,
    mobile_processors,
)
from repro.core.analyzer import StaticAnalyzer
from repro.core.graph import branching_graph, chain_graph
from repro.core.profiler import AnalyticMobileBackend
from repro.core.scenarios import Scenario
from repro.runtime import (
    PuzzleRuntime,
    RecoveryPolicy,
    RuntimeConfig,
    Worker,
    WorkerExecutionError,
    greedy_remap,
)
from repro.runtime.tensorpool import SharedBufferTransport, TensorPool

PROCS = mobile_processors()
PROFILER = Profiler(AnalyticMobileBackend(PROCS))


def _nets():
    return [
        chain_graph("ra", [("conv", 4e6, 1000, 4000)] * 5),
        branching_graph("rb", [("conv", 2e6, 800, 2000)] * 4,
                        [(0, 1), (0, 2), (1, 3), (2, 3)]),
        chain_graph("rc", [("fc", 8e6, 2000, 8000)] * 3),
    ]


def _solution_using(nets, pid, seed0=0):
    """First SolutionFactory draw that places work on ``pid``."""
    for seed in range(seed0, seed0 + 64):
        fac = SolutionFactory(nets, num_processors=len(PROCS),
                              rng=random.Random(seed), cut_prob=0.4)
        sol = fac.random_solution()
        if any(p.processor == pid
               for pl in decode_solution(sol, nets) for p in pl):
            return sol
    raise AssertionError(f"no draw uses pid {pid}")


def _runtime(nets, sol, faults, recovery):
    spec = build_spec(decode_solution(sol, nets), PROCS, PROFILER,
                      PAPER_COMM_MODEL)
    return PuzzleRuntime(
        nets, sol, PROCS,
        config=RuntimeConfig(virtual=True, faults=faults, recovery=recovery),
        spec=spec,
    ), spec


GROUPS, PERIODS, NR = [[0, 1], [2]], [0.004, 0.006], 8
DROPOUT = FaultSpec(dropouts=((2, 0.010, None),), seed=5)


# -- dropout → remap ---------------------------------------------------------

def test_dropout_remap_keeps_inflight_requests():
    """The acceptance scenario: a mid-run permanent dropout with recovery
    enabled loses zero requests, while the same run without recovery drops
    every request that needs the dead processor."""
    nets = _nets()
    sol = _solution_using(nets, pid=2)

    rt_raw, _ = _runtime(nets, sol, DROPOUT, recovery=None)
    with rt_raw:
        raw = rt_raw.run_periodic(GROUPS, PERIODS, num_requests=NR)
    dropped_raw = sum(st.makespan is None for gl in raw for st in gl)
    assert dropped_raw > 0, "scenario must actually lose requests raw"

    rt, _ = _runtime(nets, sol, DROPOUT, recovery=RecoveryPolicy())
    with rt:
        res = rt.run_periodic(GROUPS, PERIODS, num_requests=NR)
    assert all(st.makespan is not None for gl in res for st in gl)
    remaps = [e for e in rt.recovery_events if e.kind == "remap"]
    assert len(remaps) == 1 and remaps[0].pid == 2
    assert remaps[0].time == 0.010
    # nothing starts on the dead processor after the drop instant
    for rec in rt.coordinator.trace:
        if rec.processor == 2 and rec.started is not None:
            assert rec.started <= 0.010
    # the placement itself was rewired off the dead pid
    assert all(p.processor != 2 for pl in rt.placed for p in pl)


def test_dropout_remap_uses_registered_backup():
    nets = _nets()
    sol = _solution_using(nets, pid=2)
    sc = Scenario(name="rt-backup", graphs=tuple(nets), groups=((0, 1), (2,)))
    an = StaticAnalyzer(sc, PROCS, PROFILER, PAPER_COMM_MODEL)
    backup_sol, remap = an.backup_mapping(sol, dead_pid=2)
    assert remap and all(pid != 2 for pid in remap.values())
    bspec = build_spec(decode_solution(backup_sol, nets), PROCS, PROFILER,
                       PAPER_COMM_MODEL)

    rt, _ = _runtime(nets, sol, DROPOUT, recovery=RecoveryPolicy())
    rt.set_backup(2, remap, spec=bspec)
    with rt:
        res = rt.run_periodic(GROUPS, PERIODS, num_requests=NR)
    assert all(st.makespan is not None for gl in res for st in gl)
    ev = [e for e in rt.recovery_events if e.kind == "remap"][0]
    assert ev.detail["backup"] == "registered"
    # the backup spec's rows now override the primary costs for exactly
    # the remapped subgraphs
    src = rt._cost_source
    assert set(src.override) == {bspec.offsets[n] + k for n, k in remap}
    for (n, k), new_pid in remap.items():
        assert rt.placed[n][k].processor == new_pid


def test_set_backup_rejects_remap_onto_dead_pid():
    nets = _nets()
    sol = _solution_using(nets, pid=2)
    rt, _ = _runtime(nets, sol, DROPOUT, recovery=RecoveryPolicy())
    with rt:
        with pytest.raises(ValueError):
            rt.set_backup(2, {(0, 0): 2})


def test_stall_intercept_reroutes_without_scheduled_remap():
    """Belt-and-braces path: if the dropout handler did NOT fire first
    (here: forcibly unscheduled), a task delivered onto the dead processor
    is intercepted mid-stall, triggers the remap, and is re-routed — the
    request still completes."""
    nets = _nets()
    sol = _solution_using(nets, pid=2)
    rt, _ = _runtime(nets, sol, DROPOUT, recovery=RecoveryPolicy())
    # at construction time the only scheduled events are the dropout
    # handlers — drop them to force deliveries onto the dead pid
    assert rt.clock.pending == 1
    rt.clock._events.clear()
    with rt:
        res = rt.run_periodic(GROUPS, PERIODS, num_requests=NR)
    assert all(st.makespan is not None for gl in res for st in gl)
    remaps = [e for e in rt.recovery_events if e.kind == "remap"]
    assert len(remaps) == 1 and remaps[0].time >= 0.010


def test_no_survivors_degrades_without_livelock():
    """A dropout with no surviving processor cannot be remapped: affected
    requests drop (exactly like the raw tiers), but the run terminates."""
    nets = _nets()[:1]
    one_proc = PROCS[:1]
    profiler = Profiler(AnalyticMobileBackend(one_proc))
    fac = SolutionFactory(nets, num_processors=1, rng=random.Random(1),
                          cut_prob=0.5)
    sol = fac.random_solution()
    spec = build_spec(decode_solution(sol, nets), one_proc, profiler,
                      PAPER_COMM_MODEL)
    faults = FaultSpec(dropouts=((0, 0.006, None),), seed=1)
    rt = PuzzleRuntime(
        nets, sol, one_proc,
        config=RuntimeConfig(virtual=True, faults=faults,
                             recovery=RecoveryPolicy()),
        spec=spec,
    )
    with rt:
        res = rt.run_periodic([[0]], [0.004], num_requests=6)
    dropped = sum(st.makespan is None for st in res[0])
    assert dropped > 0
    assert sum(st.makespan is not None for st in res[0]) > 0


def test_greedy_remap_deterministic_and_complete():
    nets = _nets()
    sol = _solution_using(nets, pid=2)
    placed = decode_solution(sol, nets)
    survivors = [0, 1]
    a = greedy_remap(placed, 2, survivors, load={0: 0.5})
    b = greedy_remap(placed, 2, survivors, load={0: 0.5})
    assert a == b
    owned = {(n, k) for n, pl in enumerate(placed)
             for k, p in enumerate(pl) if p.processor == 2}
    assert set(a) == owned
    assert all(pid in survivors for pid in a.values())
    with pytest.raises(ValueError):
        greedy_remap(placed, 2, [])


def test_backup_mapping_deterministic_and_excludes_dead():
    nets = _nets()
    sol = _solution_using(nets, pid=2)
    sc = Scenario(name="bm", graphs=tuple(nets), groups=((0, 1), (2,)))
    an = StaticAnalyzer(sc, PROCS, PROFILER, PAPER_COMM_MODEL)
    b1, r1 = an.backup_mapping(sol, dead_pid=2)
    b2, r2 = an.backup_mapping(sol, dead_pid=2)
    assert r1 == r2
    assert b1.mapping == b2.mapping
    assert all(pid != 2 for pid in r1.values())
    # backup shares partition/priority: only the mapping moved
    assert b1.partition == sol.partition
    assert b1.priority == sol.priority
    placed_b = decode_solution(b1, nets)
    assert all(p.processor != 2 for pl in placed_b for p in pl)


# -- straggler timeout + retry ----------------------------------------------

def test_straggler_retries_are_recorded_and_bounded():
    nets = _nets()
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(0), cut_prob=0.4).random_solution()
    faults = FaultSpec(straggler_prob=0.5, straggler_shape=0.8, seed=11)
    pol = RecoveryPolicy(max_retries=2, timeout_factor=3.0, min_timeout=1e-5)
    rt, _ = _runtime(nets, sol, faults, recovery=pol)
    with rt:
        res = rt.run_periodic(GROUPS, PERIODS, num_requests=NR)
    retries = [e for e in rt.recovery_events if e.kind == "retry"]
    assert retries, "heavy-tailed stragglers must trip the watchdog"
    per_task = {}
    for e in retries:
        key = (e.detail["request"], e.detail["net"], e.detail["sg"])
        per_task[key] = max(per_task.get(key, 0), e.detail["attempt"])
        assert e.detail["total_s"] > e.detail["timeout_s"]
    assert all(n <= pol.max_retries for n in per_task.values())
    # exhausted retries run to completion: recovery never drops work the
    # fault itself would not have dropped
    for gl in res:
        for st in gl:
            assert st.makespan is not None


def test_clean_run_with_recovery_has_no_events():
    nets = _nets()
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(0), cut_prob=0.4).random_solution()
    rt, _ = _runtime(nets, sol, None, recovery=RecoveryPolicy())
    with rt:
        res = rt.run_periodic(GROUPS, PERIODS, num_requests=NR)
    assert rt.recovery_events == []
    assert all(st.makespan is not None for gl in res for st in gl)


# -- robustness objective (analyzer side) ------------------------------------

def test_score_under_faults_reports_clean_vs_faulted():
    nets = _nets()
    sc = Scenario(
        name="suf", graphs=tuple(nets), groups=((0, 1), (2,)),
        faults=FaultSpec(dropouts=((2, 0.010, None),),
                         straggler_prob=0.2, straggler_shape=1.5, seed=7))
    an = StaticAnalyzer(sc, PROCS, PROFILER, PAPER_COMM_MODEL)
    sol = _solution_using(nets, pid=2)
    rep = an.score_under_faults(sol, num_requests=NR)
    for key in ("satisfaction_clean", "satisfaction_faulted", "score_clean",
                "score_faulted", "dropped_clean", "dropped_faulted",
                "satisfaction_delta", "score_delta"):
        assert key in rep
    assert 0.0 <= rep["satisfaction_clean"] <= 1.0
    assert 0.0 <= rep["satisfaction_faulted"] <= 1.0
    # a permanent dropout of a used processor must show up as damage
    assert rep["dropped_faulted"] > rep["dropped_clean"]
    assert rep["satisfaction_faulted"] <= rep["satisfaction_clean"]


# -- worker hardening (satellite: errors fail the request, not the thread) ---

def _real_worker(collected, event):
    """A threaded (real-mode) Worker with one stub engine."""
    class StubEngine:
        exec_times = {}

        def execute(self, key, inputs=None):
            if key != "good":
                raise KeyError(key)
            return 42

    def on_done(payload, result, quant_t, exec_t):
        collected.append(result)
        event.set()

    pool = TensorPool()
    w = Worker(1, "gpu", {"default": StubEngine()}, pool,
               SharedBufferTransport(pool), on_done)
    w.start()
    return w


def _payload(backend="default", engine_key="good"):
    return {"request": 0, "net": 3, "sg": 1, "dtype": "fp16",
            "backend": backend, "engine_key": engine_key, "inputs": None,
            "released": 0.0}


def test_unknown_backend_fails_task_not_thread():
    """Regression: the engine lookup used to sit outside the try block, so
    an unknown backend key raised in the exec thread's main loop and killed
    it — stranding the coordinator with a forever-pending future."""
    collected, event = [], threading.Event()
    w = _real_worker(collected, event)
    try:
        w.submit((0, 0, 1), _payload(backend="no-such-backend"))
        assert event.wait(5.0), "worker thread died instead of reporting"
        err = collected[-1]
        assert isinstance(err, WorkerExecutionError)
        for frag in ("net=3", "sg=1", "processor 1", "gpu",
                     "no-such-backend"):
            assert frag in str(err)
        assert w.threads_alive()
        # the worker keeps serving after the failure
        event.clear()
        w.submit((0, 0, 2), _payload())
        assert event.wait(5.0)
        assert collected[-1] == 42
    finally:
        w.stop()
    assert not w.threads_alive()


def test_unloaded_engine_key_fails_task_not_thread():
    collected, event = [], threading.Event()
    w = _real_worker(collected, event)
    try:
        w.submit((0, 0, 1), _payload(engine_key="never-loaded"))
        assert event.wait(5.0)
        err = collected[-1]
        assert isinstance(err, WorkerExecutionError)
        assert "net=3" in str(err) and "processor 1" in str(err)
        assert w.threads_alive()
    finally:
        w.stop()


def test_staging_error_fails_task_not_thread():
    collected, event = [], threading.Event()
    w = _real_worker(collected, event)
    try:
        bad = _payload()
        bad["inputs"] = [(object(), "fp32")]  # unconvertible tensor
        w.submit((0, 0, 1), bad)
        assert event.wait(5.0)
        err = collected[-1]
        assert isinstance(err, WorkerExecutionError)
        assert "staging" in str(err)
        assert w.threads_alive()
    finally:
        w.stop()


# -- measured-cost guard (satellite: partial/poisoned sample sets) -----------

def test_measured_costs_skips_unusable_samples():
    nets = _nets()
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(0)).random_solution()
    rt, _ = _runtime(nets, sol, None, recovery=None)
    with rt:
        eng = next(iter(rt.workers[0].engines.values()))
        eng.exec_times["empty"] = []
        eng.exec_times["poisoned"] = [math.inf, -1.0, 0.0]
        eng.exec_times["ok"] = [0.5, 0.3, math.nan, 0.4]
        costs = rt.measured_costs()
    assert "empty" not in costs and "poisoned" not in costs
    assert costs["ok"] == 0.3  # nan dropped, slowest-of-3 trimmed, median
    assert rt.measured_cost_skips == 2
