"""Sharding rules, input specs, and the HLO static analyzer."""
import jax
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch.hlo_analysis import analyze, parse_module
from repro.launch.shapes import INPUT_SHAPES, config_for_shape, input_specs
from repro.sharding.rules import batch_spec, spec_for_shape


@pytest.fixture(scope="module")
def mesh():
    # a virtual 16x16 mesh over abstract devices (no allocation)
    import numpy as np
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    return Mesh(devs, ("data", "model"))


def test_spec_ffn_shards_model(mesh):
    p = spec_for_shape(("embed", "ffn"), (4096, 27648), mesh)
    assert p == P("data", "model")


def test_spec_heads_divisible(mesh):
    p = spec_for_shape(("embed", "heads", "head_dim"), (8192, 64, 128), mesh)
    assert p == P("data", "model")


def test_spec_heads_not_divisible_replicates(mesh):
    # 40 heads % 16 != 0 -> heads AND head_dim stay unsharded (§Perf 2)
    p = spec_for_shape(("embed", "heads", "head_dim"), (5120, 40, 128), mesh)
    assert p == P("data")


def test_spec_vocab_not_divisible(mesh):
    p = spec_for_shape(("vocab", "embed"), (50280, 2048), mesh)
    # 50280 % 16 != 0 -> vocab unsharded; embed takes data
    assert p == P(None, "data")


def test_spec_layers_never_sharded(mesh):
    p = spec_for_shape(("layers", "experts", "embed", "ffn"),
                       (61, 384, 7168, 2048), mesh)
    assert p == P(None, "model", "data")


def test_batch_spec(mesh):
    assert batch_spec(mesh, 256) == P("data")
    assert batch_spec(mesh, 1) == P(None)
    assert batch_spec(mesh, 13) == P(None)


def test_input_specs_shapes():
    cfg = get_config("phi4-mini-3.8b")
    tr = input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert tr["tokens"].shape == (256, 4096)
    de = input_specs(cfg, INPUT_SHAPES["decode_32k"])
    assert de["token"].shape == (128, 1)
    # decode carries a cache pytree sized to seq_len
    leaves = jax.tree.leaves(de["caches"])
    assert any(leaf.shape[2] == 32768 for leaf in leaves
               if len(leaf.shape) == 5)


def test_long_context_gets_sliding_window():
    cfg = get_config("qwen3-14b")
    assert cfg.sliding_window is None
    adj = config_for_shape(cfg, INPUT_SHAPES["long_500k"])
    assert adj.sliding_window == 8192
    # SSM archs stay untouched (natively sub-quadratic)
    ssm = get_config("mamba2-1.3b")
    assert config_for_shape(ssm, INPUT_SHAPES["long_500k"]).sliding_window is None
    # windowed decode cache is a ring buffer of window size
    specs = input_specs(adj, INPUT_SHAPES["long_500k"])
    kv = [leaf for leaf in jax.tree.leaves(specs["caches"])
          if len(leaf.shape) == 5]
    assert all(leaf.shape[2] == 8192 for leaf in kv)


# -- HLO analyzer on a hand-written module ----------------------------------

HLO_SAMPLE = """
HloModule test

%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %w = f32[256,256]{1,0} constant({...})
  %d = f32[128,256]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256]{1,0} all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ni, %ar)
}

%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%z, %a)
  %w = (s32[], f32[128,256]) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"8"}}
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_trip_count_multiplies():
    stats = analyze(HLO_SAMPLE)
    # dot: 2 * 128*256 * 256 flops, times trip count 8
    assert stats.flops == pytest.approx(8 * 2 * 128 * 256 * 256)
    # all-reduce operand: 128*256*4 bytes, times 8
    assert stats.collective_bytes == pytest.approx(8 * 128 * 256 * 4)
    assert stats.collective_by_op["all-reduce"] == pytest.approx(8 * 128 * 256 * 4)


def test_hlo_parser_handles_tuple_params():
    comps, entry = parse_module(HLO_SAMPLE)
    assert entry == "main"
    assert "body.1" in comps
    assert any(i.opcode == "dot" for i in comps["body.1"].instrs)
