"""Training substrate: optimizers, data, checkpointing, end-to-end loss drop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.train import (
    DataConfig,
    MarkovDataset,
    TrainConfig,
    adafactor,
    adamw,
    make_optimizer,
    optimizer_for_config,
    restore_checkpoint,
    save_checkpoint,
    train,
)


# -- optimizers -------------------------------------------------------------

def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array(5.0)}


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(opt_name):
    init, update = make_optimizer(opt_name, lr=0.1)
    params = _quadratic_params()
    state = init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state = update(grads, state, params)
    assert float(loss(params)) < 0.05


def test_adamw_step_counts_and_shapes():
    init, update = adamw()
    params = {"a": jnp.ones((4, 8)), "b": jnp.zeros((3,))}
    state = init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    new_params, new_state = update(grads, state, params)
    assert int(new_state.step) == 1
    assert jax.tree.structure(new_params) == jax.tree.structure(params)
    assert new_params["a"].shape == (4, 8)


def test_adafactor_factored_state_is_small():
    init, _ = adafactor()
    params = {"w": jnp.ones((512, 256))}
    state = init(params)
    leaf = state.inner["w"]
    assert "vr" in leaf and "vc" in leaf and "v" not in leaf
    assert leaf["vr"].shape == (512,)
    assert leaf["vc"].shape == (256,)
    # factored state is ~2 orders smaller than the full second moment
    assert leaf["vr"].size + leaf["vc"].size < 512 * 256 / 100


def test_optimizer_for_config_picks_adafactor_for_1t():
    from repro.configs import get_config
    assert optimizer_for_config(get_config("kimi-k2-1t-a32b")) == "adafactor"
    assert optimizer_for_config(get_config("phi4-mini-3.8b")) == "adamw"


# -- data -----------------------------------------------------------------

def test_markov_dataset_deterministic_and_shaped():
    cfg = DataConfig(vocab_size=64, seq_len=16, batch_size=4, seed=3)
    d1, d2 = MarkovDataset(cfg), MarkovDataset(cfg)
    b1 = next(d1.batches())
    b2 = next(d2.batches())
    np.testing.assert_array_equal(b1[0], b2[0])
    tokens, labels = b1
    assert tokens.shape == (4, 16) and labels.shape == (4, 16)
    np.testing.assert_array_equal(tokens[:, 1:], labels[:, :-1])  # shifted
    assert 0 < d1.entropy() < np.log(64)


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 128), st.integers(0, 100))
def test_markov_tokens_in_range(vocab, seed):
    cfg = DataConfig(vocab_size=vocab, seq_len=8, batch_size=2, seed=seed)
    tokens, labels = next(MarkovDataset(cfg).batches())
    assert tokens.min() >= 0 and tokens.max() < vocab


# -- checkpointing ------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    params = {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
              "b": jnp.ones((2,), jnp.float32)}
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, params, opt, step=42, meta={"note": "x"})
    p2, o2, step, meta = restore_checkpoint(path, params, opt)
    assert step == 42 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


# -- end-to-end: the model learns the chain ---------------------------------

def test_training_reduces_loss():
    cfg = get_smoke_config("phi4-mini-3.8b")
    res = train(cfg, TrainConfig(steps=60, batch_size=8, seq_len=32,
                                 lr=3e-3, log_every=0))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.5, (first, last)
    assert last > res.loss_floor - 0.05  # can't beat the entropy floor


def test_training_checkpoint_resume(tmp_path):
    cfg = get_smoke_config("mamba2-1.3b")
    path = str(tmp_path / "ck.msgpack")
    train(cfg, TrainConfig(steps=20, batch_size=4, seq_len=32, lr=1e-3,
                           log_every=0, checkpoint_path=path,
                           checkpoint_every=20))
    assert os.path.exists(path)
    r2 = train(cfg, TrainConfig(steps=30, batch_size=4, seq_len=32, lr=1e-3,
                                log_every=0, checkpoint_path=path,
                                checkpoint_every=100))
    assert len(r2.losses) == 10  # resumed from step 20
