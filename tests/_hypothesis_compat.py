"""Use hypothesis when installed; otherwise a minimal deterministic fallback.

The offline CI image does not ship ``hypothesis``, which used to hard-error
test collection for every module importing it. This shim keeps the property
tests running either way: with hypothesis installed you get real shrinking
and edge-case generation; without it, each ``@given`` test runs a fixed
number of seeded-random examples (deterministic across runs, no shrinking).

Only the surface the test-suite uses is implemented: ``st.integers``,
``st.floats``, ``st.lists``, ``st.tuples``, ``st.data``, ``st.composite``,
``@settings(max_examples=..., deadline=...)``.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import zlib

    _FALLBACK_CAP = 30  # keep offline runs quick; hypothesis explores deeper

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def do_draw(self, rng):
            return self._draw(rng)

    class _DataObject:
        """Stand-in for ``st.data()`` draws inside the test body."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.do_draw(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            def draw(r):
                hi = max_size if max_size is not None else min_size + 10
                k = r.randint(min_size, hi)
                return [elements.do_draw(r) for _ in range(k)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda r: tuple(e.do_draw(r) for e in elems))

        @staticmethod
        def data():
            return _Strategy(_DataObject)

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                return _Strategy(
                    lambda r: fn(lambda s: s.do_draw(r), *args, **kwargs)
                )

            return make

    st = _Strategies()

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            base = zlib.crc32(fn.__qualname__.encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_fallback_max_examples", 20),
                        _FALLBACK_CAP)
                for example in range(n):
                    rng = random.Random(base * 1000003 + example)
                    vals = tuple(s.do_draw(rng) for s in strategies)
                    fn(*args, *vals, **kwargs)

            # hide the generated parameters from pytest's fixture resolution
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
