"""End-to-end behaviour of the Puzzle system (paper §6 protocol, reduced)."""
import pytest

from repro.core import (
    AnalyzerConfig,
    GAConfig,
    PAPER_COMM_MODEL,
    Profiler,
    StaticAnalyzer,
    TableBackend,
    build_scenario,
    decode_solution,
    mobile_processors,
    random_scenarios,
)
from repro.core.profiler import AnalyticMobileBackend
from repro.zoo import MODEL_NAMES, all_cost_graphs, paper_profile_tables


@pytest.fixture(scope="module")
def analyzer():
    graphs = all_cost_graphs()
    procs = mobile_processors()
    backend = TableBackend(
        processors=procs, tables=paper_profile_tables(),
        fallback=AnalyticMobileBackend(procs),
    )
    prof = Profiler(backend)
    scen = build_scenario(
        "e2e",
        [["face_det", "selfie_seg", "yolov8n", "fast_scnn", "pose_det", "hand_det"]],
        graphs,
    )
    cfg = AnalyzerConfig(ga=GAConfig(pop_size=16, max_generations=14, min_generations=6, seed=7))
    return StaticAnalyzer(scen, procs, prof, PAPER_COMM_MODEL, cfg)


def test_base_periods_formula(analyzer):
    # φ̄ = Σ min_p τ_p(m) × N × 1.1 with N=1
    s = sum(min(t for t, _, _ in bt.values()) for bt in analyzer.best_times)
    assert analyzer.base_periods[0] == pytest.approx(s * 1.1)


def test_npu_only_baseline_structure(analyzer):
    sol = analyzer.npu_only()
    placed = decode_solution(sol, analyzer.scenario.graphs)
    for plist in placed:
        assert len(plist) == 1           # un-partitioned
        assert plist[0].processor == 2   # NPU


def test_best_mapping_no_partitioning(analyzer):
    sols = analyzer.best_mapping(max_evals=40)
    assert sols
    for sol in sols:
        placed = decode_solution(sol, analyzer.scenario.graphs)
        assert all(len(p) == 1 for p in placed)


def test_ga_improves_over_npu_only(analyzer):
    res = analyzer.run_ga()
    assert res.pareto
    npu_obj = analyzer.objectives(analyzer.npu_only())
    best = min(res.pareto, key=lambda s: s.fitness[0])
    assert best.fitness[0] <= npu_obj[0]


def test_saturation_ordering_puzzle_vs_npu(analyzer):
    """The paper's headline: Puzzle sustains higher request frequency
    (lower α*) than NPU Only."""
    res = analyzer.run_ga()
    pz = analyzer.median_saturation(res.pareto)
    npu = analyzer.saturation(analyzer.npu_only()).alpha_star
    assert pz < npu
    assert pz < 2.0  # sane absolute range (paper: 0.78±0.08)


def test_score_monotone_in_alpha_roughly(analyzer):
    sol = analyzer.npu_only()
    s_tight = analyzer.score(sol, 0.4, measured=False)
    s_loose = analyzer.score(sol, 3.0, measured=False)
    assert s_loose >= s_tight


def test_random_scenarios_shapes():
    single = random_scenarios(MODEL_NAMES, count=10, models_per_scenario=6, num_groups=1)
    multi = random_scenarios(MODEL_NAMES, count=10, models_per_scenario=6, num_groups=2)
    assert len(single) == 10 and len(multi) == 10
    for s in single:
        assert len(s) == 1 and len(s[0]) == 6
        assert len(set(s[0])) == 6  # no duplicate models within scenario
    for s in multi:
        assert len(s) == 2 and all(len(g) == 3 for g in s)
