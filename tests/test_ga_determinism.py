"""GA end-to-end determinism across evaluation plumbing.

The search result must be a pure function of ``(scenario, GAConfig seed)``:
routing evaluations through the generation-batched engine (``batch_eval``),
sharding batches across worker processes (``batch_workers``), or changing
nothing at all and re-running must all produce the same ``GAResult`` —
history, Pareto front (chromosomes *and* fitnesses), generation count and
evaluation count.
"""
import random

from repro.core import (
    AnalyzerConfig,
    GAConfig,
    PAPER_COMM_MODEL,
    Profiler,
    StaticAnalyzer,
    branching_graph,
    build_scenario,
    chain_graph,
    mobile_processors,
)
from repro.core.profiler import AnalyticMobileBackend


def _nets():
    return [
        chain_graph("a", [("conv", 4e6, 1000, 4000)] * 5),
        branching_graph("b", [("conv", 2e6, 800, 2000)] * 4,
                        [(0, 1), (0, 2), (1, 3), (2, 3)]),
        chain_graph("c", [("fc", 8e6, 2000, 8000)] * 3),
        branching_graph("d", [("conv", 3e6, 500, 1500)] * 5,
                        [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)]),
    ]


def _analyzer(batch_eval=False, batch_workers=1, seed=3):
    nets = _nets()
    scen = build_scenario("det", [["a", "b"], ["c", "d"]],
                          {g.name: g for g in nets})
    procs = mobile_processors()
    prof = Profiler(AnalyticMobileBackend(procs))
    cfg = AnalyzerConfig(
        batch_workers=batch_workers,
        ga=GAConfig(pop_size=8, max_generations=4, min_generations=2,
                    seed=seed, batch_eval=batch_eval),
    )
    return StaticAnalyzer(scen, procs, prof, PAPER_COMM_MODEL, cfg)


def _fingerprint(result):
    return (
        result.history,
        [s.key() for s in result.pareto],
        [s.fitness for s in result.pareto],
        result.generations,
        result.evaluations,
        result.oracle_drift,
    )


def test_same_seed_same_result():
    assert _fingerprint(_analyzer().run_ga()) == \
        _fingerprint(_analyzer().run_ga())


def test_batch_eval_on_off_identical():
    base = _fingerprint(_analyzer(batch_eval=False).run_ga())
    batched = _fingerprint(_analyzer(batch_eval=True).run_ga())
    assert base == batched


def test_batch_workers_identical():
    """Sharding batch lanes across processes changes wall-clock only."""
    one = _analyzer(batch_eval=True, batch_workers=1)
    two = _analyzer(batch_eval=True, batch_workers=2)
    try:
        assert _fingerprint(one.run_ga()) == _fingerprint(two.run_ga())
    finally:
        one.close()
        two.close()


def test_distinct_seeds_diverge():
    """Sanity: the fingerprint actually discriminates different searches."""
    a = _fingerprint(_analyzer(seed=3).run_ga())
    b = _fingerprint(_analyzer(seed=4).run_ga())
    assert a != b


def test_objectives_batch_matches_scalar_loop():
    an = _analyzer()
    an.factory.rng = random.Random(99)
    sols = [an.factory.random_solution() for _ in range(12)]
    # include chromosome-level duplicates: dedup must not reorder results
    sols = sols + [sols[0].copy(), sols[5].copy()]
    for measured in (False, True):
        fresh = _analyzer()
        batch = an.objectives_batch(sols, measured=measured)
        scalar = [fresh.objectives(s, measured=measured) for s in sols]
        assert batch == scalar


def test_population_saturation_matches_scalar_loop():
    an = _analyzer()
    an.factory.rng = random.Random(42)
    sols = [an.factory.random_solution() for _ in range(5)]
    fresh = _analyzer()
    batched = an.population_saturation(sols)
    scalar = [fresh.saturation(s) for s in sols]
    assert [b.alpha_star for b in batched] == [s.alpha_star for s in scalar]
    assert [b.scores for b in batched] == [s.scores for s in scalar]
