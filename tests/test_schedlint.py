"""Static schedule analyzer (repro.analysis): unit + soundness differential.

Three layers of defense:

1. **Per-code unit tests** — every ``SL0xx`` diagnostic fires on a
   hand-built trigger and stays silent on the corresponding clean input.
2. **Soundness differential** — 100+ random chromosomes across randomized
   scenarios (noise, faults, bursty arrivals): wherever the analyzer
   *proves* infeasibility, the simulator must agree — every α below
   ``alpha_lower_bound`` scores below the saturation threshold, every
   SL030/SL031 finding coincides with a sub-threshold score, and every
   SL020 finding coincides with a real ``TensorPoolOOM`` when the schedule
   is provisioned through a capacity-bounded pool (and conversely: no
   finding ⇒ provisioning succeeds). Zero false positives tolerated.
3. **GA determinism** — with nothing provable, ``prescreen`` on/off GA
   runs are bit-identical (fronts, history, evaluation counts); with a
   memory budget, pruned chromosomes never reach the front and every
   front survivor actually provisions.
"""
import dataclasses
import json
import math
import random

import pytest

from repro.analysis import (
    CODES,
    Diagnostic,
    LintReport,
    ScheduleLinter,
    memory_lower_bounds,
    provision_memory,
    structural_diagnostics,
)
from repro.core import (
    ArrivalSpec,
    FaultSpec,
    PAPER_COMM_MODEL,
    Profiler,
    SolutionFactory,
    chain_graph,
    mobile_processors,
)
from repro.core.analyzer import (
    PRESCREEN_OBJECTIVE,
    AnalyzerConfig,
    StaticAnalyzer,
)
from repro.core.ga import GAConfig
from repro.core.graph import Subgraph, partition_quotient, quotient_is_acyclic
from repro.core.memlayout import CHUNK, rounded_chunk_bytes
from repro.core.profiler import AnalyticMobileBackend
from repro.core.scenarios import Scenario
from repro.core.scoring import ALPHA_GRID

from test_batchsim_properties import _random_problem

PROCS = mobile_processors()
PROFILER = Profiler(AnalyticMobileBackend(PROCS))
THRESHOLD = 0.995


def _nets():
    return (
        chain_graph("alpha", [("conv", 4e6, 1000, 4000)] * 4),
        chain_graph("beta", [("fc", 8e6, 2000, 8000)] * 3),
    )


def _analyzer(nets=None, groups=((0,), (1,)), processors=None, faults=None,
              arrival=None, **cfg):
    nets = nets if nets is not None else _nets()
    scenario = Scenario(name="lint_test", graphs=tuple(nets),
                        groups=tuple(tuple(g) for g in groups),
                        arrival=arrival, faults=faults)
    return StaticAnalyzer(
        scenario, list(processors if processors is not None else PROCS),
        PROFILER, PAPER_COMM_MODEL, AnalyzerConfig(**cfg))


def _solution(nets, seed=0, cut_prob=0.35):
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(seed), cut_prob=cut_prob)
    return fac.random_solution()


# -- diagnostics plumbing ----------------------------------------------------

def test_diagnostic_rejects_unknown_code_and_severity():
    with pytest.raises(ValueError):
        Diagnostic(code="SL999", severity="error", message="x")
    with pytest.raises(ValueError):
        Diagnostic(code="SL001", severity="fatal", message="x")


def test_lint_report_json_round_trip():
    rep = LintReport(alpha_lower_bound=1.25, checked_alpha=0.8)
    rep.extend([
        Diagnostic(code="SL020", severity="error", message="oom",
                   location=(("processor", 2),), proof=True),
        Diagnostic(code="SL010", severity="warning", message="fallback",
                   location=(("net", 0), ("processor", 2))),
    ])
    doc = json.loads(json.dumps(rep.to_json()))
    back = LintReport.from_json(doc)
    assert back.to_json() == rep.to_json()
    assert back.infeasible and rep.infeasible
    assert back.counts() == {"SL010": 1, "SL020": 1}
    assert [d.code for d in back.errors()] == ["SL020"]


def test_alpha_scoped_proof_is_not_schedule_infeasibility():
    rep = LintReport()
    rep.extend([Diagnostic(code="SL030", severity="error", message="miss",
                           location=(("alpha", 0.5), ("group", 0)),
                           proof=True)])
    assert not rep.infeasible  # only that (solution, α) pair is dead


def test_every_code_is_documented():
    assert set(CODES) == {"SL001", "SL002", "SL003", "SL004", "SL010",
                          "SL020", "SL030", "SL031"}


# -- SL001/SL002: structural -------------------------------------------------

def test_sl001_quotient_cycle():
    g = chain_graph("c", [("conv", 1e6, 100, 400)] * 3)
    # layers {0, 2} vs {1}: edge 0→1 crosses A→B, edge 1→2 crosses B→A
    sgs = [Subgraph(graph=g, layer_ids=(0, 2), sg_index=0),
           Subgraph(graph=g, layer_ids=(1,), sg_index=1)]
    _owner, edges, problems = partition_quotient(g, sgs)
    assert not problems and not quotient_is_acyclic(len(sgs), edges)
    diags = structural_diagnostics(g, sgs, net=3)
    assert [d.code for d in diags] == ["SL001"]
    assert diags[0].proof and diags[0].where() == {"net": 3}


def test_sl002_unowned_and_duplicated_layers():
    g = chain_graph("c", [("conv", 1e6, 100, 400)] * 3)
    missing = [Subgraph(graph=g, layer_ids=(0, 1), sg_index=0)]
    codes = [d.code for d in structural_diagnostics(g, missing)]
    assert codes and set(codes) == {"SL002"}
    dup = [Subgraph(graph=g, layer_ids=(0, 1), sg_index=0),
           Subgraph(graph=g, layer_ids=(1, 2), sg_index=1)]
    codes = [d.code for d in structural_diagnostics(g, dup)]
    assert codes and set(codes) == {"SL002"}


def test_structural_clean_on_real_partitions():
    nets = _nets()
    an = _analyzer(nets)
    for seed in range(5):
        placed = an.linter().builder.decode(_solution(nets, seed=seed))
        for net, g in enumerate(nets):
            assert structural_diagnostics(
                g, [p.subgraph for p in placed[net]], net) == []


# -- SL003/SL004: chromosome shape -------------------------------------------

def test_sl003_wrong_lengths_and_ranges():
    nets = _nets()
    an = _analyzer(nets)
    linter = an.linter()
    sol = _solution(nets)
    sol.mapping = [row[:-1] for row in sol.mapping]  # truncate every net
    rep = linter.lint(sol)
    assert {d.code for d in rep.findings} == {"SL003"}
    assert rep.infeasible

    sol = _solution(nets)
    sol.mapping[0][0] = len(PROCS)  # out-of-range processor
    assert {d.code for d in an.linter().lint(sol).findings} == {"SL003"}

    sol = _solution(nets)
    sol.dtype = list(sol.dtype)
    sol.dtype[1] = 99
    assert {d.code for d in an.linter().lint(sol).findings} == {"SL003"}


def test_sl004_priority_not_permutation():
    nets = _nets()
    an = _analyzer(nets)
    sol = _solution(nets)
    sol.priority = [0, 0]
    rep = an.linter().lint(sol)
    assert {d.code for d in rep.findings} == {"SL004"}
    assert rep.infeasible


# -- SL010: capability -------------------------------------------------------

def test_sl010_npu_fp32_is_warning_not_proof():
    nets = _nets()
    an = _analyzer(nets)
    sol = an.factory.seeded_solution(2)
    sol.dtype = [0] * len(nets)     # force fp32/default onto the NPU:
    sol.backend = [0] * len(nets)   # unsupported -> capability warning
    rep = an.linter().lint(sol)
    w = rep.by_code("SL010")
    assert len(w) == len(nets) and all(d.severity == "warning" for d in w)
    assert not rep.infeasible
    # the simulator happily scores it (fallback penalty), so no prune
    assert an.prescreen_objectives(sol) is None
    assert an.score(sol, 6.0) > 0.0


def test_sl010_silent_on_supported_config():
    nets = _nets()
    an = _analyzer(nets)
    sol = an.factory.seeded_solution(0)  # CPU supports fp32/default
    assert an.linter().lint(sol).by_code("SL010") == []


# -- SL020: memory ------------------------------------------------------------

def test_memory_bound_matches_pool_provisioning_exactly():
    nets = _nets()
    an = _analyzer(nets)
    for seed in range(8):
        sol = _solution(nets, seed=seed)
        placed = an.linter().builder.decode(sol)
        bounds = memory_lower_bounds(placed)
        assert bounds  # something is always placed somewhere
        for pid, (weights, arena) in bounds.items():
            assert weights % CHUNK == 0 and arena % CHUNK == 0
            need = weights + arena
            assert provision_memory(placed, {pid: need}) == {pid: True}
            assert provision_memory(placed, {pid: need - 1}) == {pid: False}


def test_sl020_fires_iff_capacity_exceeded():
    nets = _nets()
    an = _analyzer(nets)
    sol = _solution(nets, seed=3)
    linter = an.linter()
    placed = linter.builder.decode(sol)
    bounds = memory_lower_bounds(placed)
    pid, (weights, arena) = sorted(bounds.items())[0]
    need = weights + arena

    tight = ScheduleLinter.from_analyzer(an)
    tight._capacity[pid] = need - 1
    rep = tight.lint(sol)
    oom = rep.by_code("SL020")
    assert len(oom) == 1 and oom[0].proof and rep.infeasible
    assert oom[0].where()["processor"] == pid

    exact = ScheduleLinter.from_analyzer(an)
    exact._capacity[pid] = need
    assert exact.lint(sol).by_code("SL020") == []


def test_processor_memory_capacity_flows_into_linter():
    nets = _nets()
    procs = [dataclasses.replace(p, memory_capacity=CHUNK) if p.pid == 2
             else p for p in PROCS]
    an = _analyzer(nets, processors=procs)
    assert an.linter().capacities()[2] == CHUNK
    sol = an.factory.seeded_solution(2)  # everything on the NPU: way over
    rep = an.linter().lint(sol)
    assert rep.by_code("SL020") and rep.infeasible
    obj = an.prescreen_objectives(sol)
    assert obj == (PRESCREEN_OBJECTIVE,) * (2 * an.scenario.num_groups)


def test_rounded_chunk_bytes():
    assert rounded_chunk_bytes(0) == CHUNK
    assert rounded_chunk_bytes(1) == CHUNK
    assert rounded_chunk_bytes(CHUNK) == CHUNK
    assert rounded_chunk_bytes(CHUNK + 1) == 2 * CHUNK


# -- SL030/SL031: deadline proofs ---------------------------------------------

def test_sl030_overloaded_scenario_proof_agrees_with_simulator():
    nets = _nets()
    an = _analyzer(nets)
    an.base_periods = [p / 50.0 for p in an.base_periods]  # hopeless rate
    sol = an.factory.seeded_solution(0)
    rep = an.lint(sol, alpha=1.0)
    assert rep.by_code("SL030"), "overload must be provable"
    assert rep.alpha_lower_bound > 1.0
    assert not rep.infeasible  # α-scoped: some larger α may be fine
    assert an.score(sol, 1.0) < THRESHOLD


def test_sl031_window_bound_counts_all_groups_work():
    nets = _nets()
    an = _analyzer(nets, groups=((0, 1),))
    an.base_periods = [p / 50.0 for p in an.base_periods]
    sol = an.factory.seeded_solution(0)  # serialize everything on the CPU
    rep = an.lint(sol, alpha=1.0)
    assert rep.by_code("SL031")
    assert an.score(sol, 1.0) < THRESHOLD


def test_deadline_proofs_silent_when_feasible():
    nets = _nets()
    an = _analyzer(nets)
    sol = an.factory.seeded_solution(2)
    sat = an.saturation(sol)
    assert math.isfinite(sat.alpha_star)
    rep = an.lint(sol, alpha=sat.alpha_star)
    assert rep.by_code("SL030") == [] and rep.by_code("SL031") == []
    assert rep.alpha_lower_bound <= sat.alpha_star


def test_group_proof_guard_disables_weak_templates():
    nets = _nets()
    an = _analyzer(nets)
    linter = an.linter()
    linter.threshold = 0.5  # 2 groups: (N-1)/N = 0.5 is NOT < threshold
    spec = an.solution_spec(an.factory.seeded_solution(0))
    assert linter.alpha_lower_bound(spec) == 0.0
    assert linter.deadline_diagnostics(spec, 1e-9) == []


def test_exec_floor_clean_and_noise_and_throttle():
    nets = _nets()
    an = _analyzer(nets)
    linter = an.linter()
    assert linter.exec_floor(measured=False) == 1.0
    noisy = linter.exec_floor(measured=True)
    assert 0.0 < noisy < 1.0  # cpu σ=0.22 makes sub-1 multipliers certain

    speedup = FaultSpec(throttles=((0, 0.0, 10.0, 0.25),))
    an2 = _analyzer(nets, faults=speedup)
    # a <1 throttle factor is a speedup window: the floor must shrink
    assert an2.linter().exec_floor(measured=True) == pytest.approx(
        noisy * 0.25)
    assert an2.linter().exec_floor(measured=False) == 0.25


# -- α floor ↔ bisection skip --------------------------------------------------

def test_alpha_floor_skip_preserves_alpha_star():
    nets = _nets()
    for pid in (1, 2):
        sols = []
        sats = {}
        for prescreen in (False, True):
            an = _analyzer(nets, prescreen=prescreen)
            sol = an.factory.seeded_solution(pid)
            sols.append(sol)
            sats[prescreen] = an.saturation(sol)
        assert sats[False].alpha_star == sats[True].alpha_star


def test_population_saturation_matches_scalar_with_prescreen():
    nets = _nets()
    an = _analyzer(nets, prescreen=True)
    sols = [an.factory.seeded_solution(p.pid) for p in PROCS]
    batched = an.population_saturation(sols)
    scalar = [an.saturation(s) for s in sols]
    assert [b.alpha_star for b in batched] == [s.alpha_star for s in scalar]


# -- soundness differential ----------------------------------------------------

def _lattice_below(lb, k=3):
    """Up to ``k`` lattice α values just below ``lb`` (the tightest ones)."""
    below = [a for a in ALPHA_GRID if a < lb]
    return below[-k:]


def test_soundness_differential_sweep():
    """100+ random chromosomes: every proof the analyzer emits must be
    confirmed by the simulator / the capacity-bounded TensorPool."""
    rng = random.Random(20250808)
    chromosomes = 0
    deadline_proof_checks = 0
    memory_checks = 0
    while chromosomes < 104:
        nets, groups, periods = _random_problem(rng)
        arrival = None
        if rng.random() < 0.3:
            arrival = ArrivalSpec(
                kind=rng.choice(["jittered", "poisson"]),
                jitter=0.25, seed=rng.randrange(1 << 20))
        faults = None
        if rng.random() < 0.3:
            faults = FaultSpec(
                throttles=((rng.randrange(3), 0.0, rng.uniform(0.01, 1.0),
                            rng.choice([0.5, 2.0, 3.0])),),
                straggler_prob=rng.choice([0.0, 0.2]),
                straggler_shape=1.5, seed=rng.randrange(1 << 20))
        an = _analyzer(nets, groups=groups, arrival=arrival, faults=faults,
                       prescreen=True)
        an.base_periods = list(periods)  # decouple from derived periods
        linter = an.linter()
        fac = SolutionFactory(nets, num_processors=len(PROCS),
                              rng=random.Random(rng.randrange(1 << 30)),
                              cut_prob=rng.uniform(0.1, 0.5))
        for _ in range(4):
            sol = fac.random_solution()
            chromosomes += 1
            spec = an.solution_spec(sol)

            # (a) α lower bound: every lattice point below it must score
            # below the saturation threshold
            lb = linter.alpha_lower_bound(spec)
            for alpha in _lattice_below(lb):
                assert an.score(sol, alpha) < THRESHOLD, (
                    f"false α proof: lb={lb}, α={alpha}")
                deadline_proof_checks += 1

            # (b) per-α deadline findings at arbitrary probes
            for alpha in (0.5, 1.0, 2.0):
                if linter.deadline_diagnostics(spec, alpha):
                    assert an.score(sol, alpha) < THRESHOLD, (
                        f"false SL030/SL031 at α={alpha}")
                    deadline_proof_checks += 1

            # (c) memory: the analytic bound must agree with real
            # provisioning through a capacity-bounded pool, both ways
            placed = linter.builder.decode(sol)
            bounds = memory_lower_bounds(placed)
            pid = rng.choice(sorted(bounds))
            need = sum(bounds[pid])
            for cap, expect_ok in ((need, True), (need - 1, False),
                                   (rng.randrange(CHUNK, need + CHUNK),
                                    None)):
                ok = provision_memory(placed, {pid: cap})[pid]
                if expect_ok is not None:
                    assert ok is expect_ok
                probe = ScheduleLinter.from_analyzer(an)
                probe._capacity = {pid: cap}
                flagged = bool(probe.memory_diagnostics(placed))
                assert flagged == (not ok), (
                    f"SL020 disagrees with TensorPool: cap={cap} "
                    f"need={need} ok={ok}")
                memory_checks += 1

    assert chromosomes >= 104
    assert memory_checks >= 3 * chromosomes
    assert deadline_proof_checks > 0


# -- GA integration ------------------------------------------------------------

def _fingerprint(result):
    return (
        result.history,
        [s.key() for s in result.pareto],
        [s.fitness for s in result.pareto],
        result.generations,
        result.evaluations,
    )


def _ga_analyzer(processors=None, prescreen=False):
    return _analyzer(
        processors=processors, prescreen=prescreen,
        ga=GAConfig(pop_size=12, max_generations=8, min_generations=4,
                    seed=11, prescreen=prescreen))


def test_ga_prescreen_off_on_identical_when_nothing_pruned():
    base = _ga_analyzer(prescreen=False).run_ga()
    screened_an = _ga_analyzer(prescreen=True)
    screened = screened_an.run_ga()
    assert _fingerprint(base) == _fingerprint(screened)
    assert screened.prescreen_stats["pruned"] == 0
    assert screened.prescreen_stats["checked"] > 0
    assert base.prescreen_stats["checked"] == 0  # disabled: never consulted


def test_ga_prescreen_prunes_only_provable_oom():
    tight = [dataclasses.replace(p, memory_capacity=16384)
             if p.kind == "npu" else p for p in PROCS]
    an = _ga_analyzer(processors=tight, prescreen=True)
    linter = an.linter()
    result = an.run_ga()
    stats = result.prescreen_stats
    assert stats["pruned"] > 0
    assert stats["simulations_avoided"] == stats["pruned"]
    # pruned chromosomes carry worst-rank fitness and never win the front;
    # every front survivor genuinely provisions within the budget
    for sol in result.pareto:
        assert sol.fitness is None or \
            max(sol.fitness) < PRESCREEN_OBJECTIVE
        placed = linter.builder.decode(sol)
        ok = provision_memory(placed, linter.capacities())
        assert all(ok.values()), "infeasible chromosome survived the GA"


def test_prescreen_does_not_count_pruned_as_evaluations():
    tight = [dataclasses.replace(p, memory_capacity=16384)
             if p.kind == "npu" else p for p in PROCS]
    an = _ga_analyzer(processors=tight, prescreen=True)
    result = an.run_ga()
    assert result.evaluations > 0
    # cache-level accounting: every prune is a simulation that never ran
    assert result.prescreen_stats["checked"] >= \
        result.prescreen_stats["pruned"]


# -- CLI ----------------------------------------------------------------------

def test_cli_demo_smoke(capsys):
    from repro.analysis.lint import main
    assert main(["--demo", "--alpha", "1.0"]) == 0
    out = capsys.readouterr().out
    assert "linted" in out and "demo/" in out


def test_cli_golden_writes_report(tmp_path, capsys):
    from repro.analysis.lint import main
    out_path = tmp_path / "lint_report.json"
    assert main(["--golden", "--alpha", "1.0", "--out", str(out_path)]) == 0
    doc = json.loads(out_path.read_text())
    assert doc["mode"] == "golden"
    names = {row["scenario"] for row in doc["schedules"]}
    assert "tri_chain_clean" in names and "fault_dropout_mix" in names
    for row in doc["schedules"]:
        back = LintReport.from_json(row["report"])
        assert back.to_json()["counts"] == row["report"]["counts"]


def test_cli_strict_flags_errors(capsys):
    from repro.analysis.lint import main
    # the demo set contains provably-missed deadlines at α=1
    assert main(["--demo", "--alpha", "1.0", "--strict"]) == 1
    capsys.readouterr()
    # without an α probe the demo schedules carry no error findings
    assert main(["--demo", "--strict"]) == 0
