"""Golden-trace regression: canonical task traces for four fixed scenarios.

``tests/golden/*.json`` holds the reference :class:`SimResult` — the exact
task ordering (release/start/finish times, costs, placements), request
records, busy times and horizon — produced by the reference DES at a fixed
seed. Every engine tier (RuntimeSimulator, FastSimulator, BatchSimulator,
and the virtual-clock PuzzleRuntime) must reproduce it *bit for bit*: any
silent semantic drift in dispatch order, tie-breaking, cost arithmetic or
the noise stream fails loudly here even if the engines still agree with
each other.

Regenerate (after an intentional semantic change) with::

    PYTHONPATH=src python tests/test_golden_traces.py --regen

and review the diff — a regeneration that changes values is a semantics
change and must be called out in the PR.
"""
import json
import os
import random
import sys

import pytest

from repro.core import (
    ArrivalSpec,
    BatchLane,
    BatchSimulator,
    FastSimulator,
    FaultSpec,
    NoiseModel,
    PAPER_COMM_MODEL,
    Profiler,
    RuntimeSimulator,
    SolutionFactory,
    branching_graph,
    build_spec,
    chain_graph,
    decode_solution,
    mobile_processors,
)
from repro.core.profiler import AnalyticMobileBackend
from repro.runtime.conformance import run_virtual_schedule, serialize_result

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

PROCS = mobile_processors()
PROFILER = Profiler(AnalyticMobileBackend(PROCS))


def _nets_tri_chain():
    return [
        chain_graph("alpha", [("conv", 4e6, 1000, 4000)] * 4),
        chain_graph("beta", [("fc", 8e6, 2000, 8000)] * 3),
        chain_graph("gamma", [("dw", 1.5e6, 600, 1800)] * 5),
    ]


def _nets_diamond_mix():
    return [
        chain_graph("a", [("conv", 4e6, 1000, 4000)] * 5),
        branching_graph("b", [("conv", 2e6, 800, 2000)] * 4,
                        [(0, 1), (0, 2), (1, 3), (2, 3)]),
        chain_graph("c", [("fc", 8e6, 2000, 8000)] * 3),
        branching_graph("d", [("conv", 3e6, 500, 1500)] * 5,
                        [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)]),
    ]


def _solution(nets, seed, cut_prob=0.35, pin=None):
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(seed), cut_prob=cut_prob)
    if pin is not None:
        # everything cut apart but mapped to one processor: maximal queueing
        sol = fac.random_solution()
        sol.partition = [[1] * g.num_edges for g in nets]
        sol.mapping = [[pin] * g.num_layers for g in nets]
        return sol
    return fac.random_solution()


def _nets_runtime_conformance():
    """Mixed chain/branching set exercising the conformance-critical
    semantics at once: cross-group contention, noise draws in delivery
    order, dispatch-token injection, cross-processor boundaries and
    multi-producer joins (seed 11 decodes to 1+4+2 subgraphs over three
    processors)."""
    return [
        chain_graph("p", [("conv", 3e6, 900, 3000)] * 7),
        branching_graph("q", [("conv", 2.5e6, 700, 2200)] * 8,
                        [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5),
                         (3, 6), (5, 7), (6, 7)]),
        chain_graph("r", [("fc", 6e6, 1500, 6000)] * 5),
    ]


#: name -> (nets, groups, periods, num_requests, noise seed, dispatch, pin,
#:          arrivals, faults)
SCENARIOS = {
    "tri_chain_clean": (
        _nets_tri_chain, [[0, 1, 2]], [0.005], 8, None, 0.0, None, None,
        None),
    "diamond_mix_measured": (
        _nets_diamond_mix, [[0, 1], [2, 3]], [0.004, 0.006], 6, 7, 150e-6,
        None, None, None),
    "diamond_mix_overload": (
        _nets_diamond_mix, [[0, 1], [2, 3]], [2e-6, 2e-6], 30, None, 0.0, 0,
        None, None),
    # the device-in-the-loop tier's canonical trace (PR 4): replayed through
    # all four engine tiers including the virtual-clock PuzzleRuntime
    "runtime_conformance": (
        _nets_runtime_conformance, [[0, 2], [1]], [0.035, 0.05], 8, 3,
        150e-6, None, None, None),
    # non-periodic arrivals (PR 5): Poisson traffic + noise + dispatch
    # tokens — the bursty-load canonical trace, replayed through all four
    # tiers with the shared pre-drawn arrival-timestamp stream
    "poisson_burst_measured": (
        _nets_diamond_mix, [[0, 1], [2, 3]], [0.004, 0.006], 8, 5, 150e-6,
        None, ArrivalSpec(kind="poisson", seed=42), None),
    # fault injection (PR 6): a permanent mid-run processor dropout, a
    # thermal-throttle window and heavy-tailed stragglers in one ensemble,
    # on top of noise + dispatch tokens — the canonical faulted trace,
    # realized by the one shared seeded fault stream in all four tiers
    # (dropped requests at the horizon must match exactly)
    "fault_dropout_mix": (
        _nets_diamond_mix, [[0, 1], [2, 3]], [0.004, 0.006], 8, 7, 150e-6,
        None, None,
        FaultSpec(
            dropouts=((2, 0.012, None),),
            throttles=((0, 0.002, 0.008, 3.0),),
            straggler_prob=0.2, straggler_shape=1.5, seed=13,
        )),
}


def _run_reference(name):
    (nets_fn, groups, periods, nr, noise_seed, dispatch, pin,
     arrivals, faults) = SCENARIOS[name]
    nets = nets_fn()
    sol = _solution(nets, seed=11, pin=pin)
    placed = decode_solution(sol, nets)
    noise = NoiseModel(seed=noise_seed) if noise_seed is not None else None
    res = RuntimeSimulator(
        placed=placed, processors=PROCS, profiler=PROFILER,
        comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods,
        num_requests=nr, noise=noise, dispatch_overhead=dispatch,
        arrivals=arrivals, faults=faults,
    ).run()
    return (nets, sol, groups, periods, nr, noise, dispatch, arrivals,
            faults, res)


# single schema source: the runtime conformance harness serializes the same
# way, so runtime traces diff directly against these files
_serialize = serialize_result


def _assert_matches_golden(res, golden, engine):
    got = _serialize(res)
    assert got["horizon"] == golden["horizon"], engine
    assert got["busy_time"] == golden["busy_time"], engine
    assert len(got["requests"]) == len(golden["requests"]), engine
    for g, w in zip(got["requests"], golden["requests"]):
        assert g == w, (engine, "request", g, w)
    assert got["makespans"] == golden["makespans"], engine
    assert len(got["tasks"]) == len(golden["tasks"]), (
        engine, len(got["tasks"]), len(golden["tasks"]))
    for i, (g, w) in enumerate(zip(got["tasks"], golden["tasks"])):
        assert g == w, (engine, "task", i, g, w)


def _engine_results(name):
    """Replay one golden scenario through all four engine tiers.

    The single construction site for both the pytest parity test and the
    CI ``--check`` gate — a new engine parameter (like ``arrivals`` in this
    PR) cannot silently reach only one of the two.
    """
    (nets, sol, groups, periods, nr, noise, dispatch, arrivals, faults,
     ref) = _run_reference(name)
    spec = build_spec(decode_solution(sol, nets), PROCS, PROFILER,
                      PAPER_COMM_MODEL)
    return {
        "reference-des": ref,
        "fastsim": FastSimulator(
            spec, groups=groups, periods=periods, num_requests=nr,
            noise=noise, dispatch_overhead=dispatch, arrivals=arrivals,
            faults=faults,
        ).run(collect_tasks=True),
        "batchsim": BatchSimulator(
            [BatchLane(spec=spec, periods=periods, num_requests=nr,
                       noise=noise, dispatch_overhead=dispatch,
                       arrivals=arrivals, faults=faults)],
            groups, PROCS,
        ).run(collect_tasks=True).result(0),
        # fourth tier: the actual Coordinator/Worker dispatch code replaying
        # the spec's costs on the virtual clock — the device-in-the-loop
        # conformance path must reproduce the same trace bit for bit
        "virtual-runtime": run_virtual_schedule(
            nets, sol, PROCS, spec, groups, periods, nr,
            noise=noise, dispatch_overhead=dispatch, arrivals=arrivals,
            faults=faults,
        ),
    }


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_trace(name):
    path = os.path.join(GOLDEN_DIR, f"{name}.json")
    assert os.path.exists(path), (
        f"missing golden file {path}; regenerate with "
        f"`PYTHONPATH=src python tests/test_golden_traces.py --regen`")
    with open(path) as f:
        golden = json.load(f)
    for engine, res in _engine_results(name).items():
        _assert_matches_golden(res, golden, engine)


def test_golden_traces_have_interesting_structure():
    """The committed traces must exercise the semantics they guard."""
    with open(os.path.join(GOLDEN_DIR, "diamond_mix_measured.json")) as f:
        measured = json.load(f)
    # noise applied: exec times differ across requests of the same task
    execs = {}
    varied = False
    for g, r, net, k, pid, rel, st_, fin, cm, qt, ex in measured["tasks"]:
        key = (net, k)
        if key in execs and execs[key] != ex:
            varied = True
        execs[key] = ex
    assert varied, "measured trace shows no run-to-run exec variance"
    with open(os.path.join(GOLDEN_DIR, "runtime_conformance.json")) as f:
        conf = json.load(f)
    # the conformance trace must exercise multi-subgraph dependencies on
    # multiple processors, dispatch load, and a completed/dropped mix
    assert len({t[4] for t in conf["tasks"]}) >= 3, "single-processor trace"
    assert any(t[8] > 0 for t in conf["tasks"]), "no cross-processor comm"
    assert any(m is None for m in conf["makespans"])
    assert any(m is not None for m in conf["makespans"])
    with open(os.path.join(GOLDEN_DIR, "diamond_mix_overload.json")) as f:
        overload = json.load(f)
    assert any(m is None for m in overload["makespans"]), (
        "overload trace dropped no requests")
    assert any(m is not None for m in overload["makespans"])
    # the bursty trace must actually be non-periodic: inter-arrival gaps
    # within a group vary (and some request still completes under load)
    with open(os.path.join(GOLDEN_DIR, "poisson_burst_measured.json")) as f:
        burst = json.load(f)
    arrivals_g0 = [r[2] for r in burst["requests"] if r[0] == 0]
    gaps = [b - a for a, b in zip(arrivals_g0, arrivals_g0[1:])]
    assert len(set(round(g, 12) for g in gaps)) > 1, (
        "poisson golden trace has periodic arrivals")
    assert any(m is not None for m in burst["makespans"])
    # noise + dispatch exercised on the bursty path too
    assert any(t[8] > 0 for t in burst["tasks"]), "no cross-processor comm"
    # the fault trace must show all three fault classes actually biting:
    # a permanent dropout dropping requests mid-run (while earlier requests
    # completed), the throttle window inflating in-window work, and the
    # straggler stream adding exec variance on top of the noise model
    with open(os.path.join(GOLDEN_DIR, "fault_dropout_mix.json")) as f:
        faulted = json.load(f)
    spec = SCENARIOS["fault_dropout_mix"][8]
    dead = spec.dropped_pids()[0]
    assert any(m is None for m in faulted["makespans"]), (
        "fault trace dropped no requests")
    assert any(m is not None for m in faulted["makespans"])
    t_drop = dict(
        (d[0], d[1]) for d in spec.dropouts)[dead]
    dead_tasks = [t for t in faulted["tasks"] if t[4] == dead]
    assert dead_tasks, "dead processor never used before the dropout"
    assert all(t[6] <= t_drop for t in dead_tasks), (
        "task started on the dead processor after its dropout")
    pid_t, t0, t1, factor = spec.throttles[0]
    in_window = [t for t in faulted["tasks"]
                 if t[4] == pid_t and t0 <= t[6] < t1]
    assert in_window, "throttle window caught no deliveries"


def regenerate():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name in sorted(SCENARIOS):
        *_, res = _run_reference(name)
        path = os.path.join(GOLDEN_DIR, f"{name}.json")
        doc = _serialize(res)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {path}: {len(doc['tasks'])} tasks, "
              f"{len(doc['requests'])} requests")


def _trace_diff(got, golden):
    """Scalar summary of got-vs-golden: max abs diffs + exact-match flag."""
    diffs = {
        "horizon": abs(got["horizon"] - golden["horizon"]),
        "busy_time": max(
            (abs(got["busy_time"].get(k, 0.0) - golden["busy_time"].get(k, 0.0))
             for k in set(got["busy_time"]) | set(golden["busy_time"])),
            default=0.0),
        "task_count": abs(len(got["tasks"]) - len(golden["tasks"])),
        "request_count": abs(len(got["requests"]) - len(golden["requests"])),
    }
    ms = 0.0
    for a, b in zip(got["makespans"], golden["makespans"]):
        if a is None and b is None:
            continue
        if a is None or b is None:
            ms = float("inf")
            break
        ms = max(ms, abs(a - b))
    diffs["makespan"] = ms
    t = 0.0
    for a, b in zip(got["tasks"], golden["tasks"]):
        if a[:5] != b[:5]:  # (group, request, net, sg, processor) ordering
            t = float("inf")
            break
        t = max(t, max(abs(x - y) for x, y in zip(a[5:], b[5:])))
    diffs["task_fields"] = t
    diffs["exact"] = got == golden
    return diffs


def check(out_path=None):
    """Replay every golden scenario through all four engine tiers and
    report max-abs trace diffs (the CI gate; writes a JSON artifact).

    Returns the number of (scenario, engine) pairs that failed to
    reproduce their golden trace exactly.
    """
    report = {}
    failures = 0
    for name in sorted(SCENARIOS):
        with open(os.path.join(GOLDEN_DIR, f"{name}.json")) as f:
            golden = json.load(f)
        report[name] = {}
        for engine, res in _engine_results(name).items():
            diffs = _trace_diff(_serialize(res), golden)
            report[name][engine] = diffs
            status = "ok" if diffs["exact"] else "DIFF"
            if not diffs["exact"]:
                failures += 1
            print(f"{name:28s} {engine:16s} {status} "
                  f"max_task_diff={diffs['task_fields']:.3e}")
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {out_path}")
    return failures


if __name__ == "__main__":
    if "--regen" in sys.argv:
        regenerate()
    elif "--check" in sys.argv:
        out = None
        if "--out" in sys.argv:
            out = sys.argv[sys.argv.index("--out") + 1]
        sys.exit(1 if check(out_path=out) else 0)
    else:
        print(__doc__)
