"""Golden-trace regression: canonical task traces for three fixed scenarios.

``tests/golden/*.json`` holds the reference :class:`SimResult` — the exact
task ordering (release/start/finish times, costs, placements), request
records, busy times and horizon — produced by the reference DES at a fixed
seed. Every engine (RuntimeSimulator, FastSimulator, BatchSimulator) must
reproduce it *bit for bit*: any silent semantic drift in dispatch order,
tie-breaking, cost arithmetic or the noise stream fails loudly here even if
the engines still agree with each other.

Regenerate (after an intentional semantic change) with::

    PYTHONPATH=src python tests/test_golden_traces.py --regen

and review the diff — a regeneration that changes values is a semantics
change and must be called out in the PR.
"""
import json
import math
import os
import random
import sys

import pytest

from repro.core import (
    BatchLane,
    BatchSimulator,
    FastSimulator,
    NoiseModel,
    PAPER_COMM_MODEL,
    Profiler,
    RuntimeSimulator,
    SolutionFactory,
    branching_graph,
    build_spec,
    chain_graph,
    decode_solution,
    mobile_processors,
)
from repro.core.profiler import AnalyticMobileBackend

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

PROCS = mobile_processors()
PROFILER = Profiler(AnalyticMobileBackend(PROCS))


def _nets_tri_chain():
    return [
        chain_graph("alpha", [("conv", 4e6, 1000, 4000)] * 4),
        chain_graph("beta", [("fc", 8e6, 2000, 8000)] * 3),
        chain_graph("gamma", [("dw", 1.5e6, 600, 1800)] * 5),
    ]


def _nets_diamond_mix():
    return [
        chain_graph("a", [("conv", 4e6, 1000, 4000)] * 5),
        branching_graph("b", [("conv", 2e6, 800, 2000)] * 4,
                        [(0, 1), (0, 2), (1, 3), (2, 3)]),
        chain_graph("c", [("fc", 8e6, 2000, 8000)] * 3),
        branching_graph("d", [("conv", 3e6, 500, 1500)] * 5,
                        [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)]),
    ]


def _solution(nets, seed, cut_prob=0.35, pin=None):
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(seed), cut_prob=cut_prob)
    if pin is not None:
        # everything cut apart but mapped to one processor: maximal queueing
        sol = fac.random_solution()
        sol.partition = [[1] * g.num_edges for g in nets]
        sol.mapping = [[pin] * g.num_layers for g in nets]
        return sol
    return fac.random_solution()


#: name -> (nets, groups, periods, num_requests, noise seed, dispatch, pin)
SCENARIOS = {
    "tri_chain_clean": (
        _nets_tri_chain, [[0, 1, 2]], [0.005], 8, None, 0.0, None),
    "diamond_mix_measured": (
        _nets_diamond_mix, [[0, 1], [2, 3]], [0.004, 0.006], 6, 7, 150e-6,
        None),
    "diamond_mix_overload": (
        _nets_diamond_mix, [[0, 1], [2, 3]], [2e-6, 2e-6], 30, None, 0.0, 0),
}


def _run_reference(name):
    nets_fn, groups, periods, nr, noise_seed, dispatch, pin = SCENARIOS[name]
    nets = nets_fn()
    sol = _solution(nets, seed=11, pin=pin)
    placed = decode_solution(sol, nets)
    noise = NoiseModel(seed=noise_seed) if noise_seed is not None else None
    res = RuntimeSimulator(
        placed=placed, processors=PROCS, profiler=PROFILER,
        comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods,
        num_requests=nr, noise=noise, dispatch_overhead=dispatch,
    ).run()
    return nets, sol, groups, periods, nr, noise, dispatch, res


def _serialize(res):
    return {
        "horizon": res.horizon,
        "busy_time": {str(pid): t for pid, t in sorted(res.busy_time.items())},
        "requests": [
            [r.group, r.request, r.arrival, r.first_start, r.last_finish,
             r.done_tasks, r.total_tasks]
            for r in res.requests
        ],
        "makespans": [
            None if math.isinf(r.makespan) else r.makespan
            for r in res.requests
        ],
        "tasks": [
            [t.group, t.request, t.network, t.sg_index, t.processor,
             t.released, t.started, t.finished,
             t.comm_time, t.quant_time, t.exec_time]
            for t in res.tasks
        ],
    }


def _assert_matches_golden(res, golden, engine):
    got = _serialize(res)
    assert got["horizon"] == golden["horizon"], engine
    assert got["busy_time"] == golden["busy_time"], engine
    assert len(got["requests"]) == len(golden["requests"]), engine
    for g, w in zip(got["requests"], golden["requests"]):
        assert g == w, (engine, "request", g, w)
    assert got["makespans"] == golden["makespans"], engine
    assert len(got["tasks"]) == len(golden["tasks"]), (
        engine, len(got["tasks"]), len(golden["tasks"]))
    for i, (g, w) in enumerate(zip(got["tasks"], golden["tasks"])):
        assert g == w, (engine, "task", i, g, w)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_trace(name):
    path = os.path.join(GOLDEN_DIR, f"{name}.json")
    assert os.path.exists(path), (
        f"missing golden file {path}; regenerate with "
        f"`PYTHONPATH=src python tests/test_golden_traces.py --regen`")
    with open(path) as f:
        golden = json.load(f)
    nets, sol, groups, periods, nr, noise, dispatch, ref = _run_reference(name)

    _assert_matches_golden(ref, golden, "reference-des")

    spec = build_spec(decode_solution(sol, nets), PROCS, PROFILER,
                      PAPER_COMM_MODEL)
    fast = FastSimulator(
        spec, groups=groups, periods=periods, num_requests=nr,
        noise=noise, dispatch_overhead=dispatch,
    ).run(collect_tasks=True)
    _assert_matches_golden(fast, golden, "fastsim")

    batch = BatchSimulator(
        [BatchLane(spec=spec, periods=periods, num_requests=nr,
                   noise=noise, dispatch_overhead=dispatch)],
        groups, PROCS,
    ).run(collect_tasks=True)
    _assert_matches_golden(batch.result(0), golden, "batchsim")


def test_golden_traces_have_interesting_structure():
    """The committed traces must exercise the semantics they guard."""
    with open(os.path.join(GOLDEN_DIR, "diamond_mix_measured.json")) as f:
        measured = json.load(f)
    # noise applied: exec times differ across requests of the same task
    execs = {}
    varied = False
    for g, r, net, k, pid, rel, st_, fin, cm, qt, ex in measured["tasks"]:
        key = (net, k)
        if key in execs and execs[key] != ex:
            varied = True
        execs[key] = ex
    assert varied, "measured trace shows no run-to-run exec variance"
    with open(os.path.join(GOLDEN_DIR, "diamond_mix_overload.json")) as f:
        overload = json.load(f)
    assert any(m is None for m in overload["makespans"]), (
        "overload trace dropped no requests")
    assert any(m is not None for m in overload["makespans"])


def regenerate():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name in sorted(SCENARIOS):
        *_, res = _run_reference(name)
        path = os.path.join(GOLDEN_DIR, f"{name}.json")
        doc = _serialize(res)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {path}: {len(doc['tasks'])} tasks, "
              f"{len(doc['requests'])} requests")


if __name__ == "__main__":
    if "--regen" in sys.argv:
        regenerate()
    else:
        print(__doc__)
