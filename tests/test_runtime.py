"""Puzzle Runtime: coordinator/worker/engine behaviour + §5.3 optimizations.

Scheduling-behaviour tests run in **virtual-clock mode** — deterministic,
instant, no ``time.sleep`` and no wall-clock-dependent assertions — while
real-execution tests (engine agreement, tensor pool, measured costs) keep
exercising the threaded path but assert only on counts and values, never on
timing.
"""
import random
import threading

import numpy as np
import pytest

from repro.core import (
    PAPER_COMM_MODEL,
    FaultSpec,
    Profiler,
    Solution,
    SolutionFactory,
    build_spec,
    decode_solution,
    mobile_processors,
)
from repro.core.profiler import AnalyticMobileBackend
from repro.core.simulator import NoiseModel
from repro.core.fastsim import FastSimulator
from repro.core.graph import branching_graph, chain_graph
from repro.runtime import (
    PuzzleRuntime,
    RuntimeConfig,
    TensorPool,
    SharedBufferTransport,
    VirtualClock,
    make_engine,
    runtime_result,
)
from repro.zoo import executable_zoo

PROCS = mobile_processors()
PROFILER = Profiler(AnalyticMobileBackend(PROCS))


@pytest.fixture(scope="module")
def zoo():
    return executable_zoo(names=["face_det", "selfie_seg"], channels=4, spatial=8)


def _solution(graphs, split_first=True):
    g0, g1 = graphs
    part0 = [0] * g0.num_edges
    if split_first:
        # cut the last chain edge: the final layers form a second subgraph
        part0[g0.num_layers - 2] = 1
    return Solution(
        partition=[part0, [0] * g1.num_edges],
        mapping=[[2] * (g0.num_layers - 1) + [1], [0] * g1.num_layers],
        priority=[0, 1],
        dtype=[0, 0],
        backend=[0, 0],
    )


def _virtual_runtime(nets, sol, noise=None, dispatch=0.0):
    spec = build_spec(decode_solution(sol, nets), PROCS, PROFILER,
                      PAPER_COMM_MODEL)
    rt = PuzzleRuntime(
        nets, sol, PROCS,
        config=RuntimeConfig(virtual=True, noise=noise,
                             dispatch_overhead=dispatch),
        spec=spec,
    )
    return rt, spec


def _random_nets():
    return [
        chain_graph("vx", [("conv", 4e6, 1000, 4000)] * 5),
        branching_graph("vy", [("conv", 2e6, 800, 2000)] * 4,
                        [(0, 1), (0, 2), (1, 3), (2, 3)]),
    ]


# -- virtual-clock scheduling behaviour (deterministic, no wall clock) -------

def test_virtual_end_to_end_inference():
    nets = _random_nets()
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(3)).random_solution()
    rt, _ = _virtual_runtime(nets, sol)
    with rt:
        st = rt.infer_sync([0, 1])
        assert st.makespan is not None and st.makespan > 0
        placed = decode_solution(sol, nets)
        assert len(st.task_records) == sum(len(p) for p in placed)
        # virtual time advanced, and deterministically so
        assert rt.clock.now() == st.finish


def test_virtual_cross_processor_dependency_order():
    """The consumer subgraph must start only after its producer finishes."""
    nets = _random_nets()
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(5)).random_solution()
    rt, _ = _virtual_runtime(nets, sol)
    with rt:
        rt.infer_sync([0, 1])
        trace = rt.coordinator.trace
        finished = {}
        for rec in trace:
            finished[(rec.network, rec.sg_index)] = rec.finished
        deps = rt.coordinator._deps
        for rec in trace:
            for producer in deps[rec.network][rec.sg_index]:
                assert rec.started >= finished[(rec.network, producer)]


def test_virtual_periodic_requests_all_complete():
    nets = _random_nets()
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(7)).random_solution()
    rt, _ = _virtual_runtime(nets, sol)
    with rt:
        res = rt.run_periodic([[0], [1]], [0.02, 0.03], num_requests=4)
        assert len(res) == 2
        for glist in res:
            assert len(glist) == 4
            for st in glist:
                assert st.makespan is not None
        # request sources fired at exactly rid × period (virtual time)
        for gid, period in enumerate([0.02, 0.03]):
            for rid, st in enumerate(res[gid]):
                assert st.submitted == rid * period


def test_virtual_runtime_matches_fastsim():
    """Virtual-clock execution is bit-identical to the fast simulator."""
    nets = _random_nets()
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(11)).random_solution()
    groups, periods, nr = [[0], [1]], [0.004, 0.006], 6
    noise = NoiseModel(seed=4)
    rt, spec = _virtual_runtime(nets, sol, noise=noise, dispatch=150e-6)
    with rt:
        states = rt.run_periodic(groups, periods, num_requests=nr)
        got = runtime_result(rt, states, periods, nr)
    want = FastSimulator(
        spec, groups=groups, periods=periods, num_requests=nr,
        noise=noise, dispatch_overhead=150e-6,
    ).run(collect_tasks=True)
    assert [(t.network, t.sg_index, t.released, t.started, t.finished,
             t.exec_time) for t in got.tasks] == \
           [(t.network, t.sg_index, t.released, t.started, t.finished,
             t.exec_time) for t in want.tasks]
    assert got.busy_time == want.busy_time
    assert [r.makespan for r in got.requests] == \
           [r.makespan for r in want.requests]


def test_virtual_runtime_is_deterministic():
    nets = _random_nets()
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(13)).random_solution()
    traces = []
    for _ in range(2):
        rt, _ = _virtual_runtime(nets, sol, noise=NoiseModel(seed=9))
        with rt:
            states = rt.run_periodic([[0, 1]], [0.01], num_requests=5)
            traces.append([
                (t.network, t.sg_index, t.released, t.started, t.finished)
                for t in rt.coordinator.trace
            ])
            assert all(st.makespan is not None for st in states[0])
    assert traces[0] == traces[1]


def test_virtual_clock_event_ordering():
    clock = VirtualClock()
    fired = []
    clock.schedule(0.5, lambda: fired.append("b"))
    clock.schedule(0.5, lambda: fired.append("c"))  # same time: push order
    clock.schedule(0.1, lambda: fired.append("a"))
    clock.schedule(2.0, lambda: fired.append("past-horizon"))
    clock.run(until=1.0)
    assert fired == ["a", "b", "c"]
    assert clock.now() == 0.5
    assert clock.pending == 1


def test_close_during_injected_fault_names_the_fault():
    """Closing a virtual runtime whose requests were stranded by an
    injected dropout must fail the pending futures with an error *naming
    the fault* — not a bare close sentinel — join every worker thread and
    drain every queue."""
    nets = _random_nets()
    sol = None
    for seed in range(64):
        cand = SolutionFactory(nets, num_processors=len(PROCS),
                               rng=random.Random(seed)).random_solution()
        if any(p.processor == 2 for pl in decode_solution(cand, nets)
               for p in pl):
            sol = cand
            break
    assert sol is not None
    faults = FaultSpec(dropouts=((2, 0.008, None),), seed=3)
    spec = build_spec(decode_solution(sol, nets), PROCS, PROFILER,
                      PAPER_COMM_MODEL)
    rt = PuzzleRuntime(
        nets, sol, PROCS,
        config=RuntimeConfig(virtual=True, faults=faults),
        spec=spec,
    )
    states = rt.run_periodic([[0, 1]], [0.004], num_requests=8)
    stranded = [st for st in states[0] if not st.future.done()]
    assert stranded, "the dropout must strand at least one request"
    rt.close()
    for st in stranded:
        with pytest.raises(RuntimeError, match=r"processor 2 dropped at "
                                               r"t=0\.008"):
            st.future.result(timeout=0)
    assert not any(w.threads_alive() for w in rt.workers.values())
    for w in rt.workers.values():
        assert not w._vstore
        assert w._queue.empty() and w._exec_queue.empty()
    rt.close()  # idempotent


# -- lifecycle: close(), thread leaks, abandoned requests --------------------

def test_close_joins_all_worker_threads(zoo):
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    rt = PuzzleRuntime(graphs, _solution(graphs), mobile_processors(), zoo)
    threads = [t for w in rt.workers.values()
               for t in (w._quant_thread, w._exec_thread)]
    assert all(t.is_alive() for t in threads)
    rt.infer_sync([0, 1])
    rt.close()
    assert all(not t.is_alive() for t in threads)
    assert not any(w.threads_alive() for w in rt.workers.values())
    rt.close()  # idempotent


def test_close_mid_request_fails_pending_futures(zoo):
    """Abandoning a runtime mid-request must not leak threads or hang."""
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    rt = PuzzleRuntime(graphs, _solution(graphs), mobile_processors(), zoo)
    states = [rt.infer([0, 1]) for _ in range(8)]
    rt.close()  # queues may still hold tasks: the stop sentinel outranks them
    assert not any(w.threads_alive() for w in rt.workers.values())
    for st in states:
        # either completed before the stop sentinel won the queue race,
        # or failed with the close error — never left hanging
        assert st.future.done()
    with pytest.raises(RuntimeError):
        rt.infer([0, 1])


def test_context_manager_closes(zoo):
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    with PuzzleRuntime(graphs, _solution(graphs), mobile_processors(),
                       zoo) as rt:
        st = rt.infer_sync([0, 1])
        assert st.makespan is not None
    assert not any(w.threads_alive() for w in rt.workers.values())


def test_worker_stop_with_queued_tasks_regression(zoo):
    """stop() with a non-empty priority queue used to raise TypeError
    (None unorderable vs WorkerTask) and leak both threads."""
    graphs = [zoo["face_det"].graph]
    g = graphs[0]
    sol = Solution(partition=[[0] * g.num_edges], mapping=[[0] * g.num_layers],
                   priority=[0], dtype=[0], backend=[0])
    rt = PuzzleRuntime(graphs, sol, mobile_processors(), zoo)
    w = rt.workers[0]
    # pile tasks into the queue faster than they can drain, then stop
    for _ in range(32):
        rt.infer([0])
    rt.close()
    assert not w.threads_alive()


def test_no_leaked_threads_across_many_runtimes(zoo):
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    base = threading.active_count()
    for _ in range(3):
        with PuzzleRuntime(graphs, _solution(graphs), mobile_processors(),
                           zoo) as rt:
            rt.infer_sync([0, 1])
    assert threading.active_count() <= base


# -- real execution: engines, memory optimizations ---------------------------

def test_end_to_end_inference(zoo):
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    with PuzzleRuntime(graphs, _solution(graphs), mobile_processors(),
                       zoo) as rt:
        st = rt.infer_sync([0, 1])
        assert st.makespan is not None
        # face_det split into 2 subgraphs + selfie 1
        assert len(st.task_records) == 3
        out = st.outputs
        assert all(not np.any(np.isnan(np.asarray(v, np.float32)))
                   for v in out.values() if not isinstance(v, tuple))


def test_cross_processor_dependency_order(zoo):
    """Subgraph 2 (GPU) must consume subgraph 1's (NPU) output."""
    graphs = [zoo["face_det"].graph]
    g = graphs[0]
    sol = Solution(
        partition=[[1 if i == g.num_layers - 2 else 0 for i in range(g.num_edges)]],
        mapping=[[2] * (g.num_layers - 1) + [1]],
        priority=[0], dtype=[0], backend=[0],
    )
    with PuzzleRuntime(graphs, sol, mobile_processors(), zoo) as rt:
        st = rt.infer_sync([0])
        recs = {r["sg"]: r for r in st.task_records}
        assert set(recs) == {0, 1}


def test_measured_costs_keyed_by_profile_key(zoo):
    """Real execution produces per-Merkle-key medians for the feedback loop."""
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    sol = _solution(graphs)
    with PuzzleRuntime(graphs, sol, mobile_processors(), zoo) as rt:
        for _ in range(3):
            rt.infer_sync([0, 1])
        costs = rt.measured_costs()
    placed = decode_solution(sol, graphs)
    expected_keys = {p.profile_key() for plist in placed for p in plist}
    assert set(costs) == expected_keys
    assert all(t > 0 for t in costs.values())


def test_tensor_pool_reuse():
    pool = TensorPool(enabled=True)
    a = pool.acquire((16, 16), np.float32)
    pool.release(a)
    b = pool.acquire((8, 8), np.float32)   # smaller fits the same chunk? no:
    # different rounded size -> fresh alloc; same size -> reuse
    pool.release(b)
    c = pool.acquire((16, 16), np.float32)
    assert pool.stats.reuses >= 1
    assert pool.stats.mallocs <= 2
    c[:] = 1.0  # usable memory


def test_tensor_pool_disabled_always_allocates():
    pool = TensorPool(enabled=False)
    a = pool.acquire((16,), np.float32)
    pool.release(a)
    pool.acquire((16,), np.float32)
    assert pool.stats.mallocs == 2
    assert pool.stats.reuses == 0


def test_shared_buffer_zero_copy():
    pool = TensorPool()
    t_zero = SharedBufferTransport(pool, zero_copy=True)
    t_copy = SharedBufferTransport(pool, zero_copy=False)
    src = np.ones((64,), np.float32)
    out_zero = t_zero.transfer(src)
    assert out_zero is src
    out_copy = t_copy.transfer(src)
    assert out_copy is not src
    np.testing.assert_array_equal(np.asarray(out_copy), src)
    assert t_copy.stats.staged_bytes == src.nbytes


def test_engines_agree(zoo):
    """All backends compute the same function (different kernel profiles)."""
    from repro.core import whole_model_placement
    g = zoo["face_det"].graph
    placed = whole_model_placement(g, 0, 0, 0, 0)
    outs = {}
    for name in ("default", "xnnpack", "nnapi"):
        eng = make_engine(name)
        key = eng.load(placed, zoo)
        outs[name] = np.asarray(eng.execute(key), np.float32)
        assert key in eng.exec_times and len(eng.exec_times[key]) == 1
    np.testing.assert_allclose(outs["default"], outs["nnapi"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs["default"], outs["xnnpack"], rtol=1e-2, atol=1e-3)


def test_ablation_pool_reduces_mallocs(zoo):
    """Table 5 direction: tensor pool cuts allocation counts."""
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    sol = _solution(graphs)
    sol = Solution(
        partition=sol.partition, mapping=sol.mapping, priority=sol.priority,
        dtype=[0, 1], backend=[0, 0],   # dtype boundary forces staging copies
    )
    counts = {}
    for pool_on in (False, True):
        with PuzzleRuntime(
            graphs, sol, mobile_processors(), zoo,
            RuntimeConfig(tensor_pool=pool_on, shared_buffer=False),
        ) as rt:
            for _ in range(6):
                rt.infer_sync([0, 1])
            counts[pool_on] = rt.stats()["pool"]["mallocs"]
    assert counts[True] <= counts[False]
