"""Puzzle Runtime: coordinator/worker/engine behaviour + §5.3 optimizations."""
import numpy as np
import pytest

from repro.core import Solution, mobile_processors
from repro.runtime import (
    PuzzleRuntime,
    RuntimeConfig,
    TensorPool,
    SharedBufferTransport,
    make_engine,
)
from repro.zoo import executable_zoo


@pytest.fixture(scope="module")
def zoo():
    return executable_zoo(names=["face_det", "selfie_seg"], channels=4, spatial=8)


def _solution(graphs, split_first=True):
    g0, g1 = graphs
    part0 = [0] * g0.num_edges
    if split_first:
        # cut the last chain edge: the final layers form a second subgraph
        part0[g0.num_layers - 2] = 1
    return Solution(
        partition=[part0, [0] * g1.num_edges],
        mapping=[[2] * (g0.num_layers - 1) + [1], [0] * g1.num_layers],
        priority=[0, 1],
        dtype=[0, 0],
        backend=[0, 0],
    )


def test_end_to_end_inference(zoo):
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    rt = PuzzleRuntime(graphs, _solution(graphs), mobile_processors(), zoo)
    try:
        st = rt.infer_sync([0, 1])
        assert st.makespan is not None and st.makespan > 0
        # face_det split into 2 subgraphs + selfie 1
        assert len(st.task_records) == 3
        out = st.outputs
        assert all(not np.any(np.isnan(np.asarray(v, np.float32)))
                   for v in out.values() if not isinstance(v, tuple))
    finally:
        rt.close()


def test_cross_processor_dependency_order(zoo):
    """Subgraph 2 (GPU) must consume subgraph 1's (NPU) output."""
    graphs = [zoo["face_det"].graph]
    g = graphs[0]
    sol = Solution(
        partition=[[1 if i == g.num_layers - 2 else 0 for i in range(g.num_edges)]],
        mapping=[[2] * (g.num_layers - 1) + [1]],
        priority=[0], dtype=[0], backend=[0],
    )
    rt = PuzzleRuntime(graphs, sol, mobile_processors(), zoo)
    try:
        st = rt.infer_sync([0])
        recs = {r["sg"]: r for r in st.task_records}
        assert set(recs) == {0, 1}
    finally:
        rt.close()


def test_periodic_requests_all_complete(zoo):
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    rt = PuzzleRuntime(graphs, _solution(graphs), mobile_processors(), zoo)
    try:
        res = rt.run_periodic([[0], [1]], [0.02, 0.03], num_requests=4)
        assert len(res) == 2
        for glist in res:
            assert len(glist) == 4
            for st in glist:
                assert st.makespan is not None
    finally:
        rt.close()


def test_tensor_pool_reuse():
    pool = TensorPool(enabled=True)
    a = pool.acquire((16, 16), np.float32)
    pool.release(a)
    b = pool.acquire((8, 8), np.float32)   # smaller fits the same chunk? no:
    # different rounded size -> fresh alloc; same size -> reuse
    pool.release(b)
    c = pool.acquire((16, 16), np.float32)
    assert pool.stats.reuses >= 1
    assert pool.stats.mallocs <= 2
    c[:] = 1.0  # usable memory


def test_tensor_pool_disabled_always_allocates():
    pool = TensorPool(enabled=False)
    a = pool.acquire((16,), np.float32)
    pool.release(a)
    b = pool.acquire((16,), np.float32)
    assert pool.stats.mallocs == 2
    assert pool.stats.reuses == 0


def test_shared_buffer_zero_copy():
    pool = TensorPool()
    t_zero = SharedBufferTransport(pool, zero_copy=True)
    t_copy = SharedBufferTransport(pool, zero_copy=False)
    src = np.ones((64,), np.float32)
    out_zero = t_zero.transfer(src)
    assert out_zero is src
    out_copy = t_copy.transfer(src)
    assert out_copy is not src
    np.testing.assert_array_equal(np.asarray(out_copy), src)
    assert t_copy.stats.staged_bytes == src.nbytes


def test_engines_agree(zoo):
    """All backends compute the same function (different kernel profiles)."""
    from repro.core import whole_model_placement
    g = zoo["face_det"].graph
    placed = whole_model_placement(g, 0, 0, 0, 0)
    outs = {}
    for name in ("default", "xnnpack", "nnapi"):
        eng = make_engine(name)
        key = eng.load(placed, zoo)
        outs[name] = np.asarray(eng.execute(key), np.float32)
    np.testing.assert_allclose(outs["default"], outs["nnapi"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs["default"], outs["xnnpack"], rtol=1e-2, atol=1e-3)


def test_ablation_pool_reduces_mallocs(zoo):
    """Table 5 direction: tensor pool cuts allocation counts."""
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    sol = _solution(graphs)
    sol = Solution(
        partition=sol.partition, mapping=sol.mapping, priority=sol.priority,
        dtype=[0, 1], backend=[0, 0],   # dtype boundary forces staging copies
    )
    counts = {}
    for pool_on in (False, True):
        rt = PuzzleRuntime(
            graphs, sol, mobile_processors(), zoo,
            RuntimeConfig(tensor_pool=pool_on, shared_buffer=False),
        )
        try:
            for _ in range(6):
                rt.infer_sync([0, 1])
            counts[pool_on] = rt.stats()["pool"]["mallocs"]
        finally:
            rt.close()
    assert counts[True] <= counts[False]
