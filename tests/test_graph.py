"""Graph IR: partitioning, convexity, Merkle hashing."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Edge, Layer, ModelGraph, branching_graph, chain_graph


def make_chain(n=6):
    return chain_graph("c", [("conv", 1e6, 100, 1000)] * n)


def test_chain_no_cuts_single_subgraph():
    g = make_chain(5)
    sgs = g.partition([0] * g.num_edges)
    assert len(sgs) == 1
    assert sgs[0].layer_ids == tuple(range(5))


def test_chain_all_cuts():
    g = make_chain(4)
    sgs = g.partition([1] * g.num_edges)
    assert len(sgs) == 4
    assert [s.layer_ids for s in sgs] == [(0,), (1,), (2,), (3,)]


def test_partition_matches_paper_fig7():
    # Fig 7: 5-layer chain, edges [2],[3] cut -> {0,1,2} and {3,4}
    g = make_chain(5)
    bits = [0, 0, 1, 0]
    # edge index 2 connects layers 2-3 -> cut after layer 2
    sgs = g.partition(bits)
    assert [s.layer_ids for s in sgs] == [(0, 1, 2), (3, 4)]


def test_cut_inside_connected_component_is_ignored():
    # diamond: 0 -> 1 -> 3, 0 -> 2 -> 3; cutting only edge 0->1 leaves 1
    # connected through 1->3, so the cut is ineffective: one subgraph.
    g = branching_graph(
        "d", [("conv", 1e6, 0, 10)] * 4, [(0, 1), (0, 2), (1, 3), (2, 3)]
    )
    sgs = g.partition([1, 0, 0, 0])
    assert len(sgs) == 1


def test_branching_convexity():
    # cut edges 0->1 and 1->3: naive components are {0,2,3} and {1}, but 1
    # depends on 0 and feeds 3 -> {0,2,3} is non-convex (subgraph-level
    # cycle) and must split so the quotient graph stays a DAG.
    g = branching_graph(
        "d", [("conv", 1e6, 0, 10)] * 4, [(0, 1), (0, 2), (1, 3), (2, 3)]
    )
    sgs = g.partition([1, 0, 1, 0])
    comp = {lid: s.sg_index for s in sgs for lid in s.layer_ids}
    # layer 3 cannot be compiled with 0 while 1 is external in between
    assert comp[3] != comp[0]
    # quotient order respects dependencies
    for e in g.edges:
        assert comp[e.src] <= comp[e.dst]


def test_merkle_stable_and_config_sensitive():
    g = make_chain(5)
    sgs = g.partition([0, 1, 0, 0])
    h1 = sgs[0].merkle_hash()
    h2 = g.partition([0, 1, 0, 0])[0].merkle_hash()
    assert h1 == h2
    assert sgs[0].merkle_hash(extra=(1, "fp16")) != h1
    assert sgs[0].merkle_hash() != sgs[1].merkle_hash()


def test_merkle_same_structure_same_hash():
    # identical subgraph content in different graphs -> same hash (DB reuse)
    g1 = make_chain(6)
    g2 = chain_graph("other", [("conv", 1e6, 100, 1000)] * 6)
    h1 = g1.partition([1, 0, 0, 0, 0])[1].merkle_hash()
    h2 = g2.partition([1, 0, 0, 0, 0])[1].merkle_hash()
    assert h1 == h2


def test_edge_validation():
    layers = [Layer(0, "a", "conv"), Layer(1, "b", "conv")]
    with pytest.raises(ValueError):
        ModelGraph("bad", layers, [Edge(0, 1, 0, 10)])  # backward edge


@st.composite
def random_dag(draw):
    n = draw(st.integers(3, 14))
    layers = [Layer(i, f"l{i}", "conv", macs=1e6, out_bytes=100) for i in range(n)]
    edges = []
    k = 0
    for i in range(n - 1):  # chain backbone keeps it connected
        edges.append(Edge(k, i, i + 1, 100))
        k += 1
    extra = draw(st.lists(
        st.tuples(st.integers(0, n - 2), st.integers(1, n - 1)), max_size=6))
    for s, d in extra:
        if s < d and (s, d) not in [(e.src, e.dst) for e in edges]:
            edges.append(Edge(k, s, d, 100))
            k += 1
    return ModelGraph("r", layers, edges)


@settings(max_examples=60, deadline=None)
@given(random_dag(), st.data())
def test_partition_properties(g, data):
    bits = data.draw(st.lists(st.integers(0, 1), min_size=g.num_edges,
                              max_size=g.num_edges))
    sgs = g.partition(bits)
    # 1. exact cover of layers
    covered = sorted(lid for s in sgs for lid in s.layer_ids)
    assert covered == list(range(g.num_layers))
    # 2. quotient graph is a DAG with topological order = sg_index order
    comp = {lid: s.sg_index for s in sgs for lid in s.layer_ids}
    for e in g.edges:
        assert comp[e.src] <= comp[e.dst]
    # 3. MAC conservation
    assert abs(sum(s.macs for s in sgs) - g.total_macs) < 1e-3
