"""Profiler backends, Merkle caching, zoo models, Table 2/3/4 consistency."""
import pytest

from repro.core import (
    AnalyticMobileBackend,
    JaxExecBackend,
    LaneRooflineBackend,
    ProfileDB,
    Profiler,
    decode_solution,
    mobile_processors,
    tpu_lanes,
    whole_model_placement,
    Solution,
    TableBackend,
)
from repro.zoo import (
    MODEL_NAMES,
    all_cost_graphs,
    executable_zoo,
    make_cost_graph,
    paper_profile_tables,
)


@pytest.fixture(scope="module")
def procs():
    return mobile_processors()


@pytest.fixture(scope="module")
def graphs():
    return all_cost_graphs()


def test_zoo_graphs_match_table6(graphs):
    from repro.zoo.profiles import MODEL_SPECS
    for name, g in graphs.items():
        assert g.total_macs == pytest.approx(MODEL_SPECS[name]["macs"], rel=1e-6)
        assert g.num_layers == MODEL_SPECS[name]["layers"]
        assert g.validate_acyclic()


def test_table_backend_whole_model_matches_paper(procs, graphs):
    """Whole-model times on each processor == Table 3 (plus overhead)."""
    from repro.zoo.profiles import best_processor_times_s
    tables = paper_profile_tables()
    backend = TableBackend(processors=procs, tables=tables)
    best = best_processor_times_s()
    for name in MODEL_NAMES:
        g = graphs[name]
        p = whole_model_placement(g, 0, processor=2, dtype_ix=1, backend_ix=0)
        t = backend.measure(p) - procs[2].invocation_overhead
        assert t == pytest.approx(best[name]["npu"], rel=0.05)


def test_fragmentation_matches_table4_direction(procs, graphs):
    """Σ(single-layer subgraphs) vs whole graph reproduces the sign and
    rough magnitude of the paper's non-linearity ratios (Table 4)."""
    tables = paper_profile_tables()
    backend = TableBackend(processors=procs, tables=tables)
    prof = Profiler(backend)
    name = "mosaic"
    g = graphs[name]
    whole = prof.subgraph_time(whole_model_placement(g, 0, 2, 1, 0))
    sol = Solution(
        partition=[[1] * g.num_edges], mapping=[[2] * g.num_layers],
        priority=[0], dtype=[1], backend=[0],
    )
    placed = decode_solution(sol, [g])[0]
    summed = sum(prof.subgraph_time(p) for p in placed)
    ratio = summed / whole
    assert 1.3 < ratio < 4.5  # NPU: estimated overshoots measured (1.4-3.45)


def test_profile_db_merkle_cache(procs, graphs):
    tables = paper_profile_tables()
    db = ProfileDB()
    prof = Profiler(TableBackend(processors=procs, tables=tables), db)
    p = whole_model_placement(graphs["yolov8n"], 0, 2, 1, 0)
    t1 = prof.subgraph_time(p)
    assert db.misses == 1
    t2 = prof.subgraph_time(p)
    assert t1 == t2
    assert db.hits == 1


def test_profile_db_persistence(tmp_path, procs, graphs):
    path = str(tmp_path / "db.json")
    db = ProfileDB(path)
    prof = Profiler(TableBackend(processors=procs, tables=paper_profile_tables()), db)
    p = whole_model_placement(graphs["yolov8n"], 0, 2, 1, 0)
    t1 = prof.subgraph_time(p)
    db.save()
    db2 = ProfileDB(path)
    prof2 = Profiler(TableBackend(processors=procs, tables=paper_profile_tables()), db2)
    assert prof2.subgraph_time(p) == t1
    assert db2.hits == 1 and db2.misses == 0


def test_analytic_backend_unsupported_config_penalty(procs, graphs):
    backend = AnalyticMobileBackend(procs)
    # NPU has no fp32 kernels -> fallback penalty makes fp32 far slower
    p16 = whole_model_placement(graphs["yolov8n"], 0, 2, 1, 0)
    p32 = whole_model_placement(graphs["yolov8n"], 0, 2, 0, 0)
    assert backend.measure(p32) > 5 * backend.measure(p16)


def test_jax_exec_backend_device_in_the_loop():
    """Literal device-in-the-loop: really runs a jitted subgraph on CPU."""
    zoo = executable_zoo(names=["face_det"], channels=4, spatial=8)
    backend = JaxExecBackend(zoo, repeats=2)
    g = zoo["face_det"].graph
    p = whole_model_placement(g, 0, 0, 0, 0)
    t = backend.measure(p)
    assert 0 < t < 5.0  # executed for real, in sane time


def test_jax_exec_nonlinearity_is_real():
    """Cutting a real jitted model changes measured time (XLA fusion loss +
    per-call overhead) — the non-linearity of §2.1.2 observed live."""
    zoo = executable_zoo(names=["selfie_seg"], channels=4, spatial=8)
    backend = JaxExecBackend(zoo, repeats=3)
    prof = Profiler(backend)
    g = zoo["selfie_seg"].graph
    whole = prof.subgraph_time(whole_model_placement(g, 0, 0, 0, 0))
    sol = Solution(
        partition=[[1] * g.num_edges], mapping=[[0] * g.num_layers],
        priority=[0], dtype=[0], backend=[0],
    )
    placed = decode_solution(sol, [g])[0]
    summed = sum(prof.subgraph_time(p) for p in placed)
    assert summed != pytest.approx(whole, rel=0.05)


def test_lane_roofline_backend_biggest_not_always_best():
    lanes = tpu_lanes((128, 8))
    backend = LaneRooflineBackend(lanes)
    small = make_cost_graph("face_det")
    big = make_cost_graph("fastsam_s")
    t_small_big_lane = backend.measure(whole_model_placement(small, 0, 0, 1, 0))
    t_small_small_lane = backend.measure(whole_model_placement(small, 0, 1, 1, 0))
    # tiny model: big lane's efficiency collapse means the small lane wins
    # or at least is competitive
    assert t_small_small_lane < t_small_big_lane * 10
    t_big_big = backend.measure(whole_model_placement(big, 0, 0, 1, 0))
    t_big_small = backend.measure(whole_model_placement(big, 0, 1, 1, 0))
    assert t_big_big < t_big_small  # big model wants the big lane


def test_executable_zoo_branching_subgraph():
    """add_merge layers with external skip inputs execute correctly."""
    zoo = executable_zoo(names=["hand_det"], channels=4, spatial=8)
    m = zoo["hand_det"]
    skips = [layer.index for layer in m.graph.layers
             if layer.op_type == "add_merge"]
    assert skips, "hand_det should have merge layers"
    # subgraph starting at a merge layer -> two external inputs
    fn, args = m.build_subgraph_fn([skips[0]], "fp32")
    out = fn(*args)
    import numpy as np
    assert not np.any(np.isnan(np.asarray(out)))
