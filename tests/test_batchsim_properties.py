"""Property-based differential parity: batchsim == fastsim == reference DES.

Randomized scenarios, solutions and noise seeds drive all three evaluation
engines; every comparison demands *bit-identical* results (zero max-abs
diff), not approximate agreement — the fast paths are exactness-preserving
rewrites, so any ulp of drift is a bug. ``test_bulk_differential_parity``
alone covers 200+ randomized cases with deterministic seeds (independent of
whether real hypothesis is installed), and
``test_bulk_differential_parity_arrivals`` adds 100+ cases with randomized
arrival specs (jittered / Poisson / trace) replayed through all **four**
tiers including the virtual-clock PuzzleRuntime; the ``@given`` tests add
shrinking and deeper generation when hypothesis is installed.
``test_compiled_tier_differential_spot_check`` extends the differential to
the opt-in compiled (jax) tier, which is tolerance-bounded rather than
bit-exact; its exhaustive suite is ``tests/test_batchsim_compiled.py``.

Also holds the genetic-operator invariants the engines rely on: UPMX keeps
priorities a permutation, mutation keeps every gene in range.
"""
import math
import random

from _hypothesis_compat import given, settings, st

from repro.core import (
    ArrivalSpec,
    BatchLane,
    BatchSimulator,
    FastSimulator,
    NoiseModel,
    PAPER_COMM_MODEL,
    Profiler,
    RuntimeSimulator,
    SolutionFactory,
    batch_objectives,
    branching_graph,
    build_spec,
    chain_graph,
    decode_solution,
    mobile_processors,
    run_batch,
    upmx,
)
from repro.core.profiler import AnalyticMobileBackend

PROCS = mobile_processors()
PROFILER = Profiler(AnalyticMobileBackend(PROCS))


def _random_problem(rng: random.Random):
    """A small random multi-network scenario (kept tiny: the DES is slow)."""
    n_nets = rng.randint(2, 4)
    nets = []
    for n in range(n_nets):
        n_layers = rng.randint(2, 5)
        layers = [
            (rng.choice(["conv", "fc", "dw"]),
             rng.uniform(5e5, 8e6),
             rng.uniform(200, 3000),
             rng.uniform(500, 6000))
            for _ in range(n_layers)
        ]
        if rng.random() < 0.5 or n_layers < 3:
            g = chain_graph(f"n{n}", layers)
        else:
            edges = [(i, i + 1) for i in range(n_layers - 1)]
            edges += [(0, n_layers - 1)]  # one skip edge -> a diamond
            g = branching_graph(f"n{n}", layers, edges)
        nets.append(g)
    if n_nets == 2 or rng.random() < 0.4:
        groups = [list(range(n_nets))]
    else:
        cut = rng.randint(1, n_nets - 1)
        groups = [list(range(cut)), list(range(cut, n_nets))]
    periods = [rng.uniform(0.0005, 0.006) for _ in groups]
    return nets, groups, periods


def _assert_identical(ref, other, tag=""):
    assert len(ref.requests) == len(other.requests), tag
    for a, b in zip(ref.requests, other.requests):
        assert (a.group, a.request) == (b.group, b.request), tag
        assert a.arrival == b.arrival, tag
        assert a.first_start == b.first_start, tag
        assert a.last_finish == b.last_finish, tag
        assert a.done_tasks == b.done_tasks, tag
        assert a.total_tasks == b.total_tasks, tag
        assert a.makespan == b.makespan or (
            math.isinf(a.makespan) and math.isinf(b.makespan)), tag
    assert len(ref.tasks) == len(other.tasks), tag
    for a, b in zip(ref.tasks, other.tasks):
        assert (a.group, a.request, a.network, a.sg_index, a.processor) == (
            b.group, b.request, b.network, b.sg_index, b.processor), tag
        assert a.released == b.released, tag
        assert a.started == b.started, tag
        assert a.finished == b.finished, tag
        assert a.comm_time == b.comm_time, tag
        assert a.quant_time == b.quant_time, tag
        assert a.exec_time == b.exec_time, tag
    assert ref.busy_time == other.busy_time, tag
    assert ref.horizon == other.horizon, tag


def _run_three_engines(rng: random.Random, measured: bool):
    """One random case through DES, fastsim and batchsim; assert identity."""
    nets, groups, periods = _random_problem(rng)
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(rng.randrange(1 << 30)),
                          cut_prob=rng.uniform(0.1, 0.5))
    sol = fac.random_solution()
    num_requests = rng.randint(3, 6)
    noise = NoiseModel(seed=rng.randrange(1 << 16)) if measured else None
    dispatch = 150e-6 if measured else 0.0

    placed = decode_solution(sol, nets)
    ref = RuntimeSimulator(
        placed=placed, processors=PROCS, profiler=PROFILER,
        comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods,
        num_requests=num_requests, noise=noise, dispatch_overhead=dispatch,
    ).run()
    spec = build_spec(placed, PROCS, PROFILER, PAPER_COMM_MODEL)
    fast = FastSimulator(
        spec, groups=groups, periods=periods, num_requests=num_requests,
        noise=noise, dispatch_overhead=dispatch,
    ).run(collect_tasks=True)
    batch = BatchSimulator(
        [BatchLane(spec=spec, periods=periods, num_requests=num_requests,
                   noise=noise, dispatch_overhead=dispatch)],
        groups, PROCS,
    ).run(collect_tasks=True)
    _assert_identical(ref, fast, "fastsim-vs-des")
    _assert_identical(ref, batch.result(0), "batchsim-vs-des")
    return ref


def test_bulk_differential_parity():
    """≥200 randomized cases, zero max-abs diff across all three engines.

    Deterministic seeds, so this guarantee does not depend on hypothesis
    being installed. Half the cases run the measured path (lognormal noise
    + dispatch-token injection) — the tie-breaking-sensitive configuration.
    """
    cases = 0
    for seed in range(100):
        _run_three_engines(random.Random(0xB47C0 + seed), measured=False)
        cases += 1
    for seed in range(100):
        _run_three_engines(random.Random(0x90153 + seed), measured=True)
        cases += 1
    assert cases >= 200


# -- arrival-process differential parity (all four tiers) ---------------------

def _random_arrival(rng: random.Random, groups, periods, num_requests):
    """A random non-trivial arrival spec (sometimes periodic as control)."""
    kind = rng.choice(("periodic", "jittered", "jittered-lognormal",
                       "poisson", "trace"))
    if kind == "periodic":
        return rng.choice((None, ArrivalSpec()))
    if kind == "jittered":
        return ArrivalSpec(kind="jittered", jitter=rng.uniform(0.05, 1.5),
                           seed=rng.randrange(1 << 16))
    if kind == "jittered-lognormal":
        return ArrivalSpec(kind="jittered", distribution="lognormal",
                           jitter=rng.uniform(0.1, 0.8),
                           sigma=rng.uniform(0.1, 0.9),
                           seed=rng.randrange(1 << 16))
    if kind == "poisson":
        return ArrivalSpec(kind="poisson", seed=rng.randrange(1 << 16))
    # trace: random timestamps incl. ties, regressions and gaps — the
    # generator's monotone-clamp path must keep all tiers in lock-step
    trace = []
    for gid, period in enumerate(periods):
        n = rng.randint(0, num_requests + 2)
        ts = [rng.uniform(0.0, num_requests * period) for _ in range(n)]
        if ts and rng.random() < 0.5:
            ts.sort()
        if ts and rng.random() < 0.3:
            ts[rng.randrange(len(ts))] = ts[0]  # force a tie
        trace.append(tuple(ts))
    return ArrivalSpec(kind="trace", trace=tuple(trace))


def _run_four_engines(rng: random.Random, measured: bool):
    """One random arrival-spec case through DES, fastsim, batchsim AND the
    virtual-clock PuzzleRuntime; assert bit-identical traces."""
    from repro.runtime.conformance import run_virtual_schedule

    nets, groups, periods = _random_problem(rng)
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(rng.randrange(1 << 30)),
                          cut_prob=rng.uniform(0.1, 0.5))
    sol = fac.random_solution()
    num_requests = rng.randint(3, 6)
    arrivals = _random_arrival(rng, groups, periods, num_requests)
    noise = NoiseModel(seed=rng.randrange(1 << 16)) if measured else None
    dispatch = 150e-6 if measured else 0.0

    placed = decode_solution(sol, nets)
    ref = RuntimeSimulator(
        placed=placed, processors=PROCS, profiler=PROFILER,
        comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods,
        num_requests=num_requests, noise=noise, dispatch_overhead=dispatch,
        arrivals=arrivals,
    ).run()
    spec = build_spec(placed, PROCS, PROFILER, PAPER_COMM_MODEL)
    fast = FastSimulator(
        spec, groups=groups, periods=periods, num_requests=num_requests,
        noise=noise, dispatch_overhead=dispatch, arrivals=arrivals,
    ).run(collect_tasks=True)
    batch = BatchSimulator(
        [BatchLane(spec=spec, periods=periods, num_requests=num_requests,
                   noise=noise, dispatch_overhead=dispatch,
                   arrivals=arrivals)],
        groups, PROCS,
    ).run(collect_tasks=True)
    virtual = run_virtual_schedule(
        nets, sol, PROCS, spec, groups, periods, num_requests,
        noise=noise, dispatch_overhead=dispatch, arrivals=arrivals,
    )
    _assert_identical(ref, fast, "arrivals:fastsim-vs-des")
    _assert_identical(ref, batch.result(0), "arrivals:batchsim-vs-des")
    _assert_identical(ref, virtual, "arrivals:virtual-runtime-vs-des")
    return arrivals


def test_bulk_differential_parity_arrivals():
    """100+ randomized arrival-spec cases, zero max-abs diff across all
    FOUR engine tiers (reference DES, fastsim, batchsim, virtual-clock
    PuzzleRuntime); half measured (noise + dispatch tokens)."""
    cases = 0
    kinds = set()
    for seed in range(55):
        spec = _run_four_engines(random.Random(0xA221E + seed),
                                 measured=False)
        kinds.add(spec.kind if spec is not None else "periodic")
        cases += 1
    for seed in range(55):
        spec = _run_four_engines(random.Random(0x7A913 + seed),
                                 measured=True)
        kinds.add(spec.kind if spec is not None else "periodic")
        cases += 1
    assert cases >= 100
    # the draw actually exercised every process family
    assert kinds >= {"periodic", "jittered", "poisson", "trace"}, kinds


@given(st.integers(min_value=0, max_value=1 << 30))
@settings(max_examples=15, deadline=None)
def test_property_parity_arrivals(seed):
    rng = random.Random(seed)
    _run_four_engines(rng, measured=rng.random() < 0.5)


def test_bulk_parity_overload():
    """Dropped-request (inf makespan) cases agree across engines."""
    saw_drop = False
    for seed in range(12):
        rng = random.Random(0xD209 + seed)
        nets, groups, _ = _random_problem(rng)
        periods = [2e-6 for _ in groups]  # hopeless overload
        fac = SolutionFactory(nets, num_processors=len(PROCS),
                              rng=random.Random(seed), cut_prob=0.3)
        sol = fac.random_solution()
        placed = decode_solution(sol, nets)
        ref = RuntimeSimulator(
            placed=placed, processors=PROCS, profiler=PROFILER,
            comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods,
            num_requests=40,
        ).run()
        spec = build_spec(placed, PROCS, PROFILER, PAPER_COMM_MODEL)
        batch = BatchSimulator(
            [BatchLane(spec=spec, periods=periods, num_requests=40)],
            groups, PROCS,
        ).run(collect_tasks=True)
        _assert_identical(ref, batch.result(0))
        saw_drop = saw_drop or any(math.isinf(m) for m in batch.makespans(0))
    assert saw_drop, "overload cases never dropped a request"


@given(st.integers(min_value=0, max_value=1 << 30))
@settings(max_examples=30, deadline=None)
def test_property_parity_clean(seed):
    _run_three_engines(random.Random(seed), measured=False)


@given(st.integers(min_value=0, max_value=1 << 30))
@settings(max_examples=20, deadline=None)
def test_property_parity_measured(seed):
    _run_three_engines(random.Random(seed), measured=True)


@given(st.integers(min_value=0, max_value=1 << 30))
@settings(max_examples=10, deadline=None)
def test_property_batch_width_invariance(seed):
    """A lane's result is independent of what else shares its batch, and of
    process-pool sharding — lanes are isolated."""
    rng = random.Random(seed)
    nets, groups, periods = _random_problem(rng)
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(seed), cut_prob=0.3)
    sols = [fac.random_solution() for _ in range(5)]
    specs = [build_spec(decode_solution(s, nets), PROCS, PROFILER,
                        PAPER_COMM_MODEL) for s in sols]
    lanes = [
        BatchLane(spec=sp, periods=periods, num_requests=3 + (i % 3),
                  noise=NoiseModel(seed=i) if i % 2 else None,
                  dispatch_overhead=150e-6 if i % 2 else 0.0)
        for i, sp in enumerate(specs)
    ]
    wide = BatchSimulator(lanes, groups, PROCS).run()
    for i, lane in enumerate(lanes):
        solo = BatchSimulator([lane], groups, PROCS).run()
        assert wide.makespans(i) == solo.makespans(0)
        assert wide.result(i).busy_time == solo.result(0).busy_time
    sharded = run_batch(lanes, groups, PROCS, workers=2)
    assert batch_objectives(sharded) == batch_objectives(wide)


# -- genetic-operator invariants ---------------------------------------------

@given(st.integers(min_value=0, max_value=1 << 30))
@settings(max_examples=30, deadline=None)
def test_property_upmx_keeps_permutations(seed):
    rng = random.Random(seed)
    n = rng.randint(1, 12)
    p1 = list(range(n))
    p2 = list(range(n))
    rng.shuffle(p1)
    rng.shuffle(p2)
    c1, c2 = upmx(list(p1), list(p2), rng, indpb=rng.uniform(0.0, 1.0))
    assert sorted(c1) == list(range(n))
    assert sorted(c2) == list(range(n))


@given(st.integers(min_value=0, max_value=1 << 30))
@settings(max_examples=30, deadline=None)
def test_property_crossover_mutation_invariants(seed):
    """Chromosomes stay well-formed under crossover + mutation."""
    rng = random.Random(seed)
    nets, _, _ = _random_problem(rng)
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(seed + 1), cut_prob=0.3)

    def check(sol):
        assert sorted(sol.priority) == list(range(len(nets)))
        for net, g in enumerate(nets):
            assert len(sol.partition[net]) == g.num_edges
            assert all(b in (0, 1) for b in sol.partition[net])
            assert len(sol.mapping[net]) == g.num_layers
            assert all(0 <= m < len(PROCS) for m in sol.mapping[net])
        assert all(0 <= d < fac.num_dtypes for d in sol.dtype)
        assert all(0 <= b < fac.num_backends for b in sol.backend)

    a, b = fac.random_solution(), fac.random_solution()
    check(a)
    check(b)
    c1, c2 = fac.crossover(a, b)
    check(c1)
    check(c2)
    m = fac.mutate(c1, p_bit=0.3, p_map=0.3, p_prio=0.9, p_cfg=0.5)
    check(m)
    # mutation copies: the parent is untouched
    check(c1)


def test_compiled_tier_differential_spot_check():
    """Opt-in compiled tier vs fastsim vs numpy batch on randomized cases
    (arrivals + noise + dispatch tokens + a fault ensemble), within the
    compiled tier's documented tolerance — observed diff is exactly 0.0.
    The exhaustive compiled suite (all golden traces, fallback contract)
    lives in tests/test_batchsim_compiled.py."""
    import pytest

    pytest.importorskip("jax")
    import repro.core.batchsim_compiled as bsc
    from repro.core import (
        COMPILED_ABS_TOL,
        COMPILED_REL_TOL,
        FaultSpec,
        run_batch_compiled,
    )

    def close(a, b):
        if math.isinf(a) or math.isinf(b):
            return math.isinf(a) and math.isinf(b)
        return abs(a - b) <= COMPILED_ABS_TOL + COMPILED_REL_TOL * max(
            abs(a), abs(b))

    for seed, faulted in ((0xC0119, False), (0xC011A, True)):
        rng = random.Random(seed)
        nets, groups, periods = _random_problem(rng)
        fac = SolutionFactory(nets, num_processors=len(PROCS),
                              rng=random.Random(seed + 1), cut_prob=0.3)
        lanes = []
        for i in range(3):
            spec = build_spec(decode_solution(fac.random_solution(), nets),
                              PROCS, PROFILER, PAPER_COMM_MODEL)
            nr = rng.randint(3, 6)
            faults = FaultSpec(
                dropouts=((rng.randrange(len(PROCS)), 0.0, 0.004),),
                straggler_prob=0.3, straggler_shape=1.5,
                seed=rng.randrange(1 << 16),
            ) if faulted else None
            lanes.append(BatchLane(
                spec=spec, periods=periods, num_requests=nr,
                noise=NoiseModel(seed=rng.randrange(1 << 16)),
                dispatch_overhead=150e-6,
                arrivals=_random_arrival(rng, groups, periods, nr),
                faults=faults))
        comp = run_batch_compiled(lanes, groups, PROCS)
        assert comp is not None and bsc.last_stats["fallback"] is False
        ref = BatchSimulator(lanes, groups, PROCS).run()
        for i, lane in enumerate(lanes):
            fast = FastSimulator(
                lane.spec, groups=groups, periods=lane.periods,
                num_requests=lane.num_requests, noise=lane.noise,
                dispatch_overhead=lane.dispatch_overhead,
                arrivals=lane.arrivals, faults=lane.faults,
            ).run()
            for tier in (ref.result(i), fast):
                cr = comp.result(i)
                assert len(tier.requests) == len(cr.requests)
                for qa, qb in zip(tier.requests, cr.requests):
                    assert qa.done_tasks == qb.done_tasks
                    assert close(qa.makespan, qb.makespan)
                    assert close(qa.first_start, qb.first_start)
                    assert close(qa.last_finish, qb.last_finish)
                for pid in tier.busy_time:
                    assert close(tier.busy_time[pid], cr.busy_time[pid])
