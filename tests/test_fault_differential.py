"""Fault-injection differential parity: all four tiers, zero divergence.

Randomized fault ensembles (dropouts permanent and repairable, throttle
windows, heavy-tailed stragglers — often stacked with noise, dispatch
tokens and non-periodic arrivals) drive the reference DES, FastSimulator,
BatchSimulator and the virtual-clock PuzzleRuntime; every comparison
demands *bit-identical* traces. The shared :class:`FaultStream` draws in
global delivery order, so any tier whose delivery sequence drifts under
faults fails here loudly.

``test_bulk_differential_parity_faults`` covers 100+ randomized cases with
deterministic seeds. Run as a script to produce the CI artifact::

    PYTHONPATH=src:tests python tests/test_fault_differential.py \
        --report results/fault_report.json
"""
import json
import math
import os
import random
import sys

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    NO_FAULTS,
    BatchLane,
    BatchSimulator,
    FastSimulator,
    FaultSpec,
    NoiseModel,
    PAPER_COMM_MODEL,
    Profiler,
    RuntimeSimulator,
    SolutionFactory,
    build_spec,
    decode_solution,
    mobile_processors,
)
from repro.core.profiler import AnalyticMobileBackend
from repro.runtime.conformance import run_virtual_schedule

from test_batchsim_properties import (
    _assert_identical,
    _random_arrival,
    _random_problem,
)

PROCS = mobile_processors()
PROFILER = Profiler(AnalyticMobileBackend(PROCS))


# -- FaultSpec unit behaviour -------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(dropouts=((0, -0.1, None),))
    with pytest.raises(ValueError):
        FaultSpec(dropouts=((0, 0.1, 0.0),))
    with pytest.raises(ValueError):
        FaultSpec(throttles=((0, 0.5, 0.5, 2.0),))
    with pytest.raises(ValueError):
        FaultSpec(throttles=((0, 0.1, 0.5, 0.0),))
    with pytest.raises(ValueError):
        FaultSpec(straggler_prob=1.0)
    with pytest.raises(ValueError):
        FaultSpec(straggler_prob=0.5, straggler_shape=0.0)


def test_fault_spec_canonicalization():
    a = FaultSpec(dropouts=((2, 0.5, None), (1, 0.1, 0.2)),
                  throttles=((1, 0.4, 0.6, 2.0), (0, 0.1, 0.3, 3.0)))
    b = FaultSpec(dropouts=((1, 0.1, 0.2), (2, 0.5, None)),
                  throttles=((0, 0.1, 0.3, 3.0), (1, 0.4, 0.6, 2.0)))
    assert a == b
    assert hash(a) == hash(b)
    assert a.key() == b.key()
    # shape is zeroed when stragglers are off: one representation per ensemble
    assert FaultSpec(straggler_shape=1.5) == FaultSpec(straggler_shape=9.0)


def test_fault_spec_json_round_trip():
    spec = FaultSpec(dropouts=((2, 0.012, None), (1, 0.002, 0.004)),
                     throttles=((0, 0.002, 0.008, 3.0),),
                     straggler_prob=0.2, straggler_shape=1.5, seed=13)
    doc = json.loads(json.dumps(spec.to_json()))
    assert FaultSpec.from_json(doc) == spec
    # serialize-by-omission: the empty spec is just its seed
    assert FaultSpec(seed=7).to_json() == {"seed": 7}
    assert FaultSpec.from_json({"seed": 7}) == FaultSpec(seed=7)


def test_fault_spec_empty_and_dropped_pids():
    assert NO_FAULTS.empty
    assert FaultSpec(seed=99).empty
    spec = FaultSpec(dropouts=((3, 0.01, None), (1, 0.02, 0.5),
                               (0, 0.03, None)))
    assert not spec.empty
    assert spec.dropped_pids() == (0, 3)  # permanent only, sorted


def test_empty_faults_match_no_faults():
    """faults=NO_FAULTS must be byte-identical to faults=None (the engines
    normalize empty specs away, so the clean path is untouched)."""
    rng = random.Random(0xFA017)
    nets, groups, periods = _random_problem(rng)
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(3), cut_prob=0.3).random_solution()
    placed = decode_solution(sol, nets)
    noise = NoiseModel(seed=5)
    kw = dict(placed=placed, processors=PROCS, profiler=PROFILER,
              comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods,
              num_requests=4, noise=noise, dispatch_overhead=150e-6)
    clean = RuntimeSimulator(**kw).run()
    empty = RuntimeSimulator(faults=NO_FAULTS, **kw).run()
    _assert_identical(clean, empty, "empty-faults-vs-none")
    spec = build_spec(placed, PROCS, PROFILER, PAPER_COMM_MODEL)
    fast = FastSimulator(spec, groups=groups, periods=periods,
                         num_requests=4, noise=noise,
                         dispatch_overhead=150e-6,
                         faults=NO_FAULTS).run(collect_tasks=True)
    _assert_identical(clean, fast, "empty-faults-fastsim")


def test_faults_do_not_break_lean_path():
    """The lean fastsim loop must still be taken when no faults are set,
    and must be bypassed (identically) when they are."""
    rng = random.Random(0x1EA9)
    nets, groups, periods = _random_problem(rng)
    sol = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(4), cut_prob=0.3).random_solution()
    placed = decode_solution(sol, nets)
    spec = build_spec(placed, PROCS, PROFILER, PAPER_COMM_MODEL)
    faults = FaultSpec(throttles=((0, 0.0, 0.002, 2.0),), seed=1)
    ref = RuntimeSimulator(
        placed=placed, processors=PROCS, profiler=PROFILER,
        comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods,
        num_requests=4, faults=faults).run()
    fast = FastSimulator(spec, groups=groups, periods=periods,
                         num_requests=4, faults=faults).run(collect_tasks=True)
    _assert_identical(ref, fast, "faulted-full-loop")


# -- randomized four-tier parity ----------------------------------------------

def _random_fault(rng: random.Random, periods, num_requests) -> FaultSpec:
    """A random non-empty fault ensemble scaled to the run's time span."""
    span = max(periods) * num_requests
    dropouts = []
    for _ in range(rng.randint(0, 2)):
        pid = rng.randrange(len(PROCS))
        start = rng.uniform(0.0, span)
        repair = None if rng.random() < 0.5 else rng.uniform(
            0.05 * span, 0.5 * span)
        dropouts.append((pid, start, repair))
    throttles = []
    for _ in range(rng.randint(0, 2)):
        pid = rng.randrange(len(PROCS))
        t0 = rng.uniform(0.0, 0.8 * span)
        throttles.append((pid, t0, t0 + rng.uniform(0.05 * span, 0.6 * span),
                          rng.choice((0.5, 1.5, 2.0, 4.0))))
    prob = rng.choice((0.0, 0.1, 0.25, 0.5))
    spec = FaultSpec(
        dropouts=tuple(dropouts), throttles=tuple(throttles),
        straggler_prob=prob,
        straggler_shape=rng.choice((0.8, 1.5, 2.5)),
        seed=rng.randrange(1 << 16),
    )
    if spec.empty:  # re-roll into a guaranteed-active ensemble
        spec = FaultSpec(straggler_prob=0.25, straggler_shape=1.5,
                         seed=rng.randrange(1 << 16))
    return spec


def _run_four_engines_faults(rng: random.Random, measured: bool,
                             with_arrivals: bool = False):
    """One random faulted case through all four tiers; assert identity.

    Returns ``(spec, ref)`` so callers can track which fault classes the
    sweep actually exercised.
    """
    nets, groups, periods = _random_problem(rng)
    fac = SolutionFactory(nets, num_processors=len(PROCS),
                          rng=random.Random(rng.randrange(1 << 30)),
                          cut_prob=rng.uniform(0.1, 0.5))
    sol = fac.random_solution()
    num_requests = rng.randint(3, 6)
    faults = _random_fault(rng, periods, num_requests)
    arrivals = (_random_arrival(rng, groups, periods, num_requests)
                if with_arrivals else None)
    noise = NoiseModel(seed=rng.randrange(1 << 16)) if measured else None
    dispatch = 150e-6 if measured else 0.0

    placed = decode_solution(sol, nets)
    ref = RuntimeSimulator(
        placed=placed, processors=PROCS, profiler=PROFILER,
        comm_model=PAPER_COMM_MODEL, groups=groups, periods=periods,
        num_requests=num_requests, noise=noise, dispatch_overhead=dispatch,
        arrivals=arrivals, faults=faults,
    ).run()
    spec = build_spec(placed, PROCS, PROFILER, PAPER_COMM_MODEL)
    fast = FastSimulator(
        spec, groups=groups, periods=periods, num_requests=num_requests,
        noise=noise, dispatch_overhead=dispatch, arrivals=arrivals,
        faults=faults,
    ).run(collect_tasks=True)
    batch = BatchSimulator(
        [BatchLane(spec=spec, periods=periods, num_requests=num_requests,
                   noise=noise, dispatch_overhead=dispatch,
                   arrivals=arrivals, faults=faults)],
        groups, PROCS,
    ).run(collect_tasks=True)
    virtual = run_virtual_schedule(
        nets, sol, PROCS, spec, groups, periods, num_requests,
        noise=noise, dispatch_overhead=dispatch, arrivals=arrivals,
        faults=faults,
    )
    _assert_identical(ref, fast, "faults:fastsim-vs-des")
    _assert_identical(ref, batch.result(0), "faults:batchsim-vs-des")
    _assert_identical(ref, virtual, "faults:virtual-runtime-vs-des")
    return faults, ref


def _coverage_update(cov, faults, ref):
    if faults.dropped_pids():
        cov.add("permanent-dropout")
    if any(r is not None for _, _, r in faults.dropouts):
        cov.add("repairable-dropout")
    if faults.throttles:
        cov.add("throttle")
    if faults.straggler_prob > 0.0:
        cov.add("straggler")
    if any(math.isinf(r.makespan) for r in ref.requests):
        cov.add("dropped-request")


def _bulk_sweep(n_clean: int, n_measured: int, n_arrival: int):
    """The deterministic-seed fault sweep; returns (cases, coverage)."""
    cov = set()
    cases = 0
    for seed in range(n_clean):
        faults, ref = _run_four_engines_faults(
            random.Random(0xFA41 + seed), measured=False)
        _coverage_update(cov, faults, ref)
        cases += 1
    for seed in range(n_measured):
        faults, ref = _run_four_engines_faults(
            random.Random(0x5E11 + seed), measured=True)
        _coverage_update(cov, faults, ref)
        cases += 1
    for seed in range(n_arrival):
        faults, ref = _run_four_engines_faults(
            random.Random(0xC0DE + seed), measured=True, with_arrivals=True)
        _coverage_update(cov, faults, ref)
        cases += 1
    return cases, cov


def test_bulk_differential_parity_faults():
    """100+ randomized fault cases, zero max-abs diff across all FOUR
    engine tiers; the sweep must exercise every fault class, including
    requests actually dropped by a permanent dropout."""
    cases, cov = _bulk_sweep(40, 40, 25)
    assert cases >= 100
    assert cov >= {"permanent-dropout", "repairable-dropout", "throttle",
                   "straggler", "dropped-request"}, cov


@given(st.integers(min_value=0, max_value=1 << 30))
@settings(max_examples=15, deadline=None)
def test_property_parity_faults(seed):
    rng = random.Random(seed)
    _run_four_engines_faults(rng, measured=rng.random() < 0.5,
                             with_arrivals=rng.random() < 0.3)


def test_fault_stream_draw_discipline():
    """One rng.random() per service() call when stragglers are on — the
    stream position is a pure function of the delivery count."""
    spec = FaultSpec(straggler_prob=0.3, straggler_shape=1.5, seed=21)
    from repro.core import FaultStream
    a, b = FaultStream(spec), FaultStream(spec)
    # interleave different pids/times on one stream: draws must not depend
    # on pid (a tier whose per-pid order differs would otherwise diverge)
    out_a = [a.service(0, 0.001 * i, 1.0)[0] for i in range(50)]
    out_b = [b.service(i % 3, 0.002 * i, 1.0)[0] for i in range(50)]
    assert out_a == out_b
    inflated = sum(1 for v in out_a if v > 1.0)
    assert 0 < inflated < 50
    assert all(v >= 1.0 for v in out_a)


# -- CI artifact --------------------------------------------------------------

def write_report(out_path: str) -> int:
    """Fault golden + differential sweep through all four tiers; write the
    CI artifact. Returns the number of failures (0 = pass)."""
    import test_golden_traces as gt

    report = {"golden": {}, "differential": {}}
    failures = 0
    with open(os.path.join(gt.GOLDEN_DIR, "fault_dropout_mix.json")) as f:
        golden = json.load(f)
    for engine, res in gt._engine_results("fault_dropout_mix").items():
        diffs = gt._trace_diff(gt._serialize(res), golden)
        report["golden"][engine] = diffs
        if not diffs["exact"]:
            failures += 1
        print(f"fault_dropout_mix {engine:16s} "
              f"{'ok' if diffs['exact'] else 'DIFF'}")
    try:
        cases, cov = _bulk_sweep(40, 40, 25)
        report["differential"] = {
            "cases": cases, "coverage": sorted(cov), "passed": True}
    except AssertionError as e:
        failures += 1
        report["differential"] = {"passed": False, "error": str(e)}
    print(f"differential: {report['differential']}")
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {out_path}")
    return failures


if __name__ == "__main__":
    out = "results/fault_report.json"
    if "--report" in sys.argv:
        idx = sys.argv.index("--report")
        if idx + 1 < len(sys.argv):
            out = sys.argv[idx + 1]
    sys.exit(1 if write_report(out) else 0)
