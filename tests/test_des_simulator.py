"""Discrete-event engine and runtime simulator behaviour."""
import pytest

from repro.core import (
    Environment,
    NoiseModel,
    PAPER_COMM_MODEL,
    PriorityStore,
    Profiler,
    RuntimeSimulator,
    chain_graph,
    decode_solution,
    mobile_processors,
    Solution,
)
from repro.core.profiler import AnalyticMobileBackend


# -- DES engine -----------------------------------------------------------

def test_timeout_ordering():
    env = Environment()
    log = []

    def proc(tag, delay):
        yield env.timeout(delay)
        log.append((tag, env.now))

    env.process(proc("b", 2.0))
    env.process(proc("a", 1.0))
    env.run()
    assert log == [("a", 1.0), ("b", 2.0)]


def test_process_chain_and_store():
    env = Environment()
    store = PriorityStore(env)
    got = []

    def consumer():
        while True:
            item = yield store.get()
            got.append((item, env.now))

    def producer():
        yield env.timeout(1.0)
        store.put("low", priority=5)
        store.put("high", priority=1)
        yield env.timeout(1.0)
        store.put("later", priority=0)

    env.process(consumer())
    env.process(producer())
    env.run(until=10)
    # 'low' delivered first (consumer already waiting when it was put),
    # then 'high' (by priority among queued), then 'later'.
    assert [g[0] for g in got] == ["low", "high", "later"]


def test_priority_store_fifo_within_priority():
    env = Environment()
    store = PriorityStore(env)
    store.put("x", priority=1)
    store.put("y", priority=1)
    store.put("z", priority=0)
    order = []

    def consumer():
        for _ in range(3):
            item = yield store.get()
            order.append(item)

    env.process(consumer())
    env.run()
    assert order == ["z", "x", "y"]


# -- runtime simulator -------------------------------------------------------

def _one_model_setup(n_layers=4, cuts=None, procs_map=None):
    g = chain_graph("m", [("conv", 50e6, 1000, 50_000)] * n_layers)
    graphs = [g]
    procs = mobile_processors()
    prof = Profiler(AnalyticMobileBackend(procs))
    cuts = cuts or [0] * g.num_edges
    mapping = procs_map or [2] * n_layers
    sol = Solution(
        partition=[cuts], mapping=[mapping], priority=[0], dtype=[1], backend=[0]
    )
    placed = decode_solution(sol, graphs)
    return placed, procs, prof


def test_single_model_makespan_equals_exec_plus_comm():
    placed, procs, prof = _one_model_setup()
    sim = RuntimeSimulator(
        placed, procs, prof, PAPER_COMM_MODEL,
        groups=[[0]], periods=[10.0], num_requests=3,
    )
    res = sim.run()
    ms = res.makespans(0)
    assert len(ms) == 3
    exec_t = prof.subgraph_time(placed[0][0])
    comm_in = PAPER_COMM_MODEL.cost(placed[0][0].subgraph.input_bytes())
    assert ms[0] == pytest.approx(exec_t + comm_in, rel=1e-6)
    # uncontended: all requests identical
    assert ms[0] == pytest.approx(ms[-1], rel=1e-6)


def test_queueing_under_tight_period():
    placed, procs, prof = _one_model_setup()
    exec_t = prof.subgraph_time(placed[0][0])
    tight = exec_t * 0.5
    sim = RuntimeSimulator(
        placed, procs, prof, PAPER_COMM_MODEL,
        groups=[[0]], periods=[tight], num_requests=8,
    )
    res = sim.run()
    ms = res.makespans(0)
    assert ms[-1] > ms[0] * 2  # queue grows when period < service time


def test_partition_pipelining_improves_throughput():
    # chain cut in half across two *identical* processors: steady-state
    # throughput doubles (pipelining across requests), so under a period
    # below the whole-model service time the cut solution stays stable
    # while the whole-model one diverges.
    from repro.core import Processor

    twin = tuple(
        Processor(
            pid=i, name=f"acc{i}", kind="npu",
            throughput=((("fp16", "default"), 1.6e12),),
            invocation_overhead=1e-6, layer_overhead=0.0,
            fragmentation_ratio=1.0,
        )
        for i in range(2)
    )
    g = chain_graph("m", [("conv", 500e6, 1000, 50_000)] * 4)
    prof = Profiler(AnalyticMobileBackend(twin))
    whole = Solution(partition=[[0, 0, 0]], mapping=[[0] * 4],
                     priority=[0], dtype=[1], backend=[0])
    cut = Solution(partition=[[0, 1, 0]], mapping=[[0, 0, 1, 1]],
                   priority=[0], dtype=[1], backend=[0])
    placed_whole = decode_solution(whole, [g])
    placed_cut = decode_solution(cut, [g])
    service = prof.subgraph_time(placed_whole[0][0])
    period = service * 0.7
    def run(placed):
        return RuntimeSimulator(
            placed, twin, prof, PAPER_COMM_MODEL,
            groups=[[0]], periods=[period], num_requests=12, input_home_pid=0,
        ).run().makespans(0)

    ms_whole, ms_cut = run(placed_whole), run(placed_cut)
    assert ms_whole[-1] > ms_whole[0] * 2      # diverging queue
    assert ms_cut[-1] < ms_cut[0] * 1.5        # pipeline keeps up
    assert ms_cut[-1] < ms_whole[-1]


def test_noise_determinism_and_effect():
    placed, procs, prof = _one_model_setup()
    def mk(seed):
        return RuntimeSimulator(
            placed, procs, prof, PAPER_COMM_MODEL,
            groups=[[0]], periods=[1.0], num_requests=5,
            noise=NoiseModel(seed=seed),
        ).run().makespans(0)

    a, b, c = mk(1), mk(1), mk(2)
    assert a == b                      # same seed -> same trace
    assert a != c                      # different seed -> different trace
    assert len(set(a)) > 1             # noise varies across requests


def test_dispatch_overhead_occupies_cpu():
    # model mapped to CPU: dispatch stubs compete with its tasks
    placed, procs, prof = _one_model_setup(procs_map=[0, 0, 0, 0])
    base = RuntimeSimulator(
        placed, procs, prof, PAPER_COMM_MODEL,
        groups=[[0]], periods=[1.0], num_requests=4,
    ).run().makespans(0)[0]
    loaded = RuntimeSimulator(
        placed, procs, prof, PAPER_COMM_MODEL,
        groups=[[0]], periods=[1.0], num_requests=4,
        dispatch_overhead=5e-3,
    ).run().makespans(0)[0]
    assert loaded > base


def test_utilization_accounting():
    placed, procs, prof = _one_model_setup()
    sim = RuntimeSimulator(
        placed, procs, prof, PAPER_COMM_MODEL,
        groups=[[0]], periods=[0.5], num_requests=4,
    )
    res = sim.run()
    assert res.busy_time[2] > 0.0
    assert res.busy_time[1] == 0.0
    assert 0.0 < res.utilization(2) <= 1.0
