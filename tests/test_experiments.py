"""Scenario-sweep harness: generation determinism, §6.1 invariants,
aggregation math, and end-to-end resume/worker-count determinism."""
import copy
import json
import math
import random

import pytest

from repro.core import base_periods, best_model_times, build_scenario, sample_groups
from repro.core.scoring import deadline_satisfaction
from repro.core import ArrivalSpec
from repro.experiments import (
    METHODS,
    ScenarioResult,
    ScenarioSpec,
    SweepConfig,
    aggregate_results,
    arrival_stream_seed,
    default_context,
    generate_scenario_specs,
    geometric_mean,
    run_sweep,
    scenario_stream_seed,
)
from repro.zoo import MODEL_NAMES

TINY = SweepConfig(pop_size=8, max_generations=6, min_generations=2,
                   bm_max_evals=30)


# -- scenario generation (§6.1) ---------------------------------------------

def test_specs_deterministic_and_prefix_stable():
    a = generate_scenario_specs(6, seed=3)
    b = generate_scenario_specs(6, seed=3)
    assert a == b
    # per-scenario streams: a shorter sweep is a prefix of a longer one
    assert generate_scenario_specs(3, seed=3) == a[:3]
    # a different sweep seed changes the compositions
    c = generate_scenario_specs(6, seed=4)
    assert [s.groups for s in c] != [s.groups for s in a]


def test_stream_seed_stable_across_processes():
    # SHA-256 derivation, not hash(): the value is a constant of (seed, index)
    assert scenario_stream_seed(0, 0) == scenario_stream_seed(0, 0)
    assert scenario_stream_seed(0, 0) != scenario_stream_seed(0, 1)
    assert 0 <= scenario_stream_seed(123, 456) < 2 ** 63


def test_spec_group_invariants():
    for spec in generate_scenario_specs(25, seed=11):
        assert 1 <= len(spec.groups) <= 3
        for group in spec.groups:
            assert 1 <= len(group) <= 4
            assert len(set(group)) == len(group)  # distinct within a group
            assert all(name in MODEL_NAMES for name in group)


def test_sample_groups_uses_only_caller_rng():
    g1 = sample_groups(random.Random(5), MODEL_NAMES)
    random.seed(999)  # global RNG state must be irrelevant
    g2 = sample_groups(random.Random(5), MODEL_NAMES)
    assert g1 == g2


def test_spec_json_roundtrip():
    spec = generate_scenario_specs(1, seed=9)[0]
    wire = json.loads(json.dumps(spec.to_json()))
    assert ScenarioSpec.from_json(wire) == spec


# -- arrival axis (this PR) ---------------------------------------------------

def test_arrival_axis_specs_deterministic():
    base = generate_scenario_specs(4, seed=5)
    poisson = generate_scenario_specs(4, seed=5, arrival="poisson")
    # same compositions, only the traffic changes
    assert [s.groups for s in poisson] == [s.groups for s in base]
    assert all(s.arrival is None for s in base)
    assert all(s.arrival.kind == "poisson" for s in poisson)
    # per-scenario SHA-256 arrival seeds: stable, distinct, independent of
    # the composition stream
    seeds = [s.arrival.seed for s in poisson]
    assert seeds == [arrival_stream_seed(5, i) for i in range(4)]
    assert len(set(seeds)) == 4
    assert generate_scenario_specs(4, seed=5, arrival="poisson") == poisson
    # "periodic" is spelled the old way: no arrival key in the JSON at all,
    # so pre-axis run dirs load (and resume) unchanged
    assert generate_scenario_specs(2, seed=5, arrival="periodic") == base[:2]
    assert "arrival" not in base[0].to_json()


def test_arrival_axis_spec_json_roundtrip():
    for kind, kw in (("poisson", {}),
                     ("jittered", dict(arrival_jitter=0.4)),
                     ("jittered", dict(arrival_jitter=0.2,
                                       arrival_distribution="lognormal"))):
        spec = generate_scenario_specs(2, seed=7, arrival=kind, **kw)[1]
        wire = json.loads(json.dumps(spec.to_json()))
        assert ScenarioSpec.from_json(wire) == spec
        assert isinstance(ScenarioSpec.from_json(wire).arrival, ArrivalSpec)


def test_base_period_follows_section_6_1_formula():
    ctx = default_context()
    spec = generate_scenario_specs(4, seed=2)[3]
    scenario = build_scenario(spec.name, [list(g) for g in spec.groups],
                              ctx.graphs)
    bt = best_model_times(scenario.graphs, ctx.processors, ctx.profiler)
    periods = base_periods(scenario, bt)
    n = len(spec.groups)
    for group, period in zip(scenario.groups, periods):
        expect = sum(min(t for t, _, _ in bt[m].values()) for m in group)
        assert period == pytest.approx(expect * n * 1.1)
        assert period > 0


def test_base_period_scales_with_group_count():
    ctx = default_context()
    one = build_scenario("one", [["face_det", "yolov8n"]], ctx.graphs)
    two = build_scenario(
        "two", [["face_det", "yolov8n"], ["hand_det"]], ctx.graphs)
    bt1 = best_model_times(one.graphs, ctx.processors, ctx.profiler)
    bt2 = best_model_times(two.graphs, ctx.processors, ctx.profiler)
    # φ̄ ∝ N: the same group composition doubles its period in a 2-group scenario
    assert base_periods(two, bt2)[0] == pytest.approx(
        2 * base_periods(one, bt1)[0])


# -- aggregation math --------------------------------------------------------

def _canned(index, alpha, ratios, satisfaction):
    spec = ScenarioSpec(index=index, name=f"c{index}", seed=index,
                        groups=(("face_det",),))
    return ScenarioResult(
        spec=spec, base_periods_s=[0.01],
        alpha_star=dict(alpha), alpha_star_best=dict(alpha),
        ratios=dict(ratios), satisfaction=dict(satisfaction),
        ga_generations=1, ga_evaluations=10, pareto_size=1, wall_s=0.1,
    )


def test_geometric_mean():
    assert geometric_mean([2.0, 8.0]) == pytest.approx(4.0)
    assert geometric_mean([3.0]) == pytest.approx(3.0)
    assert geometric_mean([]) == 0.0
    assert math.isinf(geometric_mean([1.0, float("inf")]))


def test_aggregate_canned_results():
    results = [
        _canned(0, {"puzzle": 1.0, "best_mapping": 2.0, "npu_only": 2.0},
                {"npu_only": 2.0, "best_mapping": 2.0},
                {"puzzle": 1.0, "best_mapping": 0.5, "npu_only": 0.5}),
        _canned(1, {"puzzle": 0.5, "best_mapping": 1.0, "npu_only": 4.0},
                {"npu_only": 8.0, "best_mapping": 2.0},
                {"puzzle": 0.8, "best_mapping": 0.9, "npu_only": 0.1}),
    ]
    agg = aggregate_results(results)
    assert agg["num_scenarios"] == 2
    assert agg["speedup_geomean"]["vs_npu_only"] == pytest.approx(4.0)
    assert agg["speedup_geomean"]["vs_best_mapping"] == pytest.approx(2.0)
    assert agg["speedup_mean"]["vs_npu_only"] == pytest.approx(5.0)
    assert agg["satisfaction_rate"]["puzzle"] == pytest.approx(0.9)
    assert agg["satisfaction_rate"]["npu_only"] == pytest.approx(0.3)
    assert agg["alpha_star"]["puzzle"]["mean_capped"] == pytest.approx(0.75)
    assert agg["alpha_star"]["npu_only"]["median_capped"] == pytest.approx(3.0)


def test_aggregate_caps_unsaturated_alpha():
    results = [
        _canned(0, {"puzzle": 2.0, "best_mapping": float("inf"),
                    "npu_only": float("inf")},
                {"npu_only": 3.0, "best_mapping": 3.0},
                {m: 1.0 for m in METHODS}),
    ]
    agg = aggregate_results(results, alpha_cap=6.0)
    assert agg["alpha_star"]["npu_only"]["mean_capped"] == pytest.approx(6.0)
    assert agg["alpha_star"]["npu_only"]["saturated_fraction"] == 0.0
    assert agg["alpha_star"]["puzzle"]["saturated_fraction"] == 1.0
    # best-convention ratios are capped, never inf
    assert agg["speedup_geomean_best"]["vs_npu_only"] == pytest.approx(3.0)


def test_deadline_satisfaction_pools_requests():
    ms = [[0.5, 1.5], [1.0, 2.0, float("inf")]]
    dl = [1.0, 2.0]
    # hits: 0.5; 1.0, 2.0 → 3 of 5
    assert deadline_satisfaction(ms, dl) == pytest.approx(3 / 5)
    assert deadline_satisfaction([], []) == 0.0
    assert deadline_satisfaction([[]], [1.0]) == 0.0


def test_deadline_satisfaction_rejects_group_mismatch():
    with pytest.raises(ValueError, match="group count mismatch"):
        deadline_satisfaction([[0.5], [0.5]], [1.0])


def test_scenario_result_rejects_nan():
    with pytest.raises(ValueError, match="alpha_star\\[puzzle\\]"):
        _canned(0, {"puzzle": float("nan"), "best_mapping": 2.0,
                    "npu_only": 2.0},
                {"npu_only": 2.0, "best_mapping": 2.0},
                {m: 1.0 for m in METHODS})


# -- end-to-end: resume + worker determinism --------------------------------

def _strip_wall(doc):
    doc = copy.deepcopy(doc)
    for row in doc["scenarios"]:
        row.pop("wall_s")
    doc["aggregate"].pop("total_wall_s")
    return doc


def test_sweep_resume_and_worker_determinism(tmp_path):
    specs = generate_scenario_specs(2, seed=1)
    d1 = tmp_path / "w1"
    doc1 = run_sweep(specs, TINY, run_dir=str(d1), workers=1)
    assert len(doc1["scenarios"]) == 2
    for row in doc1["scenarios"]:
        assert set(row["alpha_star"]) == set(METHODS)

    # per-scenario files landed and round-trip through ScenarioResult
    files = sorted(d1.glob("scenario_*.json"))
    assert len(files) == 2
    reloaded = ScenarioResult.from_json(json.loads(files[0].read_text()))
    assert reloaded.to_json() == doc1["scenarios"][0]

    # resume: a second run reuses the stored results verbatim
    messages = []
    doc2 = run_sweep(specs, TINY, run_dir=str(d1), workers=1,
                     log=messages.append)
    assert doc2 == doc1
    assert any("resumed 2/2" in m for m in messages)

    # fan-out: a 2-worker pool in a fresh dir reproduces everything but wall time
    doc3 = run_sweep(specs, TINY, run_dir=str(tmp_path / "w2"), workers=2)
    assert _strip_wall(doc3) == _strip_wall(doc1)


def test_sweep_arrival_axis_worker_determinism(tmp_path):
    """The arrival axis preserves the sweep's determinism contract:
    ``--workers 2`` reproduces ``--workers 1`` bit for bit, and resuming a
    non-periodic run dir reuses the stored results."""
    specs = generate_scenario_specs(2, seed=4, arrival="poisson")
    doc1 = run_sweep(specs, TINY, run_dir=str(tmp_path / "w1"), workers=1)
    for row in doc1["scenarios"]:
        assert row["spec"]["arrival"]["kind"] == "poisson"
    doc2 = run_sweep(specs, TINY, run_dir=str(tmp_path / "w2"), workers=2)
    assert _strip_wall(doc2) == _strip_wall(doc1)
    # resume path: stored non-periodic scenarios reload (spec match incl.
    # the arrival block)
    messages = []
    doc3 = run_sweep(specs, TINY, run_dir=str(tmp_path / "w1"), workers=1,
                     log=messages.append)
    assert doc3 == doc1
    assert any("resumed 2/2" in m for m in messages)
    # and the traffic actually matters: the periodic sweep of the same
    # compositions yields different results
    doc4 = run_sweep(generate_scenario_specs(2, seed=4), TINY,
                     run_dir=str(tmp_path / "p"), workers=1)
    strip1, strip4 = _strip_wall(doc1), _strip_wall(doc4)
    for row in strip1["scenarios"] + strip4["scenarios"]:
        row.pop("spec")
    assert strip1 != strip4


@pytest.mark.parametrize("arrival", [None, "poisson"])
def test_evaluate_scenario_batch_path_identical(arrival):
    """use_batch routes α*-search + satisfaction through batchsim; the
    per-scenario result must be bit-identical (wall time aside) — under
    periodic and non-periodic arrivals alike (the batch lanes must carry
    the scenario's arrival spec)."""
    from repro.experiments.evaluate import evaluate_scenario

    spec = generate_scenario_specs(2, seed=2025, arrival=arrival)[1]
    kw = dict(pop_size=8, max_generations=4, min_generations=2,
              bm_max_evals=24)
    plain = evaluate_scenario(spec, SweepConfig(**kw)).to_json()
    batched = evaluate_scenario(
        spec, SweepConfig(use_batch=True, **kw)).to_json()
    plain.pop("wall_s")
    batched.pop("wall_s")
    # the configs differ by construction; everything else must not
    assert plain.pop("spec") == batched.pop("spec")
    assert plain == batched


def test_sweep_rejects_config_mismatch(tmp_path):
    specs = generate_scenario_specs(1, seed=1)
    run_sweep(specs, TINY, run_dir=str(tmp_path), workers=1)
    other = SweepConfig(pop_size=6, max_generations=4, min_generations=2,
                        bm_max_evals=20)
    with pytest.raises(RuntimeError, match="different sweep config"):
        run_sweep(specs, other, run_dir=str(tmp_path), workers=1)
    # --force wipes the stale per-scenario results and proceeds
    doc = run_sweep(specs, other, run_dir=str(tmp_path), workers=1, force=True)
    assert len(doc["scenarios"]) == 1
