"""Device-in-the-loop evaluation: conformance + measured-cost feedback.

Demonstrates the fourth engine tier end to end, in ~1 minute on CPU:

  1. **virtual-clock conformance** — the best GA schedule executes on the
     real ``PuzzleRuntime`` Coordinator/Worker code driven by a virtual
     clock, and its task trace must match the ``FastSimulator`` prediction
     *bit for bit* (zero max-abs diff on release/start/finish times);
  2. **measured-cost feedback** — the schedule then runs for real
     (``JaxExecBackend``-profiled executable models, genuine XLA execution),
     the per-subgraph timings are written back into the Merkle-keyed
     ``ProfileDB``, the analyzer's caches are invalidated, and the GA's
     Pareto front is re-ranked on the measured costs;
  3. **in-search feedback** — a second GA run with
     ``GAConfig.device_in_loop_interval`` performs the same measurement
     rounds *during* the search (the paper's §4.2 loop).

Writes the conformance trace diff to ``results/conformance_trace.json``
(golden-trace schema; uploaded as a CI artifact).

Usage: PYTHONPATH=src python examples/device_in_loop.py
"""
import json
import os

from repro.core import (
    AnalyzerConfig,
    GAConfig,
    JaxExecBackend,
    PAPER_COMM_MODEL,
    Profiler,
    StaticAnalyzer,
    mobile_processors,
)
from repro.core.scenarios import Scenario
from repro.zoo import executable_zoo


def build_analyzer(zoo, procs, ga: GAConfig) -> StaticAnalyzer:
    graphs = [zoo["face_det"].graph, zoo["selfie_seg"].graph]
    profiler = Profiler(JaxExecBackend(
        zoo, repeats=3,
        # heterogeneity emulation on a single-CPU host: the host measures
        # one device; relative per-processor speed factors split it into
        # CPU/GPU/NPU-like profiles
        speed_scale={p.pid: 1.0 + 0.6 * p.pid for p in procs},
    ))
    scenario = Scenario(name="device_in_loop", graphs=graphs, groups=[[0, 1]])
    return StaticAnalyzer(
        scenario, procs, profiler, PAPER_COMM_MODEL,
        AnalyzerConfig(ga=ga), executables=zoo,
    )


def main() -> None:
    zoo = executable_zoo(names=["face_det", "selfie_seg"], channels=4, spatial=8)
    procs = mobile_processors()
    analyzer = build_analyzer(
        zoo, procs, GAConfig(pop_size=8, max_generations=6,
                             min_generations=2, seed=0))
    print(f"base period: {analyzer.base_periods[0] * 1000:.2f} ms")

    result = analyzer.run_ga()
    best = min(result.pareto, key=lambda s: sum(s.fitness))
    print(f"GA: {result.generations} generations, "
          f"{len(result.pareto)} Pareto solutions")

    # 1 -- virtual-clock conformance: runtime trace == simulator trace
    report = analyzer.validate_on_runtime(
        best, alpha=1.0, num_requests=8, measured=True, seed=0)
    print(f"\nvirtual conformance: passed={report.passed} "
          f"tasks={report.runtime_tasks}/{report.sim_tasks} "
          f"max|Δrelease|={report.max_release_diff} "
          f"max|Δstart|={report.max_start_diff} "
          f"max|Δfinish|={report.max_finish_diff}")
    assert report.passed, "virtual-clock runtime diverged from the simulator"
    os.makedirs("results", exist_ok=True)
    with open("results/conformance_trace.json", "w") as f:
        json.dump(report.to_json(), f, indent=1)
    print("wrote results/conformance_trace.json")

    # 2 -- measured-cost feedback: real execution -> ProfileDB -> re-rank.
    # Candidate set = GA front + the Best Mapping archive, so the re-ranking
    # has real competition to reorder.
    candidates = list(result.pareto) + analyzer.best_mapping(max_evals=40)
    objs_before = [analyzer.objectives(s, num_requests=12, measured=True)
                   for s in candidates]
    order_before = sorted(range(len(candidates)),
                          key=lambda i: sum(objs_before[i]))
    db = analyzer.profiler.db
    before_updates = db.measured_updates
    measurements = analyzer.measure_on_runtime(best, num_requests=4, alpha=2.0)
    changed = analyzer.apply_measured_costs(measurements)
    print(f"\nmeasured {len(measurements)} subgraph timings on the real "
          f"runtime; {changed} ProfileDB entries updated "
          f"(db.measured_updates {before_updates} -> {db.measured_updates})")
    assert changed > 0, "device-in-the-loop run updated no ProfileDB entry"

    front = analyzer.rerank_pareto(candidates, num_requests=12)
    objs_after = [s.fitness for s in candidates]
    order_after = sorted(range(len(candidates)),
                         key=lambda i: sum(objs_after[i]))
    moved = sum(1 for a, b in zip(objs_before, objs_after) if a != b)
    print(f"re-ranked {len(candidates)} candidates on measured costs: "
          f"{moved} objective vectors changed, new first front has "
          f"{len(front)} members, ordering changed: "
          f"{order_before != order_after}")
    assert moved > 0, "measured costs changed no objective"

    # 3 -- the same loop inside the search (paper §4.2)
    analyzer2 = build_analyzer(
        zoo, procs, GAConfig(pop_size=6, max_generations=4, min_generations=2,
                             patience=4, seed=1, device_in_loop_interval=2))
    result2 = analyzer2.run_ga()
    rounds = ", ".join(f"gen {g}: {n} entries" for g, n in
                       result2.device_updates)
    print(f"\nGA with device_in_loop_interval=2: measurement rounds "
          f"updated the ProfileDB at [{rounds}]")
    assert result2.device_updates, "no in-search device measurement round ran"

    # real-exec conformance is informational on a shared/noisy host: the
    # simulator predicts from (now measured) costs, the runtime re-executes
    rep_real = analyzer.validate_on_runtime(
        best, alpha=2.0, num_requests=4, mode="real", rel_tol=2.0)
    print(f"\nreal-exec conformance: makespan rel err "
          f"{rep_real.max_makespan_rel_err:.2f} "
          f"(tasks {rep_real.runtime_tasks}/{rep_real.sim_tasks})")


if __name__ == "__main__":
    main()
