"""Quickstart: schedule two DL models across heterogeneous processors.

Runs the full Puzzle pipeline in ~30 s on CPU:
  1. build model graphs (paper zoo) + the paper-calibrated profiler,
  2. run the GA Static Analyzer,
  3. compare the Pareto solution against the NPU-Only / Best-Mapping
     baselines via the XRBench saturation multiplier.

Usage: PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    AnalyzerConfig,
    GAConfig,
    PAPER_COMM_MODEL,
    Profiler,
    StaticAnalyzer,
    TableBackend,
    build_scenario,
    decode_solution,
    mobile_processors,
)
from repro.core.profiler import AnalyticMobileBackend
from repro.zoo import all_cost_graphs, paper_profile_tables


def main() -> None:
    graphs = all_cost_graphs()
    procs = mobile_processors()
    profiler = Profiler(TableBackend(
        processors=procs, tables=paper_profile_tables(),
        fallback=AnalyticMobileBackend(procs),
    ))
    scenario = build_scenario(
        "quickstart",
        [["face_det", "selfie_seg", "yolov8n", "fast_scnn", "pose_det",
          "hand_det"]],
        graphs,
    )
    analyzer = StaticAnalyzer(
        scenario, procs, profiler, PAPER_COMM_MODEL,
        AnalyzerConfig(ga=GAConfig(pop_size=20, max_generations=24, seed=0)),
    )
    print(f"base period: {analyzer.base_periods[0] * 1000:.2f} ms")

    result = analyzer.run_ga()
    print(f"GA: {result.generations} generations, {result.evaluations} "
          f"evaluations, {len(result.pareto)} Pareto solutions")

    best = min(result.pareto, key=lambda s: s.fitness[0])
    placed = decode_solution(best, scenario.graphs)
    for net, plist in enumerate(placed):
        desc = ", ".join(
            f"sg{p.subgraph.sg_index}->{procs[p.processor].name}"
            f"/{p.dtype}/{p.backend}" for p in plist
        )
        print(f"  {scenario.graphs[net].name:12s}: {desc}")

    pz = analyzer.median_saturation(result.pareto)
    npu = analyzer.saturation(analyzer.npu_only()).alpha_star
    bm = analyzer.median_saturation(analyzer.best_mapping(max_evals=100))
    print(f"\nsaturation multiplier α* (lower = sustains higher load):")
    print(f"  Puzzle       : {pz}")
    print(f"  Best Mapping : {bm}")
    print(f"  NPU Only     : {npu}")
    print(f"  -> Puzzle sustains {npu / pz:.2f}x the request frequency of "
          f"NPU Only (paper: 3.7x multi-group avg / 2.0x single)")


if __name__ == "__main__":
    main()
