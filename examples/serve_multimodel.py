"""End-to-end driver: schedule THEN serve multiple real models.

Demonstrates the full Puzzle flow with actual execution (not simulation):
  1. Static Analyzer finds a schedule for two model groups
     (camera group: face+selfie+hand; heavy group: pose+yolo),
  2. the PuzzleRuntime loads the solution (Coordinator/Workers/Engines,
     tensor pool + zero-copy shared buffer),
  3. periodic requests are served and XRBench scores computed from the
     REAL measured makespans.

Usage: PYTHONPATH=src python examples/serve_multimodel.py
"""
import statistics

from repro.core import (
    AnalyzerConfig,
    GAConfig,
    PAPER_COMM_MODEL,
    JaxExecBackend,
    Profiler,
    StaticAnalyzer,
    build_scenario,
    mobile_processors,
)
from repro.core.scoring import group_scores
from repro.runtime import PuzzleRuntime, RuntimeConfig
from repro.zoo import executable_zoo

MODELS = ["face_det", "selfie_seg", "hand_det", "pose_det", "yolov8n"]
GROUPS = [["face_det", "selfie_seg", "hand_det"], ["pose_det", "yolov8n"]]


def main() -> None:
    # reduced-but-real models; the profiler literally executes subgraphs
    zoo = executable_zoo(names=MODELS, channels=4, spatial=8)
    graphs = {name: zoo[name].graph for name in MODELS}
    procs = mobile_processors()
    profiler = Profiler(JaxExecBackend(zoo, repeats=2))
    scenario = build_scenario("serve", GROUPS, graphs)
    analyzer = StaticAnalyzer(
        scenario, procs, profiler, PAPER_COMM_MODEL,
        AnalyzerConfig(ga=GAConfig(pop_size=12, max_generations=10,
                                   min_generations=6, seed=1)),
    )
    print("device-in-the-loop profiling + GA search (real executions)...")
    result = analyzer.run_ga()
    best = min(result.pareto, key=lambda s: sum(s.fitness))
    print(f"GA done: {result.evaluations} evaluations, "
          f"{len(result.pareto)} Pareto solutions; profile DB has "
          f"{len(profiler.db)} measured subgraphs")

    rt = PuzzleRuntime(list(scenario.graphs), best, procs, zoo,
                       RuntimeConfig(tensor_pool=True, shared_buffer=True))
    try:
        periods = [0.05, 0.08]
        states = rt.run_periodic(
            [list(g) for g in scenario.groups], periods, num_requests=8)
        for gid, glist in enumerate(states):
            ms = [s.makespan for s in glist]
            rt_score, qoe = group_scores(ms, periods[gid])
            print(f"group {gid}: mean makespan "
                  f"{statistics.mean(ms) * 1000:.2f} ms  "
                  f"p90 {sorted(ms)[int(0.9 * (len(ms) - 1))] * 1000:.2f} ms  "
                  f"RtScore {rt_score:.3f}  QoE {qoe:.3f}")
        print("runtime stats:", rt.stats())
    finally:
        rt.close()


if __name__ == "__main__":
    main()
