"""Three-scenario sweep: the paper's headline comparison, in miniature.

Runs the full scenario-sweep harness end-to-end on CPU in well under 30 s:
generate three randomized scenarios (paper §6.1 recipe: 1-3 model groups,
1-4 models each from the nine-network zoo), run Puzzle's GA plus the NPU
Only and Best Mapping baselines on each, bisection-search every method's
saturation multiplier α*, and aggregate the frequency-gain ratios the paper
reports as 3.7×/2.2× (§6, Fig. 11).

The run directory is resumable: re-running this script reloads finished
scenarios instead of recomputing them. Same seed → same scenarios, same
numbers, on any worker count.

Usage: PYTHONPATH=src python examples/sweep_small.py
"""
import os
import tempfile

from repro.experiments import (
    METHODS,
    SweepConfig,
    format_summary,
    generate_scenario_specs,
    run_sweep,
)


def main() -> None:
    specs = generate_scenario_specs(count=3, seed=7)
    for spec in specs:
        print(f"{spec.name}: " + " | ".join(
            ", ".join(g) for g in spec.groups))

    # a reduced GA budget keeps this demo fast; the real protocol uses the
    # SweepConfig defaults (pop 20 x <=30 generations, 120 BM evals)
    config = SweepConfig(pop_size=12, max_generations=12, min_generations=4,
                         bm_max_evals=60)
    run_dir = os.path.join(tempfile.gettempdir(), "puzzle_sweep_small")
    # force=True: a stale run dir from an older version of this demo (with a
    # different config) is wiped instead of raising a config-mismatch error
    doc = run_sweep(specs, config, run_dir=run_dir, workers=1, force=True,
                    log=lambda m: print(m, flush=True))

    print()
    print(f"{'scenario':16s} " + " ".join(f"{m:>13s}" for m in METHODS))
    for row in doc["scenarios"]:
        stars = [
            "never" if row["alpha_star"][m] is None
            else f"{row['alpha_star'][m]:.2f}"
            for m in METHODS
        ]
        print(f"{row['spec']['name']:16s} "
              + " ".join(f"a*={s:>9s}" for s in stars))
    print()
    print(format_summary(doc))
    print(f"\nrun dir (resumable): {run_dir}")


if __name__ == "__main__":
    main()
