"""End-to-end training driver: ~100M-parameter model, few hundred steps.

Trains a 12-layer/512-dim GQA decoder (≈100M params with the 32k vocab)
on the synthetic Markov stream; loss should fall from ~ln(V) toward the
chain entropy. Checkpoints under /tmp and resumes if re-run.

Usage: PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse

from repro.models.config import ATTN, ModelConfig
from repro.train import TrainConfig, train


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="demo-100m",
        arch_type="dense",
        num_layers=12,
        d_model=512,
        num_heads=8,
        num_kv_heads=2,
        d_ff=2048,
        vocab_size=32_768,
        layout_pattern=(ATTN,),
        dtype="float32",
        source="examples/train_100m.py",
    ).validate()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    cfg = model_100m()
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n / 1e6:.1f}M")
    res = train(cfg, TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        lr=1e-3, log_every=25,
        checkpoint_path="/tmp/repro_train_100m.msgpack", checkpoint_every=100,
    ))
    print(f"first loss {res.losses[0]:.3f} -> last {res.losses[-1]:.3f} "
          f"(floor = chain entropy {res.loss_floor:.3f})")
    print(f"throughput: {res.tokens_per_s:,.0f} tokens/s on CPU")


if __name__ == "__main__":
    main()
