"""Synthetic-but-learnable data pipeline.

Token streams are drawn from a fixed random first-order Markov chain over
the vocabulary (seeded), so a language model has real structure to learn:
loss starts near ln(V) and should approach the chain's conditional
entropy. Deterministic, shardable, infinite.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 4        # out-degree of the Markov chain


class MarkovDataset:
    """Infinite batches of (tokens, labels) from a sparse Markov chain."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, k = cfg.vocab_size, cfg.branching
        self._succ = rng.integers(0, v, size=(v, k), dtype=np.int32)
        probs = rng.dirichlet(np.ones(k) * 0.5, size=v).astype(np.float32)
        self._cum = np.cumsum(probs, axis=1)
        self._probs = probs

    def entropy(self) -> float:
        """Conditional entropy of the chain (loss floor, nats)."""
        p = self._probs
        h = -(p * np.log(np.maximum(p, 1e-12))).sum(axis=1)
        return float(h.mean())

    def _walk(self, rng: np.random.Generator, length: int) -> np.ndarray:
        v = self.cfg.vocab_size
        out = np.empty(length + 1, dtype=np.int32)
        s = int(rng.integers(0, v))
        for i in range(length + 1):
            out[i] = s
            r = rng.random()
            j = int(np.searchsorted(self._cum[s], r))
            s = int(self._succ[s, min(j, self._succ.shape[1] - 1)])
        return out

    def batches(self, start_step: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = start_step
        while True:
            toks = np.stack([
                self._walk(np.random.default_rng((self.cfg.seed, step, b)),
                           self.cfg.seq_len)
                for b in range(self.cfg.batch_size)
            ])
            yield toks[:, :-1], toks[:, 1:]
            step += 1
