"""Checkpointing: msgpack-serialized pytrees with atomic writes.

Stores (params, opt_state, step, metadata). Arrays are serialized as
(dtype, shape, raw bytes); bfloat16 round-trips through uint16 views.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_array(a) -> Dict[str, Any]:
    arr = np.asarray(a)
    if arr.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(arr.shape),
                "data": arr.view(np.uint16).tobytes()}
    return {"dtype": arr.dtype.str, "shape": list(arr.shape),
            "data": arr.tobytes()}


def _decode_array(d: Dict[str, Any]) -> np.ndarray:
    shape = tuple(d["shape"])
    if d["dtype"] == "bfloat16":
        raw = np.frombuffer(d["data"], dtype=np.uint16).reshape(shape)
        return raw.view(jnp.bfloat16)
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(shape)


def save_checkpoint(path: str, params: Any, opt_state: Any = None,
                    step: int = 0, meta: Optional[Dict] = None) -> None:
    flat_p, tdef_p = jax.tree.flatten(params)
    payload = {
        "step": int(step),
        "meta": meta or {},
        "treedef_params": str(tdef_p),
        "params": [_encode_array(a) for a in flat_p],
    }
    if opt_state is not None:
        flat_o, tdef_o = jax.tree.flatten(opt_state)
        payload["treedef_opt"] = str(tdef_o)
        payload["opt"] = [_encode_array(a) for a in flat_o]
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)   # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore_checkpoint(path: str, params_like: Any,
                       opt_state_like: Any = None
                       ) -> Tuple[Any, Any, int, Dict]:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat_p, tdef_p = jax.tree.flatten(params_like)
    arrays = [_decode_array(d) for d in payload["params"]]
    if len(arrays) != len(flat_p):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, structure expects {len(flat_p)}"
        )
    params = tdef_p.unflatten(
        [jnp.asarray(a, dtype=p.dtype) for a, p in zip(arrays, flat_p)]
    )
    opt_state = None
    if opt_state_like is not None and "opt" in payload:
        flat_o, tdef_o = jax.tree.flatten(opt_state_like)
        arrays_o = [_decode_array(d) for d in payload["opt"]]
        opt_state = tdef_o.unflatten(
            [jnp.asarray(a, dtype=o.dtype) for a, o in zip(arrays_o, flat_o)]
        )
    return params, opt_state, payload["step"], payload.get("meta", {})
