"""Training loop: config-driven trainer usable on the host CPU (reduced
configs) and, unchanged, on a production mesh (full configs).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import forward_train, init_params
from .checkpoint import restore_checkpoint, save_checkpoint
from .data import DataConfig, MarkovDataset
from .optimizer import make_optimizer


@dataclass
class TrainConfig:
    steps: int = 200
    batch_size: int = 8
    seq_len: int = 64
    lr: float = 3e-3
    optimizer: str = "adamw"
    log_every: int = 20
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 100
    seed: int = 0


@dataclass
class TrainResult:
    losses: List[float]
    steps: int
    tokens_per_s: float
    loss_floor: float             # data-generating entropy


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(tgt)


def train(model_cfg: ModelConfig, cfg: TrainConfig,
          cross_src_fn: Optional[Callable[[int], jnp.ndarray]] = None
          ) -> TrainResult:
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params(model_cfg, key)
    opt_init, opt_update = make_optimizer(cfg.optimizer, lr=cfg.lr)
    opt_state = opt_init(params)
    start_step = 0
    if cfg.checkpoint_path:
        import os
        if os.path.exists(cfg.checkpoint_path):
            params, opt_state, start_step, _ = restore_checkpoint(
                cfg.checkpoint_path, params, opt_state
            )
    data = MarkovDataset(DataConfig(
        vocab_size=model_cfg.vocab_size, seq_len=cfg.seq_len,
        batch_size=cfg.batch_size, seed=cfg.seed,
    ))

    cross_src = cross_src_fn(cfg.batch_size) if cross_src_fn else None

    @jax.jit
    def step_fn(params, opt_state, tokens, labels, cross):
        def loss_fn(p):
            logits = forward_train(p, model_cfg, tokens, cross, remat=False)
            return cross_entropy_loss(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt = opt_update(grads, opt_state, params)
        return new_params, new_opt, loss

    losses: List[float] = []
    t0 = time.time()
    batches = data.batches(start_step)
    for step in range(start_step, cfg.steps):
        tokens, labels = next(batches)
        params, opt_state, loss = step_fn(
            params, opt_state, jnp.asarray(tokens), jnp.asarray(labels), cross_src
        )
        losses.append(float(loss))
        if cfg.log_every and (step + 1) % cfg.log_every == 0:
            print(f"step {step+1:5d}  loss {losses[-1]:.4f}")
        if cfg.checkpoint_path and (step + 1) % cfg.checkpoint_every == 0:
            save_checkpoint(cfg.checkpoint_path, params, opt_state, step + 1)
    dt = max(time.time() - t0, 1e-9)
    tokens_total = (cfg.steps - start_step) * cfg.batch_size * cfg.seq_len
    return TrainResult(
        losses=losses, steps=cfg.steps,
        tokens_per_s=tokens_total / dt,
        loss_floor=data.entropy(),
    )
