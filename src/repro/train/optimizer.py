"""Optimizers: AdamW and Adafactor, implemented as pure pytree transforms.

AdamW is the default for ≤ 32B-parameter configs. The 1T-parameter
kimi-k2 (and 398B jamba) training state would not fit 16 GB/chip HBM with
two fp32 Adam moments; they use Adafactor with factored second moments and
bf16 first moment (DESIGN.md §6) — the standard memory/quality trade
production frameworks make at that scale.

Both expose ``init(params) -> state`` and
``update(grads, state, params) -> (new_params, new_state)`` and are
pjit-transparent (states inherit the parameter shardings).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8           # t^-decay second-moment schedule
    eps: float = 1e-30
    clip_threshold: float = 1.0
    momentum: Optional[float] = 0.9   # bf16 first moment; None disables
    weight_decay: float = 0.0


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(cfg: AdamWConfig = AdamWConfig()):
    def init(params: Params) -> OptState:
        def zeros(p):
            return jnp.zeros_like(p, dtype=jnp.float32)

        return OptState(
            step=jnp.zeros((), jnp.int32),
            inner={
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
            },
        )

    def update(grads: Params, state: OptState, params: Params
               ) -> Tuple[Params, OptState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = cfg.b1 * m + (1 - cfg.b1) * g32
            v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
            mh = m_new / bc1
            vh = v_new / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
                jnp.float32
            )
            return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m_new, v_new

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.inner["m"])
        flat_v = tdef.flatten_up_to(state.inner["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, inner={"m": new_m, "v": new_v})

    return init, update


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------

def _factored_dims(shape: Tuple[int, ...]) -> Optional[Tuple[int, int]]:
    """Last two non-trivial dims to factor over, or None for <2D."""
    dims = [i for i, d in enumerate(shape) if d > 1]
    if len(dims) < 2:
        return None
    return dims[-2], dims[-1]


def adafactor(cfg: AdafactorConfig = AdafactorConfig()):
    def init_leaf(p):
        f = _factored_dims(p.shape)
        leaf: Dict[str, Any] = {}
        if f is None:
            leaf["v"] = jnp.zeros_like(p, dtype=jnp.float32)
        else:
            r, c = f
            vr_shape = tuple(d for i, d in enumerate(p.shape) if i != c)
            vc_shape = tuple(d for i, d in enumerate(p.shape) if i != r)
            leaf["vr"] = jnp.zeros(vr_shape, jnp.float32)
            leaf["vc"] = jnp.zeros(vc_shape, jnp.float32)
        if cfg.momentum is not None:
            leaf["m"] = jnp.zeros_like(p, dtype=jnp.bfloat16)
        return leaf

    def init(params: Params) -> OptState:
        return OptState(
            step=jnp.zeros((), jnp.int32),
            inner=jax.tree.map(init_leaf, params),
        )

    def update(grads: Params, state: OptState, params: Params
               ) -> Tuple[Params, OptState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** -cfg.decay

        def upd(p, g, st):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + cfg.eps
            new_st = dict(st)
            f = _factored_dims(p.shape)
            if f is None:
                v = beta2 * st["v"] + (1 - beta2) * g2
                new_st["v"] = v
                precond = jax.lax.rsqrt(v + cfg.eps)
            else:
                r, c = f
                vr = beta2 * st["vr"] + (1 - beta2) * g2.mean(axis=c)
                vc = beta2 * st["vc"] + (1 - beta2) * g2.mean(axis=r)
                new_st["vr"], new_st["vc"] = vr, vc
                # v ≈ (vr / mean(vr)) ⊗ vc  (rank-1 reconstruction)
                vr_norm = vr / jnp.maximum(vr.mean(), cfg.eps)
                v = jnp.expand_dims(vr_norm, c) * jnp.expand_dims(vc, r)
                precond = jax.lax.rsqrt(v + cfg.eps)
            u = g32 * precond
            # update clipping by RMS
            rms_u = jnp.sqrt(jnp.mean(u * u) + cfg.eps)
            u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
            if cfg.momentum is not None:
                m = cfg.momentum * st["m"].astype(jnp.float32) + (1 - cfg.momentum) * u
                new_st["m"] = m.astype(jnp.bfloat16)
                u = m
            delta = cfg.lr * u + cfg.lr * cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - delta).astype(p.dtype), new_st

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state.inner)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, OptState(step=step, inner=new_s)

    return init, update


def make_optimizer(name: str, lr: Optional[float] = None):
    """'adamw' | 'adafactor' factory used by configs and the launcher."""
    if name == "adamw":
        cfg = AdamWConfig(lr=lr) if lr else AdamWConfig()
        return adamw(cfg)
    if name == "adafactor":
        cfg = AdafactorConfig(lr=lr) if lr else AdafactorConfig()
        return adafactor(cfg)
    raise ValueError(f"unknown optimizer {name}")


def optimizer_for_config(model_cfg) -> str:
    """1T/400B-class models need factored state to fit HBM (DESIGN.md §6)."""
    return "adafactor" if model_cfg.param_count() > 100e9 else "adamw"
