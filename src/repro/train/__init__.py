"""Training substrate: optimizers, data, checkpointing, loop."""
from .checkpoint import restore_checkpoint, save_checkpoint
from .data import DataConfig, MarkovDataset
from .loop import TrainConfig, TrainResult, cross_entropy_loss, train
from .optimizer import (
    AdafactorConfig,
    AdamWConfig,
    adafactor,
    adamw,
    make_optimizer,
    optimizer_for_config,
)

__all__ = [k for k in dir() if not k.startswith("_")]
