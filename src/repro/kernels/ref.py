"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,                  # (BH, Sq, hd)
    k: jnp.ndarray,                  # (BKv, Sk, hd)
    v: jnp.ndarray,
    *,
    q_heads_per_kv: int = 1,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    kk = jnp.repeat(k, q_heads_per_kv, axis=0)
    vv = jnp.repeat(v, q_heads_per_kv, axis=0)
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * hd ** -0.5
    qpos = q_offset + jnp.arange(q.shape[1])[:, None]
    kpos = jnp.arange(kk.shape[1])[None, :]
    mask = jnp.ones_like(s[0], dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(
    x: jnp.ndarray,                  # (BH, S, P)
    dt: jnp.ndarray,                 # (BH, S)
    A: jnp.ndarray,                  # (BH,)
    Bm: jnp.ndarray,                 # (BH, S, N)
    Cm: jnp.ndarray,                 # (BH, S, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token linear recurrence — the SSD ground truth.

    state (BH, N, P); y_t = C_t · h_t, h_t = exp(dt_t A) h_{t-1} + dt_t B_t xᵀ_t.
    """
    bh, s, p = x.shape
    n = Bm.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp        # (BH,P) (BH,) (BH,N) (BH,N)
        decay = jnp.exp(dtt * A)[:, None, None]
        outer = jnp.einsum("bn,bp,b->bnp", bt, xt, dtt)
        new = decay * state + outer
        y = jnp.einsum("bn,bnp->bp", ct, new)
        return new, y

    init = jnp.zeros((bh, n, p), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32), dt.swapaxes(0, 1),
          Bm.swapaxes(0, 1).astype(jnp.float32),
          Cm.swapaxes(0, 1).astype(jnp.float32))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), final


def quantize_ref(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)
