"""Flash attention Pallas TPU kernel.

Grid: (batch×heads, Q blocks, KV blocks) with the KV dimension declared
``arbitrary`` (sequential) — the kernel revisits the same output block
across KV steps, carrying the online-softmax state (m, l, acc) in VMEM
scratch. BlockSpecs tile Q/K/V into (block_q, head_dim) / (block_k,
head_dim) VMEM tiles; head_dim and the block sizes are kept at multiples
of 128 so the MXU sees aligned matmuls.

Supports causal masking, GQA (KV-head index map = q_head // group_size)
and sliding-window masking (the `long_500k` dense path).

Oracle: ``repro.kernels.ref.attention_ref``; wrapper: ``repro.kernels.ops``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM tiles
    o_ref,                          # output tile
    m_scr, l_scr, acc_scr,          # scratch: (block_q,), (block_q,), (block_q, hd)
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                          # (bq, bk)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_k
    mask &= (q_pos - q_offset) < seq_q
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + p.sum(axis=-1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc / jnp.maximum(l_new, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,                     # (BH, Sq, hd)
    k: jnp.ndarray,                     # (BKv, Sk, hd)
    v: jnp.ndarray,
    *,
    q_heads_per_kv: int = 1,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused attention over flattened (batch×heads) leading dims.

    ``q_heads_per_kv``: GQA group size — row i of q maps to KV row
    ``i // q_heads_per_kv``.
    """
    bh, sq, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    nq = -(-sq // block_q)
    nk = -(-sk // block_k)
    pad_q = nq * block_q - sq
    pad_k = nk * block_k - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    grid = (bh, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=sq, seq_k=sk, causal=causal, window=window, q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, g=q_heads_per_kv: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, g=q_heads_per_kv: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
