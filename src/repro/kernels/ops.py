"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` mode (the
kernel body executes in Python for correctness validation); on a real TPU
the same calls lower to Mosaic. ``use_interpret()`` auto-detects.

These wrappers adapt model-layer layouts, e.g. (B, S, H, hd) GQA attention
→ the kernels' flattened (B·H, S, hd) layout, and broadcast SSD groups to
heads.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .int8_quant import dequantize_int8, quantize_int8
from .ssd_scan import ssd_scan


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset"))
def flash_attention_bshd(
    q: jnp.ndarray,                  # (B, Sq, H, hd)
    k: jnp.ndarray,                  # (B, Sk, Kv, hd)
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Model-layer entry point: GQA flash attention on (B, S, H, hd)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, v.shape[1], hd)
    out = flash_attention(
        qf, kf, vf, q_heads_per_kv=g, causal=causal, window=window,
        q_offset=q_offset, interpret=use_interpret(),
    )
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_bshp(
    x: jnp.ndarray,                  # (B, S, H, P)
    dt: jnp.ndarray,                 # (B, S, H)
    A: jnp.ndarray,                  # (H,)
    Bm: jnp.ndarray,                 # (B, S, G, N)
    Cm: jnp.ndarray,                 # (B, S, G, N)
    chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Model-layer entry point: Mamba2 SSD on (B, S, H, P) + groups."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    reps = h // g
    Bh = jnp.repeat(Bm, reps, axis=2)
    Ch = jnp.repeat(Cm, reps, axis=2)
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    Bf = Bh.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    Cf = Ch.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    Af = jnp.tile(A, b)
    y, state = ssd_scan(xf, dtf, Af, Bf, Cf, chunk=chunk,
                        interpret=use_interpret())
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    state = state.reshape(b, h, n, p).transpose(0, 1, 3, 2)   # (B, H, P, N)
    return y, state


@jax.jit
def quantize_rows(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return quantize_int8(x, interpret=use_interpret())


def dequantize_rows(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return dequantize_int8(q, scale)
