"""Mamba2 SSD chunk-scan Pallas TPU kernel.

Grid: (batch×heads, chunks); the chunk axis is ``arbitrary`` (sequential)
and carries the (N, P) recurrent state in VMEM scratch — the TPU-native
mapping of the SSD inter-chunk recurrence. Per grid cell the kernel does
three small MXU matmuls (C·Bᵀ, (L∘scores)·X, Bᵀ·X) over a (Q, ·) chunk
tile, with Q chosen 128 to align the systolic array.

Inputs are per-head (groups pre-broadcast by the wrapper):
  x (BH, S, P), dt (BH, S), B/C (BH, S, N), A (BH,)
Outputs: y (BH, S, P) and the final state (BH, N, P).

Oracle: ``repro.kernels.ref.ssd_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _ssd_kernel(
    x_ref, dt_ref, b_ref, c_ref, a_ref,
    y_ref, state_out_ref,
    state_scr,                       # (N, P) f32 scratch
    *,
    chunk: int,
):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0].astype(jnp.float32)         # (Q, N)
    a = a_ref[0].astype(jnp.float32)          # scalar (negative)

    dA = dt * a                               # (Q,)
    cum = jnp.cumsum(dA)                      # (Q,)
    total = cum[-1]

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )                                          # (Q, Q)
    w = scores * L * dt[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )                                          # (Q, P)

    # carried state: y += exp(cum) * (C @ state)
    state = state_scr[...]                     # (N, P)
    y_inter = jax.lax.dot_general(
        cm, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    y = y + y_inter * jnp.exp(cum)[:, None]

    # state update: state' = exp(total)*state + B^T @ (decay_to_end*dt*x)
    decay = jnp.exp(total - cum) * dt          # (Q,)
    xw = x * decay[:, None]
    chunk_state = jax.lax.dot_general(
        bm, xw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )                                          # (N, P)
    new_state = chunk_state + jnp.exp(total) * state
    state_scr[...] = new_state

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_out_ref[0] = new_state.astype(state_out_ref.dtype)


def ssd_scan(
    x: jnp.ndarray,                  # (BH, S, P)
    dt: jnp.ndarray,                 # (BH, S) — post-softplus
    A: jnp.ndarray,                  # (BH,) negative decay per head
    Bm: jnp.ndarray,                 # (BH, S, N)
    Cm: jnp.ndarray,                 # (BH, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (BH, S, P), final_state (BH, N, P))."""
    bh, s, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, p), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, dt, Bm, Cm, A)
    return y, state
