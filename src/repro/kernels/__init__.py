"""Pallas TPU kernels for the compute hot-spots + pure-jnp oracles.

The paper's contribution is a scheduling system, not a kernel — but its
configuration space includes per-subgraph *backend implementation* and
*data type* choices (Table 1's BE/T axes). These kernels are the TPU
backends that space selects between: fused flash attention and the SSD
chunk scan as the `pallas` backend vs plain XLA, and int8 row
quantization as the Worker's dtype-boundary fast path.
"""
from .flash_attention import flash_attention
from .int8_quant import dequantize_int8, quantize_int8
from .ops import dequantize_rows, flash_attention_bshd, quantize_rows, ssd_bshp
from .ref import attention_ref, quantize_ref, ssd_ref
from .ssd_scan import ssd_scan

__all__ = [k for k in dir() if not k.startswith("_")]
