"""JAX version-compat shims for Pallas-TPU.

Pallas-TPU renamed ``TPUCompilerParams`` to ``CompilerParams`` across JAX
releases; resolve whichever this installation provides so the kernels work
on either side of the rename.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
