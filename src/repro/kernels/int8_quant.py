"""Row-wise int8 quantization Pallas kernel.

The Puzzle Worker (de)quantizes tensors at subgraph dtype boundaries
(paper §5.1); this kernel fuses absmax + scale + round into one VMEM pass
per (block_rows, cols) tile. Symmetric per-row scaling:
``q = round(x / scale)``, ``scale = absmax / 127``.

Oracle: ``repro.kernels.ref.quantize_ref``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                  # (rows, cols)
    absmax = jnp.max(jnp.abs(x), axis=1)                # (rows,)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale.astype(jnp.float32)


def quantize_int8(
    x: jnp.ndarray,                  # (R, C)
    *,
    block_rows: int = 256,
    interpret: bool = False,
):
    """Returns (q int8 (R, C), scale f32 (R,))."""
    r, c = x.shape
    block_rows = min(block_rows, r)
    nr = -(-r // block_rows)
    pad = nr * block_rows - r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)), constant_values=1.0)
    q, scale = pl.pallas_call(
        _quant_kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr * block_rows, c), jnp.int8),
            jax.ShapeDtypeStruct((nr * block_rows,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(x)
    return q[:r], scale[:r]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale[:, None]
