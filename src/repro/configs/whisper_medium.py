"""whisper-medium [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865. 24 encoder layers
(bidirectional self-attention over stub frame embeddings, 1500 frames =
30 s at 50 Hz) + 24 decoder layers (causal self-attention + cross-attention
to the encoder output). The mel-spectrogram + conv feature extractor is the
allowed STUB — ``input_specs`` supplies frame embeddings directly.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    layout_pattern=(ATTN,),
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq_len=1500,
    source="arXiv:2212.04356",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        arch_type="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        layout_pattern=(ATTN,),
        is_encoder_decoder=True,
        encoder_layers=2,
        encoder_seq_len=32,
        dtype="float32",
        source="arXiv:2212.04356",
    ).validate()
