"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256. Every 5th layer is
a gated cross-attention layer consuming image patch embeddings (8 cross
layers total). The vision encoder is a STUB: ``input_specs`` provides
precomputed patch embeddings of shape (B, 6404, d_model) — the allowed
modality-frontend carve-out.

`long_500k` uses the sliding-window attention variant (window 8192) to
meet the sub-quadratic requirement; the launcher enables it for decode
at 500k only.
"""
from repro.models.config import ATTN, CROSS, ModelConfig

NUM_IMAGE_TOKENS = 6404  # 4 tiles x 1601 patches

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    layout_pattern=(ATTN, ATTN, ATTN, ATTN, CROSS),
    rope_theta=500_000.0,
    num_image_tokens=NUM_IMAGE_TOKENS,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke",
        arch_type="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        layout_pattern=(ATTN, CROSS),
        num_image_tokens=16,
        dtype="float32",
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    ).validate()
