"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 (dense FFN; MoE experts reuse
the same hidden size) vocab=65536, MoE 16e top-2 on every other layer.
Pattern period 8 = one attention layer + seven Mamba layers, with MoE FFN
on alternating positions (lcm of the 1:7 attention cycle and the 1:1 MoE
cycle).
"""
from repro.models.config import ATTN_MOE, SSM_MLP, SSM_MOE, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    # position 0: attention + MoE; then mamba layers alternating dense/MoE FFN
    layout_pattern=(ATTN_MOE, SSM_MLP, SSM_MOE, SSM_MLP, SSM_MOE, SSM_MLP,
                    SSM_MOE, SSM_MLP),
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    source="arXiv:2403.19887",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        arch_type="hybrid",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        layout_pattern=(ATTN_MOE, SSM_MLP),
        num_experts=4,
        experts_per_token=2,
        moe_d_ff=128,
        ssm_state=32,
        ssm_head_dim=32,
        ssm_expand=2,
        ssm_chunk=16,
        dtype="float32",
        source="arXiv:2403.19887",
    ).validate()
