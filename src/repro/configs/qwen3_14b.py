"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    layout_pattern=(ATTN,),
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=160,
        num_heads=5,
        num_kv_heads=1,
        d_ff=384,
        vocab_size=512,
        layout_pattern=(ATTN,),
        qk_norm=True,
        dtype="float32",
        source="hf:Qwen/Qwen3-8B",
    ).validate()
