"""Assigned architecture configs (``--arch <id>``) + reduced smoke variants.

Each module defines ``CONFIG`` (the exact assigned configuration, with the
source citation) and ``smoke_config()`` (2 layers, d_model ≤ 512,
≤ 4 experts — runnable on CPU).
"""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCH_IDS = (
    "mamba2_1p3b",
    "llama_3_2_vision_11b",
    "phi4_mini_3p8b",
    "olmoe_1b_7b",
    "kimi_k2_1t_a32b",
    "qwen2_5_32b",
    "minitron_4b",
    "qwen3_14b",
    "jamba_1_5_large_398b",
    "whisper_medium",
)

# public --arch ids (dashes) -> module names
ALIASES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen2.5-32b": "qwen2_5_32b",
    "minitron-4b": "minitron_4b",
    "qwen3-14b": "qwen3_14b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-medium": "whisper_medium",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ALIASES}
