"""qwen2.5-32b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    layout_pattern=(ATTN,),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=160,
        num_heads=5,
        num_kv_heads=1,
        d_ff=384,
        vocab_size=512,
        layout_pattern=(ATTN,),
        qkv_bias=True,
        dtype="float32",
        source="hf:Qwen/Qwen2.5-0.5B",
    ).validate()
