"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
Dense full-attention: `long_500k` runs only via the sliding-window variant
(window 8192), which the launcher enables for that shape.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    layout_pattern=(ATTN,),
    rope_theta=10_000.0,
    source="arXiv:2412.08905",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi4-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=192,
        num_heads=6,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        layout_pattern=(ATTN,),
        dtype="float32",
        source="arXiv:2412.08905",
    ).validate()
