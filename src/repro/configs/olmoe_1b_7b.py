"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per expert) vocab=50304,
MoE 64e top-8. Every FFN is MoE; expert-parallel sharding is where the
Puzzle dtype/backend configuration choice matters most.
"""
from repro.models.config import ATTN_MOE, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=50304,
    layout_pattern=(ATTN_MOE,),
    num_experts=64,
    experts_per_token=8,
    moe_d_ff=1024,
    qk_norm=True,
    source="arXiv:2409.02060",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        layout_pattern=(ATTN_MOE,),
        num_experts=4,
        experts_per_token=2,
        moe_d_ff=64,
        qk_norm=True,
        dtype="float32",
        source="arXiv:2409.02060",
    ).validate()
