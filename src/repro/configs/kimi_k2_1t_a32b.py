"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert) vocab=163840,
MoE 384e top-8. ~1.03T parameters; training state requires Adafactor +
full FSDP sharding (see train/optimizer.py and DESIGN.md §6).
"""
from repro.models.config import ATTN_MOE, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=163840,
    layout_pattern=(ATTN_MOE,),
    num_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        layout_pattern=(ATTN_MOE,),
        num_experts=4,
        experts_per_token=2,
        moe_d_ff=128,
        dtype="float32",
        source="arXiv:2501.kimi2",
    ).validate()
