"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    layout_pattern=(ATTN,),
    source="arXiv:2407.14679",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=192,
        num_heads=6,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=1024,
        layout_pattern=(ATTN,),
        dtype="float32",
        source="arXiv:2407.14679",
    ).validate()
