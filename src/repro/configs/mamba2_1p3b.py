"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, attention-free, d_ff=0, vocab=50280, ssm_state=128.
Sub-quadratic by construction: `long_500k` runs natively (O(1) decode
state). The Puzzle technique applies unchanged — subgraph cut points fall
between SSD blocks and the recurrent state crosses lane boundaries.
"""
from repro.models.config import SSM, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    layout_pattern=(SSM,),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060",
).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        arch_type="ssm",
        num_layers=2,
        d_model=256,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        layout_pattern=(SSM,),
        ssm_state=32,
        ssm_head_dim=32,
        ssm_expand=2,
        ssm_groups=1,
        ssm_chunk=16,
        tie_embeddings=True,
        dtype="float32",
        source="arXiv:2405.21060",
    ).validate()
