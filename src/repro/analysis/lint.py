"""Schedule lint CLI: ``python -m repro.analysis.lint``.

Runs the static analyzer (:mod:`repro.analysis.schedlint`) over concrete
(scenario, schedule) pairs without simulating anything, and prints / writes
the typed ``SL0xx`` findings:

* ``--demo`` — a small built-in two-network scenario with a random and a
  per-processor-pinned schedule (quickstart; no artifacts needed).
* ``--results PATH`` — every scenario of a committed sweep artifact
  (``RESULTS_sweep.json``): rebuilds each scenario from its replayable
  spec and lints the reconstructable schedules (the per-processor GA seed
  solutions and the NPU-Only baseline).
* ``--golden`` — the committed golden-trace scenarios: lints the exact
  (scenario, schedule) pairs behind ``tests/golden/*.json`` at their
  recorded periods (requires the test directory on ``PYTHONPATH``, e.g.
  ``PYTHONPATH=src:tests``, mirroring the fault-differential CI step).

``--alpha A`` additionally evaluates the per-α deadline proofs
(SL030/SL031) at period multiplier ``A``. ``--out PATH`` writes the full
JSON report; ``--strict`` exits nonzero when any error-severity finding
(not warnings) is present — the CI soundness step runs the golden mode
strict, because the committed goldens are known-feasible schedules.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, Iterable, List, Optional, Tuple

from ..core.chromosome import Solution
from .diagnostics import LintReport
from .schedlint import ScheduleLinter

Entry = Tuple[str, str, LintReport]  # (scenario, schedule label, report)


def _demo_entries(alpha: Optional[float]) -> List[Entry]:
    import random

    from ..core.analyzer import AnalyzerConfig, StaticAnalyzer
    from ..core.chromosome import SolutionFactory
    from ..core.comm import PAPER_COMM_MODEL
    from ..core.graph import chain_graph
    from ..core.processors import mobile_processors
    from ..core.profiler import AnalyticMobileBackend, Profiler
    from ..core.scenarios import Scenario

    nets = (
        chain_graph("alpha", [("conv", 4e6, 1000, 4000)] * 4),
        chain_graph("beta", [("fc", 8e6, 2000, 8000)] * 3),
    )
    scenario = Scenario(name="demo", graphs=nets, groups=((0,), (1,)))
    processors = mobile_processors()
    analyzer = StaticAnalyzer(
        scenario, processors, Profiler(AnalyticMobileBackend(processors)),
        PAPER_COMM_MODEL, AnalyzerConfig(),
    )
    linter = analyzer.linter()
    factory = SolutionFactory(
        nets, num_processors=len(processors), rng=random.Random(0))
    entries: List[Entry] = [
        ("demo", "random", linter.lint(factory.random_solution(), alpha=alpha)),
    ]
    for proc in processors:
        entries.append((
            "demo", f"seed_{proc.name.lower()}",
            linter.lint(analyzer.factory.seeded_solution(proc.pid),
                        alpha=alpha),
        ))
    return entries


def _results_entries(
    path: str, alpha: Optional[float], max_scenarios: Optional[int]
) -> List[Entry]:
    from ..core.analyzer import AnalyzerConfig, StaticAnalyzer
    from ..core.scenarios import build_scenario
    from ..experiments.evaluate import default_context
    from ..experiments.specs import ScenarioSpec

    with open(path) as fh:
        doc = json.load(fh)
    records = doc["scenarios"] if isinstance(doc, dict) else doc
    if max_scenarios is not None:
        records = records[:max_scenarios]
    ctx = default_context()
    entries: List[Entry] = []
    for record in records:
        spec = ScenarioSpec.from_json(record["spec"])
        scenario = build_scenario(
            spec.name, [list(g) for g in spec.groups], ctx.graphs,
            arrival=spec.arrival, faults=spec.faults,
        )
        analyzer = StaticAnalyzer(
            scenario, ctx.processors, ctx.profiler, ctx.comm_model,
            AnalyzerConfig(),
        )
        linter = analyzer.linter()
        schedules: Dict[str, Solution] = {
            f"seed_pid{p.pid}": analyzer.factory.seeded_solution(p.pid)
            for p in ctx.processors
        }
        schedules["npu_only"] = analyzer.npu_only()
        for label, sol in schedules.items():
            entries.append((spec.name, label, linter.lint(sol, alpha=alpha)))
    return entries


def _golden_entries(alpha: Optional[float]) -> List[Entry]:
    try:
        import test_golden_traces as tg
    except ImportError as exc:  # pragma: no cover - environment guard
        raise SystemExit(
            "--golden needs the test directory importable, e.g. "
            "PYTHONPATH=src:tests python -m repro.analysis.lint --golden"
        ) from exc

    from ..core.comm import PAPER_COMM_MODEL
    from ..core.simulator import NoiseModel

    entries: List[Entry] = []
    for name, params in tg.SCENARIOS.items():
        (nets_fn, groups, periods, num_requests, noise_seed, _dispatch,
         pin, arrivals, faults) = params
        nets = nets_fn()
        sol = tg._solution(nets, seed=11, pin=pin)
        linter = ScheduleLinter(
            graphs=nets, groups=groups, processors=tg.PROCS,
            profiler=tg.PROFILER, comm_model=PAPER_COMM_MODEL,
            base_periods=periods,
            noise=(NoiseModel(seed=noise_seed)
                   if noise_seed is not None else None),
            faults=faults, arrival=arrivals,
            score_requests=num_requests,
            noise_seed=noise_seed if noise_seed is not None else 0,
        )
        entries.append((name, "golden", linter.lint(sol, alpha=alpha)))
    return entries


def _print_entries(entries: Iterable[Entry], verbose: bool) -> int:
    errors = 0
    for scenario, label, rep in entries:
        counts = rep.counts()
        flag = "INFEASIBLE" if rep.infeasible else (
            "errors" if rep.errors() else "clean")
        lb = (f" alpha_lb={rep.alpha_lower_bound:.4g}"
              if rep.alpha_lower_bound > 0.0 else "")
        print(f"{scenario}/{label}: {flag} {counts or '{}'}{lb}")
        errors += len(rep.errors())
        if verbose:
            for d in rep.findings:
                proof = " [proof]" if d.proof else ""
                print(f"  {d.code} {d.severity}{proof}: {d.message}")
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Static feasibility lint over decoded schedules "
                    "(zero simulation).",
    )
    source = parser.add_mutually_exclusive_group(required=True)
    source.add_argument("--demo", action="store_true",
                        help="lint a small built-in demo scenario")
    source.add_argument("--results", metavar="PATH",
                        help="lint every scenario of a sweep artifact "
                             "(RESULTS_sweep.json)")
    source.add_argument("--golden", action="store_true",
                        help="lint the committed golden-trace schedules "
                             "(needs PYTHONPATH=src:tests)")
    parser.add_argument("--alpha", type=float, default=None,
                        help="also run the SL030/SL031 deadline proofs at "
                             "this period multiplier")
    parser.add_argument("--max-scenarios", type=int, default=None,
                        help="limit --results to the first N scenarios")
    parser.add_argument("--out", metavar="PATH",
                        help="write the full JSON report here")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 when any error-severity finding exists")
    parser.add_argument("-v", "--verbose", action="store_true",
                        help="print every finding, not just per-schedule "
                             "counts")
    args = parser.parse_args(argv)

    if args.demo:
        entries = _demo_entries(args.alpha)
        mode = "demo"
    elif args.results:
        entries = _results_entries(args.results, args.alpha,
                                   args.max_scenarios)
        mode = "results"
    else:
        entries = _golden_entries(args.alpha)
        mode = "golden"

    errors = _print_entries(entries, args.verbose)
    total = sum(len(rep.findings) for _, _, rep in entries)
    print(f"linted {len(entries)} schedules: {total} findings, "
          f"{errors} errors")

    if args.out:
        doc = {
            "mode": mode,
            "alpha": args.alpha,
            "schedules": [
                {"scenario": scenario, "schedule": label,
                 "report": rep.to_json()}
                for scenario, label, rep in entries
            ],
            "total_findings": total,
            "total_errors": errors,
        }
        out_dir = os.path.dirname(args.out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.out, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
        print(f"wrote {args.out}")

    return 1 if (args.strict and errors) else 0


if __name__ == "__main__":
    sys.exit(main())
