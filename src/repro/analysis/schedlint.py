"""Static feasibility analysis of decoded solutions — zero simulation.

Three families of checks over a candidate schedule:

* **Structural** (SL001–SL004): chromosome shape/range validity, priority
  permutation consistency, and — for decoded subgraph lists — layer
  ownership integrity and acyclicity of the contracted subgraph DAG.
* **Capability** (SL010): per-network ``(dtype, backend)`` configurations
  the mapped processor does not support. *Warning only*: the simulator
  handles these via the profiler's fallback penalty (``Processor
  .fallback_penalty``), so they are slow, never infeasible.
* **Resource proofs** (SL020, SL030, SL031): chunk-rounded peak-memory
  bounds against per-processor capacities, and deadline lower bounds
  (critical path, per-request serialization, per-processor utilization)
  from ProfileDB costs that prove a ``(solution, α)`` pair unsatisfiable.

Soundness contract
------------------
Every ``proof=True`` error is a guarantee the simulator can never
contradict:

* **SL020** — the memory model is *static provisioning*: a processor holds
  the weights of every subgraph mapped to it plus one activation arena
  sized for its largest task (input + output), all chunk-rounded exactly
  like :class:`~repro.runtime.tensorpool.TensorPool`. A flagged pid cannot
  provision through a capacity-bounded pool (:func:`provision_memory`
  raises ``TensorPoolOOM`` — the differential suite asserts this).
* **SL030/SL031** — every per-task service-time term in the bounds is a
  floor of what any engine realizes: comm/quant are exact and never
  noised; exec is scaled by :meth:`ScheduleLinter.exec_floor`, the provable
  minimum of the deterministic lognormal noise stream times the smallest
  throttle factor (stragglers and dropout stalls only *add* time). A
  ``PROOF_MARGIN`` relative slack absorbs float-summation-order
  differences between the bound and the engines' event arithmetic. A
  critical-path violation means *every* request of the group misses (QoE
  = 0); a utilization violation means at least one request misses — both
  imply a scenario score strictly below the saturation threshold, and the
  implication is only claimed when the group/request count makes it valid.
* **SL001–SL004** — the chromosome cannot be decoded/simulated at all
  (shape or ownership corruption), or its dependency structure deadlocks
  (quotient cycle: the cyclic tasks are never released, so their group
  never completes a request). Solutions produced by
  :class:`~repro.core.chromosome.SolutionFactory` never trigger these.

Anything the analyzer cannot *prove* is not reported as an error, so a
feasible schedule is never pruned — enforced end-to-end by
``tests/test_schedlint.py``'s differential sweep.
"""
from __future__ import annotations

import math
import random
from typing import TYPE_CHECKING, Dict, List, Mapping, Optional, Sequence, Tuple

if TYPE_CHECKING:  # typing-only: analysis must stay import-light
    from ..core.analyzer import StaticAnalyzer

from ..core.arrivals import ArrivalSpec, draw_arrivals
from ..core.chromosome import BACKENDS, DTYPES, PlacedSubgraph, Solution
from ..core.comm import PiecewiseLinearCommModel
from ..core.fastsim import FastSimSpec, SpecBuilder
from ..core.faults import FaultSpec
from ..core.graph import (
    ModelGraph,
    Subgraph,
    partition_quotient,
    quotient_is_acyclic,
)
from ..core.memlayout import rounded_chunk_bytes
from ..core.processors import Processor
from ..core.profiler import Profiler
from ..core.simulator import NoiseModel
from .diagnostics import ERROR, WARNING, Diagnostic, LintReport

#: Relative slack on every infeasibility inequality: the engines accumulate
#: event times in a different float-summation order than the bounds, so a
#: strict comparison could over-claim by a few ulps. 1e-6 is ~6 orders of
#: magnitude above the worst accumulated rounding error of these sums and
#: ~5 below the α lattice resolution — it costs nothing in pruning power.
PROOF_MARGIN = 1e-6

_rounded = rounded_chunk_bytes  # the TensorPool's exact chunk accounting


def structural_diagnostics(
    graph: ModelGraph, subgraphs: Sequence[Subgraph], net: int = 0
) -> List[Diagnostic]:
    """SL001/SL002 over an explicit subgraph list for one network.

    ``graph.partition`` output always passes; the checks guard hand-built
    or post-decode-corrupted subgraph lists.
    """
    out: List[Diagnostic] = []
    _owner, edges, problems = partition_quotient(graph, subgraphs)
    for msg in problems:
        out.append(Diagnostic(
            code="SL002", severity=ERROR, message=msg,
            location=(("net", net),), proof=True,
        ))
    if not problems and not quotient_is_acyclic(len(subgraphs), edges):
        out.append(Diagnostic(
            code="SL001", severity=ERROR,
            message=(f"network {graph.name}: contracted subgraph graph has "
                     f"a dependency cycle (deadlock: cyclic tasks are never "
                     f"released)"),
            location=(("net", net),), proof=True,
        ))
    return out


def memory_lower_bounds(
    placed: Sequence[Sequence[PlacedSubgraph]],
) -> Dict[int, Tuple[int, int]]:
    """Per-processor ``(weights_bytes, arena_bytes)`` residency bound.

    Static-provisioning model: weights of every subgraph mapped to a pid
    are resident for the whole run, plus one activation arena sized for the
    pid's largest task (input + output). All terms are chunk-rounded with
    the TensorPool's rounding, so the bound equals what
    :func:`provision_memory` actually acquires.
    """
    weights: Dict[int, int] = {}
    arena: Dict[int, int] = {}
    for net_placed in placed:
        for p in net_placed:
            pid = p.processor
            weights[pid] = weights.get(pid, 0) + _rounded(p.subgraph.param_bytes)
            need = (_rounded(p.subgraph.input_bytes())
                    + _rounded(p.subgraph.output_bytes()))
            if need > arena.get(pid, 0):
                arena[pid] = need
    return {pid: (weights[pid], arena.get(pid, 0)) for pid in weights}


def provision_memory(
    placed: Sequence[Sequence[PlacedSubgraph]],
    capacities: Mapping[int, int],
) -> Dict[int, bool]:
    """Actually provision each capacity-bounded processor's tensors through
    a :class:`~repro.runtime.tensorpool.TensorPool`.

    Returns ``pid -> True`` when provisioning succeeded, ``False`` when the
    pool raised ``TensorPoolOOM``. This is the executable ground truth the
    SL020 soundness differential checks the analytic bound against.
    """
    import numpy as np

    from ..runtime.tensorpool import TensorPool, TensorPoolOOM

    out: Dict[int, bool] = {}
    for pid, cap in capacities.items():
        if cap <= 0:
            continue
        pool = TensorPool(capacity_bytes=cap)
        held: List[np.ndarray] = []
        arena_task: Optional[PlacedSubgraph] = None
        arena_need = -1
        ok = True
        try:
            for net_placed in placed:
                for p in net_placed:
                    if p.processor != pid:
                        continue
                    held.append(pool.acquire(
                        (max(0, int(p.subgraph.param_bytes)),), np.uint8))
                    need = (_rounded(p.subgraph.input_bytes())
                            + _rounded(p.subgraph.output_bytes()))
                    if need > arena_need:
                        arena_need = need
                        arena_task = p
            if arena_task is not None:
                held.append(pool.acquire(
                    (max(0, int(arena_task.subgraph.input_bytes())),),
                    np.uint8))
                held.append(pool.acquire(
                    (max(0, int(arena_task.subgraph.output_bytes())),),
                    np.uint8))
        except TensorPoolOOM:
            ok = False
        out[pid] = ok
    return out


class ScheduleLinter:
    """Static analyzer over decoded solutions for one scenario instance.

    Shares the analyzer's :class:`~repro.core.fastsim.SpecBuilder` when
    constructed via :meth:`from_analyzer`, so decode/cost work done for
    linting is reused by simulation (and vice versa).

    ``score_requests`` must be an upper bound on the ``num_requests`` of
    any measured run the deadline proofs are applied to (it bounds how many
    noise draws the exec floor must cover); ``noise_seed`` is the noise
    seed those runs use (the analyzer's scoring paths default to 0).
    """

    def __init__(
        self,
        graphs: Sequence[ModelGraph],
        groups: Sequence[Sequence[int]],
        processors: Sequence[Processor],
        profiler: Profiler,
        comm_model: PiecewiseLinearCommModel,
        base_periods: Optional[Sequence[float]] = None,
        input_home_pid: int = 0,
        noise: Optional[NoiseModel] = None,
        faults: Optional[FaultSpec] = None,
        arrival: Optional[ArrivalSpec] = None,
        threshold: float = 0.995,
        score_requests: int = 36,
        memory_capacity: Optional[Mapping[int, int]] = None,
        spec_builder: Optional[SpecBuilder] = None,
        noise_seed: int = 0,
        overlap_comm: bool = False,
    ):
        self.graphs = list(graphs)
        self.groups = [tuple(g) for g in groups]
        self.processors = list(processors)
        self.base_periods = (list(base_periods)
                             if base_periods is not None else None)
        self.noise = noise
        self.faults = None if faults is None or faults.empty else faults
        self.arrival = arrival
        self.threshold = float(threshold)
        self.score_requests = int(score_requests)
        self.noise_seed = int(noise_seed)
        self.overlap_comm = bool(overlap_comm)
        self.builder = spec_builder or SpecBuilder(
            self.graphs, self.processors, profiler, comm_model,
            input_home_pid=input_home_pid,
        )
        self._capacity: Dict[int, int] = {
            p.pid: int(p.memory_capacity) for p in self.processors
        }
        if memory_capacity:
            self._capacity.update(
                {int(k): int(v) for k, v in memory_capacity.items()})
        self._exec_floor_measured: Optional[float] = None

    @classmethod
    def from_analyzer(cls, analyzer: "StaticAnalyzer") -> "ScheduleLinter":
        """Linter sharing a :class:`~repro.core.analyzer.StaticAnalyzer`'s
        scenario, periods, noise/fault/arrival context and SpecBuilder."""
        return cls(
            graphs=analyzer.scenario.graphs,
            groups=analyzer.scenario.groups,
            processors=analyzer.processors,
            profiler=analyzer.profiler,
            comm_model=analyzer.comm,
            base_periods=analyzer.base_periods,
            input_home_pid=analyzer.cfg.input_home_pid,
            noise=analyzer.cfg.noise,
            faults=analyzer.faults,
            arrival=analyzer.arrival,
            score_requests=analyzer.cfg.accurate_requests,
            spec_builder=analyzer._spec_builder,
        )

    # -- structural (SL001-SL004) -------------------------------------------
    def shape_diagnostics(self, sol: Solution) -> List[Diagnostic]:
        """SL003/SL004: raw-gene shape, range and permutation checks."""
        out: List[Diagnostic] = []
        n_nets = len(self.graphs)
        n_procs = len(self.processors)

        def bad(code: str, msg: str, **loc: object) -> None:
            out.append(Diagnostic(
                code=code, severity=ERROR, message=msg,
                location=tuple(sorted(loc.items())), proof=True,
            ))

        for field_name, genes, want_len in (
            ("partition", sol.partition, [g.num_edges for g in self.graphs]),
            ("mapping", sol.mapping, [g.num_layers for g in self.graphs]),
        ):
            if len(genes) != n_nets:
                bad("SL003", f"{field_name} covers {len(genes)} networks, "
                             f"scenario has {n_nets}")
                continue
            for net, (row, want) in enumerate(zip(genes, want_len)):
                if len(row) != want:
                    bad("SL003", f"{field_name}[{net}] has {len(row)} genes, "
                                 f"expected {want}", net=net)
                    continue
                for i, v in enumerate(row):
                    hi = 2 if field_name == "partition" else n_procs
                    if not 0 <= v < hi:
                        bad("SL003",
                            f"{field_name}[{net}][{i}] = {v} outside "
                            f"[0, {hi})", net=net)
                        break
        for field_name, genes, hi in (
            ("dtype", sol.dtype, len(DTYPES)),
            ("backend", sol.backend, len(BACKENDS)),
        ):
            if len(genes) != n_nets:
                bad("SL003", f"{field_name} covers {len(genes)} networks, "
                             f"scenario has {n_nets}")
            else:
                for net, v in enumerate(genes):
                    if not 0 <= v < hi:
                        bad("SL003", f"{field_name}[{net}] = {v} outside "
                                     f"[0, {hi})", net=net)
        if sorted(sol.priority) != list(range(n_nets)):
            bad("SL004", f"priority {sol.priority} is not a permutation of "
                         f"0..{n_nets - 1}")
        return out

    # -- capability (SL010) --------------------------------------------------
    def capability_diagnostics(
        self, placed: Sequence[Sequence[PlacedSubgraph]]
    ) -> List[Diagnostic]:
        """SL010 warnings: configurations the mapped processor cannot run
        natively. The profiler substitutes ``min(supported) ×
        fallback_penalty``, so these simulate (slowly) — never proof."""
        out: List[Diagnostic] = []
        proc_by_pid = {p.pid: p for p in self.processors}
        seen = set()
        for net, net_placed in enumerate(placed):
            for p in net_placed:
                key = (net, p.processor, p.dtype, p.backend)
                if key in seen:
                    continue
                seen.add(key)
                proc = proc_by_pid.get(p.processor)
                if proc is None or proc.thr(p.dtype, p.backend) is not None:
                    continue
                out.append(Diagnostic(
                    code="SL010", severity=WARNING,
                    message=(f"network {net}: ({p.dtype}, {p.backend}) is "
                             f"unsupported on {proc.name}; simulates at "
                             f"{proc.fallback_penalty:g}x fallback penalty"),
                    location=(("dtype", p.dtype), ("backend", p.backend),
                              ("net", net), ("processor", p.processor)),
                ))
        return out

    # -- memory (SL020) ------------------------------------------------------
    def capacities(self) -> Dict[int, int]:
        """Effective per-pid capacity (0 = unconstrained)."""
        return dict(self._capacity)

    def memory_diagnostics(
        self, placed: Sequence[Sequence[PlacedSubgraph]]
    ) -> List[Diagnostic]:
        """SL020: static-provisioning residency bound vs capacity."""
        out: List[Diagnostic] = []
        bounds = memory_lower_bounds(placed)
        for pid in sorted(bounds):
            cap = self._capacity.get(pid, 0)
            if cap <= 0:
                continue
            weights, arena = bounds[pid]
            need = weights + arena
            if need > cap:
                out.append(Diagnostic(
                    code="SL020", severity=ERROR,
                    message=(f"processor {pid}: peak residency bound "
                             f"{need} B (weights {weights} B + arena "
                             f"{arena} B, chunk-rounded) exceeds capacity "
                             f"{cap} B"),
                    location=(("capacity", cap), ("need", need),
                              ("processor", pid)),
                    proof=True,
                ))
        return out

    # -- deadline bounds (SL030/SL031) --------------------------------------
    def exec_floor(self, measured: bool = True) -> float:
        """Provable lower bound of every multiplicative exec-time factor.

        Noise: the engines draw lognormal multipliers
        ``exp(gauss(-σ²/2, σ))`` from one ``random.Random(seed)`` stream in
        delivery order. ``random.Random.gauss(mu, sigma)`` returns
        ``mu + σ·z`` with a z-stream that depends only on the seed, so the
        first ``M = score_requests × Σ layers`` possible draws (an upper
        bound on task deliveries per run) are known exactly; the floor is
        the minimum of ``exp(-σ²/2 + σ·z)`` over those draws and the
        scenario's processor-kind sigmas. Faults: throttle factors may be
        < 1 (speedup windows), so the smallest factor multiplies in;
        stragglers (Pareto ≥ 1) and dropout stalls (≥ 0) only add time.
        """
        floor = 1.0
        if measured and self.noise is not None:
            if self._exec_floor_measured is None:
                sigmas = sorted({
                    self.noise.sigma(p.kind) for p in self.processors})
                sigmas = [s for s in sigmas if s > 0.0]
                f = 1.0
                if sigmas:
                    draws = self.score_requests * max(
                        1, sum(g.num_layers for g in self.graphs))
                    rng = random.Random(self.noise_seed)
                    z_min = min(rng.gauss(0.0, 1.0) for _ in range(draws))
                    f = min(
                        min(math.exp(-0.5 * s * s + s * z_min)
                            for s in sigmas),
                        1.0,
                    )
                self._exec_floor_measured = f
            floor = self._exec_floor_measured
        if self.faults is not None and self.faults.throttles:
            floor *= min(1.0, min(
                factor for _, _, _, factor in self.faults.throttles))
        return floor

    def _service_floors(
        self, spec: FastSimSpec, measured: bool
    ) -> List[float]:
        """Per-subgraph floor of the worker service time (comm+quant+exec)."""
        floor = self.exec_floor(measured)
        comm = [0.0] * spec.num_subgraphs if self.overlap_comm else spec.comm
        return [
            c + q + x * floor
            for c, q, x in zip(comm, spec.quant, spec.exec_)
        ]

    def group_lower_bounds(
        self, spec: FastSimSpec, measured: bool = True
    ) -> Optional[List[float]]:
        """Per-group makespan lower bound: max over the group's networks of
        the subgraph-DAG critical path, and over processors of the
        request's serialized work there. ``None`` when the dependency
        structure is cyclic (structurally infeasible — lint separately)."""
        w = self._service_floors(spec, measured)
        n_nets = len(spec.counts)
        cps: List[float] = []
        for n in range(n_nets):
            lo, cnt = spec.offsets[n], spec.counts[n]
            if cnt == 0:
                cps.append(0.0)
                continue
            indeg = [spec.dep_count[lo + i] for i in range(cnt)]
            dist = [w[lo + i] for i in range(cnt)]
            ready = [i for i in range(cnt) if indeg[i] == 0]
            done = 0
            while ready:
                i = ready.pop()
                done += 1
                g = lo + i
                for s in spec.succ_flat[
                        spec.succ_indptr[g]:spec.succ_indptr[g + 1]]:
                    sl = s - lo
                    cand = dist[i] + w[s]
                    if cand > dist[sl]:
                        dist[sl] = cand
                    indeg[sl] -= 1
                    if indeg[sl] == 0:
                        ready.append(sl)
            if done != cnt:
                return None  # dependency cycle: handled by SL001
            cps.append(max(dist))
        bounds: List[float] = []
        for group in self.groups:
            lb = max((cps[n] for n in group), default=0.0)
            work: Dict[int, float] = {}
            for n in group:
                lo, cnt = spec.offsets[n], spec.counts[n]
                for g in range(lo, lo + cnt):
                    pid = spec.proc_of[g]
                    work[pid] = work.get(pid, 0.0) + w[g]
            if work:
                lb = max(lb, max(work.values()))
            bounds.append(lb)
        return bounds

    def _group_proof_valid(self) -> bool:
        # one dead group (QoE=0) caps the score at (N-1)/N; that proves
        # score < threshold only when N·(1-threshold) < 1
        return len(self.groups) * (1.0 - self.threshold) < 1.0

    def alpha_lower_bound(
        self, spec: FastSimSpec, measured: bool = True
    ) -> float:
        """Largest proven-infeasible α: for every ``α`` strictly below the
        returned value, ``score(solution, α) < threshold`` is guaranteed
        (0.0 when nothing can be proven)."""
        if self.base_periods is None or not self._group_proof_valid():
            return 0.0
        lbs = self.group_lower_bounds(spec, measured)
        if lbs is None:
            return 0.0
        out = 0.0
        for lb, phi in zip(lbs, self.base_periods):
            if phi > 0.0 and lb > 0.0:
                out = max(out, lb * (1.0 - PROOF_MARGIN) / phi)
        return out

    def deadline_diagnostics(
        self,
        spec: FastSimSpec,
        alpha: float,
        measured: bool = True,
        num_requests: Optional[int] = None,
    ) -> List[Diagnostic]:
        """SL030/SL031 proofs for one probed α (empty when unprovable)."""
        out: List[Diagnostic] = []
        if self.base_periods is None:
            return out
        lbs = self.group_lower_bounds(spec, measured)
        if lbs is None:
            return out
        if self._group_proof_valid():
            for gid, (lb, phi) in enumerate(zip(lbs, self.base_periods)):
                deadline = alpha * phi
                if deadline < lb * (1.0 - PROOF_MARGIN):
                    out.append(Diagnostic(
                        code="SL030", severity=ERROR,
                        message=(f"group {gid}: makespan lower bound "
                                 f"{lb:.6g}s exceeds the α-scaled deadline "
                                 f"{deadline:.6g}s (α={alpha:g}) — every "
                                 f"request misses"),
                        location=(("alpha", alpha), ("group", gid)),
                        proof=True,
                    ))
        nreq = int(num_requests or self.score_requests)
        n_groups = len(self.groups)
        if n_groups * nreq * (1.0 - self.threshold) >= 1.0:
            return out  # one missed request would not push score < threshold
        periods = [alpha * p for p in self.base_periods]
        if any(p <= 0.0 for p in periods):
            return out
        tables = draw_arrivals(self.arrival, periods, nreq)
        t_min = min(t[0] for t in tables)
        t_max = max(
            tables[g][i] + periods[g]
            for g in range(n_groups) for i in range(nreq)
        )
        window = t_max - t_min
        w = self._service_floors(spec, measured)
        total: Dict[int, float] = {}
        for g in range(spec.num_subgraphs):
            pid = spec.proc_of[g]
            total[pid] = total.get(pid, 0.0) + w[g]
        for pid in sorted(total):
            work = total[pid] * nreq
            if work * (1.0 - PROOF_MARGIN) > window:
                out.append(Diagnostic(
                    code="SL031", severity=ERROR,
                    message=(f"processor {pid}: {work:.6g}s of floored work "
                             f"cannot fit the {window:.6g}s arrival window "
                             f"at α={alpha:g} — at least one request "
                             f"misses"),
                    location=(("alpha", alpha), ("processor", pid)),
                    proof=True,
                ))
        return out

    # -- entry points --------------------------------------------------------
    def lint(
        self,
        sol: Solution,
        alpha: Optional[float] = None,
        measured: bool = True,
    ) -> LintReport:
        """Full static report for ``sol`` (optionally at one probed α)."""
        rep = LintReport()
        shape = self.shape_diagnostics(sol)
        rep.extend(shape)
        if shape:
            return rep  # undecodable: nothing further can be checked
        placed = self.builder.decode(sol)
        for net, g in enumerate(self.graphs):
            rep.extend(structural_diagnostics(
                g, [p.subgraph for p in placed[net]], net))
        if rep.errors():
            return rep
        rep.extend(self.capability_diagnostics(placed))
        rep.extend(self.memory_diagnostics(placed))
        spec = self.builder.build(sol)
        rep.alpha_lower_bound = self.alpha_lower_bound(spec, measured)
        if alpha is not None:
            rep.checked_alpha = alpha
            rep.extend(self.deadline_diagnostics(spec, alpha, measured))
        return rep

    def prescreen_report(self, sol: Solution) -> Optional[LintReport]:
        """α-independent verdict for the GA pre-screen: a report when the
        chromosome is *proven* infeasible, else ``None`` (simulate it)."""
        rep = LintReport()
        shape = self.shape_diagnostics(sol)
        rep.extend(shape)
        if shape:
            return rep
        placed = self.builder.decode(sol)
        for net, g in enumerate(self.graphs):
            rep.extend(structural_diagnostics(
                g, [p.subgraph for p in placed[net]], net))
        if rep.errors():
            return rep
        rep.extend(self.memory_diagnostics(placed))
        return rep if rep.infeasible else None
