"""Typed diagnostics for the static schedule analyzer.

Every finding is a :class:`Diagnostic` with a stable ``SL0xx`` code, a
severity, a human-readable message and a structured location, collected
into a JSON-serializable :class:`LintReport`. Codes are append-only: a
code's meaning never changes once released, so downstream tooling (the CI
soundness gate, the sweep harness's per-scenario stats) can filter on them
across repo versions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

ERROR = "error"
WARNING = "warning"
SEVERITIES: Tuple[str, ...] = (ERROR, WARNING)

#: Stable diagnostic registry. Structural codes are SL00x, capability SL01x,
#: memory SL02x, deadline SL03x.
CODES: Dict[str, str] = {
    "SL001": "contracted subgraph quotient graph has a dependency cycle",
    "SL002": "dangling cross-subgraph edge or corrupted layer ownership",
    "SL003": "chromosome shape or gene range is invalid for the scenario",
    "SL004": "priority chromosome is not a permutation of the networks",
    "SL010": "(dtype, backend) unsupported on the mapped processor "
             "(simulates via the fallback penalty — not infeasible)",
    "SL020": "per-processor peak tensor residency exceeds memory capacity",
    "SL030": "critical-path/serialization lower bound proves every request "
             "of a group misses its deadline at the probed α",
    "SL031": "per-processor work exceeds the feasible arrival window at "
             "the probed α (utilization bound)",
}


@dataclass(frozen=True)
class Diagnostic:
    """One finding of the static analyzer.

    ``location`` is a tuple of ``(key, value)`` pairs (kept hashable so
    diagnostics deduplicate in sets) — typical keys: ``net``, ``subgraph``,
    ``processor``, ``group``, ``alpha``. ``proof=True`` marks the finding
    as participating in an infeasibility *proof*: the soundness contract
    guarantees the simulator cannot score the schedule feasible. Only
    proof-bearing errors may prune (GA pre-screen, α-probe skip).
    """

    code: str
    severity: str
    message: str
    location: Tuple[Tuple[str, object], ...] = ()
    proof: bool = False

    def __post_init__(self) -> None:
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def where(self) -> Dict[str, object]:
        """``location`` as a plain dict."""
        return dict(self.location)

    def to_json(self) -> Dict[str, object]:
        return {
            "code": self.code,
            "severity": self.severity,
            "message": self.message,
            "location": dict(self.location),
            "proof": self.proof,
        }

    @classmethod
    def from_json(cls, d: Mapping[str, object]) -> "Diagnostic":
        loc = d.get("location") or {}
        return cls(
            code=str(d["code"]),
            severity=str(d["severity"]),
            message=str(d["message"]),
            location=tuple(sorted(loc.items())),  # type: ignore[union-attr]
            proof=bool(d.get("proof", False)),
        )


@dataclass
class LintReport:
    """All findings for one linted schedule (or one ``(schedule, α)`` pair).

    ``alpha_lower_bound`` is the proven deadline bound: for every
    ``α < alpha_lower_bound`` the scenario score is guaranteed below the
    saturation threshold (0.0 when nothing could be proven — e.g. too many
    groups for the proof template, or no deadline data). ``checked_alpha``
    records the α the deadline lints (SL030/SL031) were evaluated at, when
    one was supplied.
    """

    findings: List[Diagnostic] = field(default_factory=list)
    alpha_lower_bound: float = 0.0
    checked_alpha: Optional[float] = None

    @property
    def infeasible(self) -> bool:
        """True iff the report *proves* the schedule can never be feasible
        (independent of α). Only proof-bearing errors count — warnings and
        α-specific deadline findings (which carry ``alpha`` in their
        location) do not make the schedule itself infeasible."""
        return any(
            d.proof and d.severity == ERROR and "alpha" not in d.where()
            for d in self.findings
        )

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.findings if d.severity == ERROR]

    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.findings if d.severity == WARNING]

    def by_code(self, code: str) -> List[Diagnostic]:
        return [d for d in self.findings if d.code == code]

    def counts(self) -> Dict[str, int]:
        """Finding count per diagnostic code (stable sort order)."""
        out: Dict[str, int] = {}
        for d in self.findings:
            out[d.code] = out.get(d.code, 0) + 1
        return dict(sorted(out.items()))

    def extend(self, diagnostics: List[Diagnostic]) -> None:
        self.findings.extend(diagnostics)

    def to_json(self) -> Dict[str, object]:
        doc: Dict[str, object] = {
            "findings": [d.to_json() for d in self.findings],
            "alpha_lower_bound": self.alpha_lower_bound,
            "infeasible": self.infeasible,
            "counts": self.counts(),
        }
        if self.checked_alpha is not None:
            doc["checked_alpha"] = self.checked_alpha
        return doc

    @classmethod
    def from_json(cls, d: Mapping[str, object]) -> "LintReport":
        rep = cls(
            findings=[Diagnostic.from_json(f)  # type: ignore[arg-type]
                      for f in d.get("findings", ())],
            alpha_lower_bound=float(d.get("alpha_lower_bound", 0.0)),
        )
        if "checked_alpha" in d:
            rep.checked_alpha = float(d["checked_alpha"])  # type: ignore[arg-type]
        return rep
