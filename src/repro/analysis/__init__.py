"""Static schedule analysis (``schedlint``): decode-time feasibility checks.

This package verifies candidate schedules *without simulating them*:
structural lints over the contracted subgraph DAG, capability checks
against the processor descriptors, chunk-rounded memory-residency bounds
against TensorPool capacities, and deadline lower bounds (critical path,
per-processor work) that can prove a ``(solution, α)`` pair unsatisfiable
from ProfileDB costs alone.

Soundness contract: every *error*-severity finding with ``proof=True`` is
a guarantee — the simulator could never score the flagged chromosome
feasible. That is what allows the GA pre-screen (``GAConfig.prescreen``)
and the α-probe skip (``bisect_alpha_probes(skip_below=...)``) to act on
findings without changing search results. Warnings (e.g. capability
fallbacks) carry no such guarantee and never prune.

CLI: ``python -m repro.analysis.lint --help``.
"""
from .diagnostics import CODES, Diagnostic, LintReport
from .schedlint import (
    PROOF_MARGIN,
    ScheduleLinter,
    memory_lower_bounds,
    provision_memory,
    structural_diagnostics,
)

__all__ = [
    "CODES",
    "Diagnostic",
    "LintReport",
    "PROOF_MARGIN",
    "ScheduleLinter",
    "memory_lower_bounds",
    "provision_memory",
    "structural_diagnostics",
]
