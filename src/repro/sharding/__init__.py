"""Sharding: logical-axis rules -> PartitionSpecs for the production mesh."""
from .rules import (
    DEFAULT_RULES,
    batch_spec,
    cache_shardings,
    data_sharding,
    spec_for_shape,
    tree_shardings,
)

__all__ = [k for k in dir() if not k.startswith("_")]
