"""Activation-sharding context.

The model code is mesh-agnostic; the launcher declares which mesh axes
carry the batch (and model) dimension of activations, and the forward pass
pins activations to that layout with ``with_sharding_constraint`` at block
boundaries. Without these constraints GSPMD is free to reshard the scan
carry (observed: batch-sharding silently dropped inside the layer loop,
replicating batch work 16×).

Outside a mesh context (CPU smoke tests) the constraints are no-ops.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"batch_axes": None, "model_axis": None}


@contextlib.contextmanager
def activation_sharding(batch_axes: Optional[Tuple[str, ...]],
                        model_axis: Optional[str] = "model"):
    old = dict(_STATE)
    _STATE["batch_axes"] = batch_axes
    _STATE["model_axis"] = model_axis
    try:
        yield
    finally:
        _STATE.update(old)


def batch_axes() -> Optional[Tuple[str, ...]]:
    return _STATE["batch_axes"]


def _spec(n_extra: int) -> Optional[P]:
    ba = _STATE["batch_axes"]
    if ba is None:
        return None
    b = ba if len(ba) > 1 else ba[0]
    return P(b, *([None] * n_extra))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim 0 of an activation to the declared batch axes."""
    spec = _spec(x.ndim - 1)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_axes(x: jax.Array, *dim_axes: Optional[str]) -> jax.Array:
    """Pin specific dims: dim 0 to the batch axes, others as given.

    ``dim_axes`` covers dims 1..n; callers must pre-check divisibility for
    any 'model'-axis assignment.
    """
    ba = _STATE["batch_axes"]
    if ba is None:
        return x
    axes = [ba if len(ba) > 1 else ba[0]] + list(dim_axes)
    while len(axes) < x.ndim:
        axes.append(None)
    return jax.lax.with_sharding_constraint(x, P(*axes))
