"""Logical-axis → mesh-axis sharding rules.

Parameters carry *logical axis* names (``repro.models.*_spec``); this module
resolves them to ``PartitionSpec``s for a concrete mesh, with divisibility
checks and opportunistic fallbacks:

* ``ffn`` / ``vocab`` / ``experts`` / ``ssm_inner`` → tensor-parallel over
  the "model" axis (all assigned configs divide evenly);
* ``heads`` → "model" when the head count divides the axis, else fall back
  to sharding ``head_dim``, else replicate (GQA with few KV heads
  replicates KV — the standard Megatron compromise);
* ``embed`` → FSDP storage sharding over the data axes ("pod","data"):
  GSPMD then all-gathers weights just-in-time, i.e. ZeRO-3 semantics, and
  the gather traffic shows up in the collective roofline term;
* ``layers`` (stacked scan axis) → never sharded.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# preference-ordered candidate mesh axes per logical axis
DEFAULT_RULES: Dict[Optional[str], Tuple[Any, ...]] = {
    "embed": (("pod", "data"), ("data",)),
    "vocab": (("model",),),
    "ffn": (("model",),),
    "experts": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (),
    "ssm_inner": (("model",),),
    "ssm_heads": (("model",),),
    "layers": (),
    None: (),
}


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for_shape(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict] = None,
) -> P:
    """Resolve one leaf's logical axes to a PartitionSpec."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    # NOTE: when `heads` cannot shard over the model axis we deliberately
    # do NOT fall back to sharding head_dim: a head_dim-sharded QK^T
    # contraction all-reduces the (huge) score tensors — measured at 22 TB
    # per prefill_32k step on qwen2.5-32b (§Perf 2). Attention weights
    # replicate over "model" instead (FSDP over the data axes still shards
    # storage); the model axis then parallelizes FFN/vocab only for those
    # archs.
    for name, dim in zip(logical, shape):
        assigned = None
        candidates = list(rules.get(name, ()))
        for cand in candidates:
            cand = tuple(cand)
            if any(a in used for a in cand):
                continue
            if any(a not in mesh.shape for a in cand):
                continue
            if dim % _axes_size(mesh, cand) == 0 and dim >= _axes_size(mesh, cand):
                assigned = cand
                used.update(cand)
                break
        out.append(
            assigned[0] if assigned is not None and len(assigned) == 1
            else (assigned if assigned else None)
        )
    # trim trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(
    spec_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    rules: Optional[Dict] = None,
) -> Any:
    """Map (logical-axes tree, ShapeDtypeStruct tree) -> NamedSharding tree."""

    def leaf(axes, sds):
        p = spec_for_shape(tuple(axes), sds.shape, mesh, rules)
        return NamedSharding(mesh, p)

    return jax.tree.map(
        leaf, spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Shard the batch dim over as many data axes as divide it."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    chosen: Tuple[str, ...] = ()
    for k in range(len(axes), 0, -1):
        cand = tuple(axes[:k])
        if batch % _axes_size(mesh, cand) == 0 and batch >= _axes_size(mesh, cand):
            chosen = cand
            break
    if not chosen:
        return P(None)
    return P(chosen if len(chosen) > 1 else chosen[0])


def data_sharding(mesh: Mesh, batch: int, *trailing: Optional[str]) -> NamedSharding:
    bs = batch_spec(mesh, batch)
    return NamedSharding(mesh, P(*bs, *trailing))


def cache_shardings(cfg, mesh: Mesh, cache_tree_shapes: Any) -> Any:
    """Shardings for decode caches.

    KV caches (R, B, S, Kv, hd): batch over data axes when divisible, else
    sequence over data; Kv over "model" when divisible, else head_dim.
    SSM states (R, B, H, P, N): batch over data; H over model.
    """

    def leaf(sds):
        shape = sds.shape
        if len(shape) == 5 and shape[3] in (cfg.num_kv_heads,) and cfg.num_kv_heads:
            _, b, s, kv, hd = shape
            bspec = batch_spec(mesh, b)
            baxes = bspec[0] if len(bspec) else None
            seq_ax = None
            if baxes is None and "data" in mesh.shape and s % mesh.shape["data"] == 0:
                seq_ax = "data"
            kv_ax = "model" if kv % mesh.shape.get("model", 1) == 0 else None
            hd_ax = None
            if kv_ax is None and seq_ax != "model":
                # Context parallelism: shard the cache SEQUENCE over the
                # model axis. Decode attention then computes a distributed
                # softmax (tiny max/sum all-reduces) instead of GSPMD
                # replicating the cache for the grouped-GQA contraction
                # (§Perf 1: sharding head_dim provoked an involuntary full
                # rematerialization + 57 GiB all-gather per step).
                if s % mesh.shape.get("model", 1) == 0:
                    seq2 = ("model",) if seq_ax is None else (seq_ax, "model")
                    return NamedSharding(
                        mesh, P(None, baxes,
                                seq2 if len(seq2) > 1 else seq2[0], None, None))
            return NamedSharding(mesh, P(None, baxes, seq_ax, kv_ax, hd_ax))
        if len(shape) == 5:  # ssm state (R, B, H, P, N)
            _, b, h, p_, n_ = shape
            bspec = batch_spec(mesh, b)
            baxes = bspec[0] if len(bspec) else None
            h_ax = "model" if h % mesh.shape.get("model", 1) == 0 else None
            return NamedSharding(mesh, P(None, baxes, h_ax))
        if len(shape) == 4:  # conv state (R, B, w-1, C)
            _, b, _, c = shape
            bspec = batch_spec(mesh, b)
            baxes = bspec[0] if len(bspec) else None
            c_ax = "model" if c % mesh.shape.get("model", 1) == 0 else None
            return NamedSharding(mesh, P(None, baxes, None, c_ax))
        return NamedSharding(mesh, P())

    return jax.tree.map(leaf, cache_tree_shapes)
