"""Sweep aggregation: the paper's headline metrics from per-scenario results.

The paper's central numbers (§6, Fig. 11) are *aggregate* request-frequency
gains over randomly generated scenarios: Puzzle sustains 3.7× / 2.2× higher
request frequency than NPU Only / Best Mapping on average. Since request
frequency is the inverse of the sustainable period, the per-scenario gain
is the α* ratio ``α*_baseline / α*_puzzle``; this module reduces a list of
:class:`~repro.experiments.evaluate.ScenarioResult` to:

* per-method α* statistics (capped mean, median, fraction saturated),
* the **geometric mean** of per-scenario α* ratios vs. each baseline
  (the right mean for ratios: invariant to which side is the numerator),
* the arithmetic mean ratio (what a "N× on average" headline usually is),
* mean deadline-satisfaction rate per method at the base period.

Pure math on plain data — no simulation — so it is cheap to re-run over a
sweep directory and easy to unit-test on canned results.
"""
from __future__ import annotations

import math
from typing import Dict, Sequence

from ..core.scoring import percentile
from .evaluate import METHODS, ScenarioResult


def geometric_mean(values: Sequence[float]) -> float:
    """Geometric mean of positive values; 0.0 for an empty sequence.

    ``inf`` inputs propagate to ``inf`` (callers cap α* before forming
    ratios, so finite output is the normal case).
    """
    if not values:
        return 0.0
    if any(math.isinf(v) for v in values):
        return float("inf")
    return math.exp(sum(math.log(v) for v in values) / len(values))


def aggregate_results(
    results: Sequence[ScenarioResult],
    alpha_cap: float = 6.0,
) -> Dict[str, object]:
    """Reduce per-scenario results to the sweep's headline metrics.

    α* means/medians are computed with unsaturated scenarios capped at
    ``alpha_cap`` (matching the per-scenario ratio convention), and
    ``saturated_fraction`` reports how often each method saturated at all so
    the capping is visible rather than silent. Ratios come pre-capped from
    :class:`ScenarioResult`; ``speedup_geomean["vs_npu_only"]`` is the
    sweep-level analogue of the paper's 3.7× (and ``vs_best_mapping`` of the
    2.2×).
    """
    out: Dict[str, object] = {"num_scenarios": len(results)}
    if not results:
        return out

    alpha_stats: Dict[str, Dict[str, float]] = {}
    for m in METHODS:
        vals = [min(r.alpha_star[m], alpha_cap) for r in results]
        finite = [r.alpha_star[m] for r in results
                  if not math.isinf(r.alpha_star[m])]
        alpha_stats[m] = {
            "mean_capped": sum(vals) / len(vals),
            "median_capped": percentile(vals, 50.0),
            "saturated_fraction": len(finite) / len(results),
        }
    out["alpha_star"] = alpha_stats

    out["speedup_geomean"] = {
        "vs_npu_only": geometric_mean([r.ratios["npu_only"] for r in results]),
        "vs_best_mapping": geometric_mean(
            [r.ratios["best_mapping"] for r in results]),
    }
    # same gain under the pick-your-best-schedule convention (min over each
    # method's candidate set instead of the §6.2 median)
    out["speedup_geomean_best"] = {
        f"vs_{m}": geometric_mean([
            min(r.alpha_star_best[m], alpha_cap)
            / min(r.alpha_star_best["puzzle"], alpha_cap)
            for r in results
        ])
        for m in ("npu_only", "best_mapping")
    }
    out["speedup_mean"] = {
        "vs_npu_only": sum(r.ratios["npu_only"] for r in results) / len(results),
        "vs_best_mapping": sum(r.ratios["best_mapping"] for r in results)
        / len(results),
    }
    out["satisfaction_rate"] = {
        m: sum(r.satisfaction[m] for r in results) / len(results)
        for m in METHODS
    }
    out["total_wall_s"] = sum(r.wall_s for r in results)
    out["total_ga_evaluations"] = sum(r.ga_evaluations for r in results)
    return out
