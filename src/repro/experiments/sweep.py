"""Scenario-sweep harness: the paper's randomized evaluation at scale.

Fans randomly generated scenarios (§6.1 recipe) out across a
``ProcessPoolExecutor``, runs the full pipeline per scenario through
:func:`~repro.experiments.evaluate.evaluate_scenario`, and aggregates the
paper's headline metrics (α* ratios, geo-mean frequency gain vs. each
baseline, deadline-satisfaction rate) into ``RESULTS_sweep.json``.

Determinism contract: every scenario is a pure function of its
:class:`ScenarioSpec` and the :class:`SweepConfig`, with a private
SHA-256-derived RNG stream — so results are identical whatever the worker
count or completion order (``--workers 4`` ≡ ``--workers 1``), and a
re-run with the same seed reproduces the same scenarios and aggregates.

Resumability: each scenario persists to ``<run-dir>/scenario_NNN.json`` as
it completes (atomic rename); a re-run reloads finished scenarios whose
spec matches and evaluates only the remainder. The run directory stores the
sweep config and refuses to resume under a different one unless ``--force``
wipes it.

CLI::

    python -m repro.experiments.sweep --scenarios 30 --seed 0 --workers 4
    python -m repro.experiments.sweep --scenarios 30 --arrival poisson

``--arrival {periodic,jittered,poisson}`` opens the arrival axis: the same
scenario compositions evaluated under bursty traffic instead of the
paper's periodic sources (per-scenario SHA-256 arrival seeds keep the
determinism contract). ``--faults {none,stragglers,mixed}`` opens the
fault axis the same way: every evaluation stage (GA, α*-search,
satisfaction) runs under the scenario's injected fault ensemble — the
robustness objective. See ``--help`` for GA sizing and scenario-shape
knobs. Typical cost on a
laptop-class CPU: a handful of seconds per scenario (GA pop 20 × ≤30
generations plus three bisection α*-searches).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from typing import Callable, Dict, Optional, Sequence

from .aggregate import aggregate_results
from .evaluate import (
    METHODS,
    ScenarioResult,
    SweepConfig,
    default_context,
    evaluate_scenario,
)
from .specs import ScenarioSpec, generate_scenario_specs

_CONFIG_FILE = "sweep_config.json"

# Per-worker state, set once by the pool initializer so every scenario a
# worker evaluates reuses the same EvalContext (graph zoo + profiler cache).
_WORKER_CONFIG: Optional[SweepConfig] = None


def _init_worker(config: SweepConfig) -> None:
    global _WORKER_CONFIG
    _WORKER_CONFIG = config
    default_context()  # build graphs/profiler once, before the first task


def _eval_in_worker(spec: ScenarioSpec) -> ScenarioResult:
    return evaluate_scenario(spec, _WORKER_CONFIG, default_context())


def _scenario_path(run_dir: str, index: int) -> str:
    return os.path.join(run_dir, f"scenario_{index:03d}.json")


def _write_json(path: str, doc: Dict[str, object]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    os.replace(tmp, path)


def _load_finished(
    run_dir: str, specs: Sequence[ScenarioSpec]
) -> Dict[int, ScenarioResult]:
    """Reload completed scenarios whose stored spec matches the expected one."""
    done: Dict[int, ScenarioResult] = {}
    for spec in specs:
        path = _scenario_path(run_dir, spec.index)
        if not os.path.exists(path):
            continue
        try:
            with open(path) as f:
                result = ScenarioResult.from_json(json.load(f))
        except (ValueError, KeyError, TypeError):
            continue  # corrupt/partial file: re-evaluate
        if result.spec.to_json() == spec.to_json():
            done[spec.index] = result
    return done


def _check_run_dir(run_dir: str, config: SweepConfig, force: bool) -> None:
    os.makedirs(run_dir, exist_ok=True)
    cfg_path = os.path.join(run_dir, _CONFIG_FILE)
    if os.path.exists(cfg_path):
        with open(cfg_path) as f:
            stored = json.load(f)
        if stored != config.to_json():
            if not force:
                raise RuntimeError(
                    f"run dir {run_dir!r} holds results for a different sweep "
                    f"config; pass force=True/--force to discard them or "
                    f"choose a fresh --run-dir"
                )
            for name in os.listdir(run_dir):
                if name.startswith("scenario_") and name.endswith(".json"):
                    os.remove(os.path.join(run_dir, name))
    _write_json(cfg_path, config.to_json())


def run_sweep(
    specs: Sequence[ScenarioSpec],
    config: Optional[SweepConfig] = None,
    run_dir: str = "results/sweep",
    workers: int = 1,
    force: bool = False,
    log: Optional[Callable[[str], None]] = None,
) -> Dict[str, object]:
    """Evaluate ``specs``, resuming from ``run_dir``, and aggregate.

    ``workers <= 1`` evaluates inline (no process pool — handy under test
    and for debugging); otherwise scenarios fan out over a
    ``ProcessPoolExecutor(workers)`` whose initializer builds one shared
    :class:`EvalContext` per worker. Returns the full results document
    (``{"config", "scenarios", "aggregate"}``) with scenarios in index
    order; per-scenario wall times are in seconds.
    """
    config = config or SweepConfig()
    log = log or (lambda msg: None)
    _check_run_dir(run_dir, config, force)

    results = _load_finished(run_dir, specs)
    if results:
        log(f"resumed {len(results)}/{len(specs)} scenarios from {run_dir}")
    pending = [s for s in specs if s.index not in results]

    def record(result: ScenarioResult) -> None:
        results[result.spec.index] = result
        _write_json(_scenario_path(run_dir, result.spec.index),
                    result.to_json())
        stars = "  ".join(
            f"{m}={result.alpha_star[m]:.2f}" for m in METHODS
        )
        log(f"[{len(results)}/{len(specs)}] {result.spec.name} "
            f"groups={[len(g) for g in result.spec.groups]} {stars} "
            f"({result.wall_s:.1f}s)")

    if pending and workers <= 1:
        context = default_context()
        for spec in pending:
            record(evaluate_scenario(spec, config, context))
    elif pending:
        with ProcessPoolExecutor(
            max_workers=min(workers, len(pending)),
            initializer=_init_worker, initargs=(config,),
        ) as pool:
            futures = {pool.submit(_eval_in_worker, s) for s in pending}
            while futures:
                finished, futures = wait(futures, return_when=FIRST_COMPLETED)
                for fut in finished:
                    record(fut.result())

    ordered = [results[s.index] for s in specs]
    return {
        "config": config.to_json(),
        "scenarios": [r.to_json() for r in ordered],
        "aggregate": aggregate_results(ordered, alpha_cap=config.alpha_cap),
    }


def format_summary(doc: Dict[str, object]) -> str:
    """Human-readable recap of a results document (one string, multi-line)."""
    agg = doc["aggregate"]
    lines = [f"scenarios: {agg['num_scenarios']}"]
    if not agg["num_scenarios"]:
        return lines[0]
    for m in METHODS:
        st = agg["alpha_star"][m]
        lines.append(
            f"  {m:12s} α* mean={st['mean_capped']:.2f} "
            f"median={st['median_capped']:.2f} "
            f"saturated={st['saturated_fraction'] * 100:.0f}% "
            f"satisfaction@α=1: {agg['satisfaction_rate'][m] * 100:.0f}%"
        )
    lines.append(
        f"frequency gain (geo-mean α* ratio): "
        f"{agg['speedup_geomean']['vs_npu_only']:.2f}× vs NPU Only (paper 3.7×), "
        f"{agg['speedup_geomean']['vs_best_mapping']:.2f}× vs Best Mapping "
        f"(paper 2.2×)"
    )
    best = agg["speedup_geomean_best"]
    lines.append(
        f"frequency gain (best-schedule convention): "
        f"{best['vs_npu_only']:.2f}× vs NPU Only, "
        f"{best['vs_best_mapping']:.2f}× vs Best Mapping"
    )
    stats = [s["prescreen_stats"] for s in doc["scenarios"]
             if s.get("prescreen_stats") is not None]
    if stats:
        checked = sum(s["checked"] for s in stats)
        pruned = sum(s["pruned"] for s in stats)
        lines.append(
            f"prescreen: {pruned}/{checked} offspring pruned without "
            f"simulation across {len(stats)} scenarios"
        )
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments.sweep",
        description="Randomized scenario sweep reproducing the paper's "
                    "headline comparison (Puzzle vs NPU Only vs Best Mapping).",
    )
    ap.add_argument("--scenarios", type=int, default=30,
                    help="number of random scenarios (default 30)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sweep seed; fully determines scenarios and results")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool size; results are identical for any value")
    ap.add_argument("--run-dir", default=None,
                    help="resumable per-scenario output dir "
                         "(default results/sweep_s<seed>_n<scenarios>)")
    ap.add_argument("--out", default="RESULTS_sweep.json",
                    help="aggregate results file (default RESULTS_sweep.json)")
    ap.add_argument("--force", action="store_true",
                    help="discard run-dir results from a different config")
    ap.add_argument("--min-groups", type=int, default=1)
    ap.add_argument("--max-groups", type=int, default=3)
    ap.add_argument("--min-models", type=int, default=1)
    ap.add_argument("--max-models", type=int, default=4)
    ap.add_argument("--arrival", default="periodic",
                    choices=["periodic", "jittered", "poisson"],
                    help="request arrival process per group (default: "
                         "periodic, the paper's sources); non-periodic "
                         "scenarios carry per-scenario SHA-256 arrival "
                         "seeds, so results stay worker-count-invariant")
    ap.add_argument("--arrival-jitter", type=float, default=0.25,
                    help="jittered arrivals: max offset as a fraction of "
                         "the group period (default 0.25)")
    ap.add_argument("--arrival-distribution", default="uniform",
                    choices=["uniform", "lognormal"],
                    help="jitter distribution (default uniform)")
    ap.add_argument("--faults", default="none",
                    choices=["none", "stragglers", "mixed"],
                    help="injected fault ensemble per scenario (default "
                         "none): 'stragglers' = heavy-tailed per-task "
                         "inflation only, 'mixed' adds the dropout and "
                         "throttle windows; straggler draws use per-"
                         "scenario SHA-256 fault seeds, so results stay "
                         "worker-count-invariant")
    ap.add_argument("--fault-straggler-prob", type=float, default=0.1,
                    help="per-task straggler probability (default 0.1)")
    ap.add_argument("--fault-straggler-shape", type=float, default=1.5,
                    help="Pareto tail shape; smaller = heavier (default 1.5)")
    ap.add_argument("--fault-dropout", default="2:0.02:0.05",
                    help="mixed mode dropout window PID:T0[:T1] in seconds "
                         "(omit T1 for a permanent dropout; default "
                         "2:0.02:0.05); 'none' disables it")
    ap.add_argument("--fault-throttle", default="0:0.01:0.03:2.0",
                    help="mixed mode throttle window PID:T0:T1:FACTOR "
                         "(default 0:0.01:0.03:2.0); 'none' disables it")
    ap.add_argument("--pop-size", type=int, default=20, help="GA population")
    ap.add_argument("--max-generations", type=int, default=30)
    ap.add_argument("--min-generations", type=int, default=10)
    ap.add_argument("--bm-evals", type=int, default=120,
                    help="Best Mapping evaluation budget")
    ap.add_argument("--use-batch", action="store_true",
                    help="route α*-search + satisfaction sims through the "
                         "generation-batched engine (identical results; "
                         "see BENCH_simspeed.json for when it pays)")
    ap.add_argument("--batch-workers", type=int, default=1,
                    help="process shards per batched pass (with --use-batch)")
    ap.add_argument("--batch-engine", default="numpy",
                    choices=["numpy", "compiled"],
                    help="batched-pass engine (with --use-batch): 'numpy' "
                         "is bit-exact; 'compiled' runs the jitted "
                         "lock-step core (documented float tolerance, "
                         "transparent numpy fallback; see "
                         "BENCH_simspeed.json for the measured speedup)")
    ap.add_argument("--prescreen", action="store_true",
                    help="route GA offspring through the static schedule "
                         "linter (repro.analysis) before simulation and "
                         "skip α* probes below each solution's proven "
                         "infeasibility bound; records per-scenario prune "
                         "stats and a lint summary of the chosen schedule")
    ap.add_argument("--validate-runtime", action="store_true",
                    help="replay each scenario's best Puzzle schedule on the "
                         "virtual-clock PuzzleRuntime and record the "
                         "zero-tolerance trace diff vs the simulator")
    args = ap.parse_args(argv)
    if args.scenarios < 1:
        ap.error("--scenarios must be >= 1")

    def parse_window(text: str, parts: int, what: str):
        if text == "none":
            return None
        try:
            fields = text.split(":")
            if not (parts <= len(fields) <= parts + (1 if what == "dropout"
                                                     else 0)):
                raise ValueError(text)
            pid = int(fields[0])
            times = [float(x) for x in fields[1:]]
        except ValueError:
            ap.error(f"--fault-{what}: cannot parse {text!r}")
        if what == "dropout":
            return (pid, times[0], times[1] if len(times) > 1 else None)
        return (pid, times[0], times[1], times[2])

    specs = generate_scenario_specs(
        args.scenarios, seed=args.seed,
        min_groups=args.min_groups, max_groups=args.max_groups,
        min_models=args.min_models, max_models=args.max_models,
        arrival=args.arrival, arrival_jitter=args.arrival_jitter,
        arrival_distribution=args.arrival_distribution,
        faults=args.faults,
        fault_straggler_prob=args.fault_straggler_prob,
        fault_straggler_shape=args.fault_straggler_shape,
        fault_dropout=parse_window(args.fault_dropout, 2, "dropout"),
        fault_throttle=parse_window(args.fault_throttle, 4, "throttle"),
    )
    config = SweepConfig(
        pop_size=args.pop_size,
        max_generations=args.max_generations,
        min_generations=args.min_generations,
        bm_max_evals=args.bm_evals,
        use_batch=args.use_batch,
        batch_workers=args.batch_workers,
        batch_engine=args.batch_engine,
        validate_runtime=args.validate_runtime,
        prescreen=args.prescreen,
    )
    run_dir = args.run_dir or (
        f"results/sweep_s{args.seed}_n{args.scenarios}"
        + ("" if args.arrival == "periodic" else f"_a{args.arrival}")
        + ("" if args.faults == "none" else f"_f{args.faults}"))

    t0 = time.perf_counter()
    doc = run_sweep(specs, config, run_dir=run_dir, workers=args.workers,
                    force=args.force, log=lambda m: print(m, flush=True))
    doc["meta"] = {
        "seed": args.seed,
        "scenarios": args.scenarios,
        "workers": args.workers,
        "group_bounds": [args.min_groups, args.max_groups],
        "models_per_group_bounds": [args.min_models, args.max_models],
        "arrival": args.arrival,
        "faults": args.faults,
        "wall_s": time.perf_counter() - t0,
    }
    _write_json(args.out, doc)
    print(format_summary(doc))
    print(f"wrote {os.path.abspath(args.out)} "
          f"(per-scenario files in {run_dir})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
