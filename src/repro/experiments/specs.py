"""Scenario specifications for the randomized sweep (paper §6.1).

A :class:`ScenarioSpec` is the *replayable identity* of one randomly
generated scenario: which models, grouped how, under which request
*arrival process* (periodic / jittered / Poisson — the sweep's arrival
axis), plus the integer seeds the evaluation's explicitly seeded stages
(GA stream, baseline hillclimb shuffle, satisfaction-rate noise, arrival
timestamps) derive from. Specs serialize to/from plain JSON dicts so a
sweep run directory is self-describing and resumable — re-running a sweep
with the same ``(count, seed, size bounds, arrival)`` regenerates
byte-identical specs, and the harness cross-checks stored results against
the regenerated spec before reusing them.

Seed derivation is SHA-256 based (not ``hash()``) so it is stable across
processes and interpreter runs regardless of ``PYTHONHASHSEED`` — the
property that makes ``--workers N`` output identical to ``--workers 1``.
"""
from __future__ import annotations

import hashlib
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.arrivals import ArrivalSpec
from ..core.faults import FaultSpec
from ..core.scenarios import sample_groups
from ..zoo import MODEL_NAMES


def scenario_stream_seed(sweep_seed: int, index: int) -> int:
    """Deterministic 63-bit per-scenario seed from (sweep seed, index).

    Each scenario gets its own independent RNG stream: drawing scenario *i*
    never consumes randomness from scenario *j*, so scenarios can be
    generated, re-generated, or evaluated in any order (and on any worker)
    with identical results.
    """
    digest = hashlib.sha256(f"puzzle-sweep/{sweep_seed}/{index}".encode()).digest()
    return int.from_bytes(digest[:8], "big") >> 1


def arrival_stream_seed(sweep_seed: int, index: int) -> int:
    """Deterministic 63-bit per-scenario *arrival* seed.

    Separate derivation domain from :func:`scenario_stream_seed` so the
    arrival timestamps of scenario *i* are independent of its composition
    draws — and, like them, SHA-256-based so the value is a constant of
    ``(sweep_seed, index)`` across processes, worker counts and
    ``PYTHONHASHSEED``.
    """
    digest = hashlib.sha256(
        f"puzzle-arrival/{sweep_seed}/{index}".encode()).digest()
    return int.from_bytes(digest[:8], "big") >> 1


def fault_stream_seed(sweep_seed: int, index: int) -> int:
    """Deterministic 63-bit per-scenario *fault* seed.

    Third derivation domain beside :func:`scenario_stream_seed` and
    :func:`arrival_stream_seed`: the straggler draws of scenario *i*'s
    fault ensemble are independent of its composition and arrival streams,
    and stable across processes and ``PYTHONHASHSEED``.
    """
    digest = hashlib.sha256(
        f"puzzle-fault/{sweep_seed}/{index}".encode()).digest()
    return int.from_bytes(digest[:8], "big") >> 1


@dataclass(frozen=True)
class ScenarioSpec:
    """One randomized scenario: identity, composition, RNG stream, arrivals.

    ``groups`` holds per-group tuples of model names from the nine-network
    zoo (duplicates across groups allowed; materialized as distinct graphs).
    ``seed`` is the scenario's private stream seed — the seeded evaluation
    stages derive from it, never from global RNG state. ``arrival`` is the
    scenario's request arrival process (``None`` = periodic, serialized by
    omission so pre-arrival-axis run dirs still load); non-periodic specs
    carry their own SHA-256-derived arrival seed
    (:func:`arrival_stream_seed`), keeping results worker-count-invariant
    and resumable exactly like the composition stream. ``faults`` is the
    scenario's injected fault ensemble (``None`` = clean, serialized by
    omission); its straggler stream seed is the SHA-256-derived
    :func:`fault_stream_seed`, so the faulted sweep keeps the same
    determinism contract as the clean one.
    """

    index: int
    name: str
    seed: int
    groups: Tuple[Tuple[str, ...], ...]
    arrival: Optional[ArrivalSpec] = None
    faults: Optional[FaultSpec] = None

    @property
    def num_models(self) -> int:
        return sum(len(g) for g in self.groups)

    def to_json(self) -> Dict[str, object]:
        """Plain-JSON dict (lists instead of tuples); inverse of :meth:`from_json`."""
        doc: Dict[str, object] = {
            "index": self.index,
            "name": self.name,
            "seed": self.seed,
            "groups": [list(g) for g in self.groups],
        }
        if self.arrival is not None:
            doc["arrival"] = self.arrival.to_json()
        if self.faults is not None:
            doc["faults"] = self.faults.to_json()
        return doc

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "ScenarioSpec":
        return cls(
            index=int(d["index"]),
            name=str(d["name"]),
            seed=int(d["seed"]),
            groups=tuple(tuple(g) for g in d["groups"]),
            arrival=(ArrivalSpec.from_json(d["arrival"])
                     if d.get("arrival") is not None else None),
            faults=(FaultSpec.from_json(d["faults"])
                    if d.get("faults") is not None else None),
        )


def generate_scenario_specs(
    count: int,
    seed: int = 0,
    model_names: Sequence[str] = MODEL_NAMES,
    min_groups: int = 1,
    max_groups: int = 3,
    min_models: int = 1,
    max_models: int = 4,
    arrival: Optional[str] = None,
    arrival_jitter: float = 0.25,
    arrival_distribution: str = "uniform",
    faults: Optional[str] = None,
    fault_straggler_prob: float = 0.1,
    fault_straggler_shape: float = 1.5,
    fault_dropout: Optional[Tuple[int, float, Optional[float]]] = (2, 0.02, 0.05),
    fault_throttle: Optional[Tuple[int, float, float, float]] = (0, 0.01, 0.03, 2.0),
) -> List[ScenarioSpec]:
    """Generate ``count`` randomized scenario specs per the §6.1 recipe.

    For each scenario: 1–3 model groups (uniform), 1–4 distinct models per
    group (uniform) sampled from ``model_names`` — bounds adjustable via the
    keyword arguments. Scenario *i* is drawn from its own
    ``random.Random(scenario_stream_seed(seed, i))`` stream, so the list is
    a pure function of the arguments and any prefix of it is stable under a
    larger ``count``.

    ``arrival`` opens the sweep's arrival axis: ``None``/"periodic" keeps
    the paper's periodic sources (and byte-identical spec JSON), while
    "jittered" / "poisson" attach an :class:`ArrivalSpec` of that kind with
    a per-scenario :func:`arrival_stream_seed` — the compositions stay
    identical to the periodic sweep at the same ``seed``, only the traffic
    changes. ``arrival_jitter``/``arrival_distribution`` parameterize the
    jittered process.

    ``faults`` opens the fault axis the same way: ``None``/"none" keeps
    clean scenarios (byte-identical spec JSON), "stragglers" attaches a
    heavy-tailed straggler-only :class:`FaultSpec`, and "mixed" adds the
    ``fault_dropout`` window (``(pid, t0, t1)`` seconds; ``t1=None`` =
    permanent) and ``fault_throttle`` window (``(pid, t0, t1, factor)``) on
    top. Window times are absolute seconds shared across scenarios —
    deliberate, so the ensemble is identical per scenario and differences
    in damage reflect the *schedule*; only the straggler draws vary, via
    the per-scenario :func:`fault_stream_seed`. A scenario spec carrying
    faults makes the whole evaluation pipeline (GA search, α*-search,
    satisfaction) run under that ensemble — the robustness objective.
    """
    specs: List[ScenarioSpec] = []
    for i in range(count):
        stream = scenario_stream_seed(seed, i)
        rng = random.Random(stream)
        groups = sample_groups(
            rng, model_names,
            min_groups=min_groups, max_groups=max_groups,
            min_models=min_models, max_models=max_models,
        )
        arrival_spec = None
        if arrival is not None and arrival != "periodic":
            arrival_spec = ArrivalSpec(
                kind=arrival, jitter=arrival_jitter,
                distribution=arrival_distribution,
                seed=arrival_stream_seed(seed, i),
            )
        fault_spec = None
        if faults is not None and faults != "none":
            if faults not in ("stragglers", "mixed"):
                raise ValueError(f"unknown fault mode {faults!r} "
                                 f"(expected none/stragglers/mixed)")
            dropouts = ()
            throttles = ()
            if faults == "mixed":
                if fault_dropout is not None:
                    dropouts = (tuple(fault_dropout),)
                if fault_throttle is not None:
                    throttles = (tuple(fault_throttle),)
            fault_spec = FaultSpec(
                dropouts=dropouts, throttles=throttles,
                straggler_prob=fault_straggler_prob,
                straggler_shape=fault_straggler_shape,
                seed=fault_stream_seed(seed, i),
            )
        specs.append(ScenarioSpec(
            index=i, name=f"sweep_s{seed}_{i:03d}", seed=stream,
            groups=tuple(groups), arrival=arrival_spec, faults=fault_spec,
        ))
    return specs
