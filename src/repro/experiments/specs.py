"""Scenario specifications for the randomized sweep (paper §6.1).

A :class:`ScenarioSpec` is the *replayable identity* of one randomly
generated scenario: which models, grouped how, plus the integer seed the
evaluation's explicitly seeded stages (GA stream, baseline hillclimb
shuffle, satisfaction-rate noise) derive from. Specs serialize to/from plain JSON dicts so a sweep run
directory is self-describing and resumable — re-running a sweep with the
same ``(count, seed, size bounds)`` regenerates byte-identical specs, and
the harness cross-checks stored results against the regenerated spec before
reusing them.

Seed derivation is SHA-256 based (not ``hash()``) so it is stable across
processes and interpreter runs regardless of ``PYTHONHASHSEED`` — the
property that makes ``--workers N`` output identical to ``--workers 1``.
"""
from __future__ import annotations

import hashlib
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.scenarios import sample_groups
from ..zoo import MODEL_NAMES


def scenario_stream_seed(sweep_seed: int, index: int) -> int:
    """Deterministic 63-bit per-scenario seed from (sweep seed, index).

    Each scenario gets its own independent RNG stream: drawing scenario *i*
    never consumes randomness from scenario *j*, so scenarios can be
    generated, re-generated, or evaluated in any order (and on any worker)
    with identical results.
    """
    digest = hashlib.sha256(f"puzzle-sweep/{sweep_seed}/{index}".encode()).digest()
    return int.from_bytes(digest[:8], "big") >> 1


@dataclass(frozen=True)
class ScenarioSpec:
    """One randomized scenario: identity, composition, and RNG stream.

    ``groups`` holds per-group tuples of model names from the nine-network
    zoo (duplicates across groups allowed; materialized as distinct graphs).
    ``seed`` is the scenario's private stream seed — the seeded evaluation
    stages derive from it, never from global RNG state.
    """

    index: int
    name: str
    seed: int
    groups: Tuple[Tuple[str, ...], ...]

    @property
    def num_models(self) -> int:
        return sum(len(g) for g in self.groups)

    def to_json(self) -> Dict[str, object]:
        """Plain-JSON dict (lists instead of tuples); inverse of :meth:`from_json`."""
        return {
            "index": self.index,
            "name": self.name,
            "seed": self.seed,
            "groups": [list(g) for g in self.groups],
        }

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "ScenarioSpec":
        return cls(
            index=int(d["index"]),
            name=str(d["name"]),
            seed=int(d["seed"]),
            groups=tuple(tuple(g) for g in d["groups"]),
        )


def generate_scenario_specs(
    count: int,
    seed: int = 0,
    model_names: Sequence[str] = MODEL_NAMES,
    min_groups: int = 1,
    max_groups: int = 3,
    min_models: int = 1,
    max_models: int = 4,
) -> List[ScenarioSpec]:
    """Generate ``count`` randomized scenario specs per the §6.1 recipe.

    For each scenario: 1–3 model groups (uniform), 1–4 distinct models per
    group (uniform) sampled from ``model_names`` — bounds adjustable via the
    keyword arguments. Scenario *i* is drawn from its own
    ``random.Random(scenario_stream_seed(seed, i))`` stream, so the list is
    a pure function of the arguments and any prefix of it is stable under a
    larger ``count``.
    """
    specs: List[ScenarioSpec] = []
    for i in range(count):
        stream = scenario_stream_seed(seed, i)
        rng = random.Random(stream)
        groups = sample_groups(
            rng, model_names,
            min_groups=min_groups, max_groups=max_groups,
            min_models=min_models, max_models=max_models,
        )
        specs.append(ScenarioSpec(
            index=i, name=f"sweep_s{seed}_{i:03d}", seed=stream,
            groups=tuple(groups),
        ))
    return specs
