"""Experiment harnesses: the paper's randomized evaluation, reproducible.

The sweep harness turns the repo's single-scenario pipeline into the
paper's §6 protocol — many randomly generated scenarios, three methods
each, aggregated into the headline frequency-gain numbers::

    python -m repro.experiments.sweep --scenarios 30 --seed 0 --workers 4

Layers (each importable on its own):

* :mod:`.specs`     — :class:`ScenarioSpec` + the §6.1 random generator
* :mod:`.evaluate`  — :func:`evaluate_scenario`, the one per-scenario entry
  point (GA + baselines + α*-search + satisfaction)
* :mod:`.aggregate` — headline-metric reduction (geo-mean α* ratios, …)
* :mod:`.sweep`     — process-pool fan-out, resumable run dir, CLI
"""
from .aggregate import aggregate_results, geometric_mean
from .evaluate import (
    METHODS,
    EvalContext,
    ScenarioResult,
    SweepConfig,
    default_context,
    evaluate_scenario,
)
from .specs import (
    ScenarioSpec,
    arrival_stream_seed,
    fault_stream_seed,
    generate_scenario_specs,
    scenario_stream_seed,
)

__all__ = [
    "METHODS",
    "EvalContext",
    "ScenarioResult",
    "ScenarioSpec",
    "SweepConfig",
    "aggregate_results",
    "arrival_stream_seed",
    "default_context",
    "evaluate_scenario",
    "fault_stream_seed",
    "format_summary",
    "generate_scenario_specs",
    "geometric_mean",
    "run_sweep",
    "scenario_stream_seed",
]


def __getattr__(name):
    # .sweep is imported lazily so ``python -m repro.experiments.sweep``
    # doesn't trip runpy's found-in-sys.modules RuntimeWarning.
    if name in ("run_sweep", "format_summary"):
        from . import sweep as _sweep
        return getattr(_sweep, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
