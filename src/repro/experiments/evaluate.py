"""Single-scenario evaluation: the one entry point the sweep harness drives.

``evaluate_scenario(spec, config, context)`` runs the full pipeline the
paper applies to every randomly generated scenario:

1. materialize the scenario's model graphs and derive base periods (§6.1),
2. GA search on the fast evaluation engine (Puzzle),
3. the NPU Only and Best Mapping baselines (§6.1),
4. bisection α*-search (saturation multiplier, §6.2) for all three,
5. deadline-satisfaction rate at the base period (α = 1.0) for all three.

All times are **seconds**. Every stochastic stage is explicitly seeded: the
GA stream, the baseline's neighbor shuffle, and the satisfaction-rate noise
stream all derive from ``spec.seed``; the request *arrival* stream (when
``spec.arrival`` selects a non-periodic process) carries its own SHA-256
per-scenario seed inside the spec; and the measured-noise stream inside
the α*-search uses the analyzer's fixed default (identical across
scenarios). Either way a scenario's result is a pure function of ``(spec,
config)`` — the property the multi-process sweep relies on for
worker-count-independent output.

Deadlines are per-request: request *i* must finish by ``arrival_i + Φ``
with Φ the group's α-scaled base period — equivalent to checking the
arrival-relative makespan against Φ, which is what the scoring layer does,
so the same code is correct for periodic and bursty traffic alike.
"""
from __future__ import annotations

import math
import time
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

from ..core import (
    AnalyzerConfig,
    GAConfig,
    PAPER_COMM_MODEL,
    Profiler,
    Solution,
    StaticAnalyzer,
    TableBackend,
    build_scenario,
    deadline_satisfaction,
    mobile_processors,
    percentile,
)
from ..core.profiler import AnalyticMobileBackend
from ..zoo import all_cost_graphs, paper_profile_tables
from .specs import ScenarioSpec

#: Method keys used throughout results, in reporting order.
METHODS = ("puzzle", "best_mapping", "npu_only")


@dataclass(frozen=True)
class SweepConfig:
    """Knobs for one sweep run (picklable; shipped to pool workers).

    GA sizing defaults match the repo's benchmark protocol (pop 20 × ≤30
    generations). ``alpha_cap`` bounds unsaturated α* (``inf``) when forming
    ratios, mirroring the capped mean in ``benchmarks/run.py``.
    ``satisfaction_alpha`` is the period multiplier at which the
    deadline-satisfaction rate is measured (1.0 = the §6.1 base period).
    """

    pop_size: int = 20
    max_generations: int = 30
    min_generations: int = 10
    bm_max_evals: int = 120
    engine: str = "fast"
    saturation_mode: str = "bisect"
    alpha_cap: float = 6.0
    satisfaction_alpha: float = 1.0
    satisfaction_requests: int = 36
    # Route the α*-searches and satisfaction sims through the
    # generation-batched engine (repro.core.batchsim): every bisection round
    # evaluates the whole candidate population as one lock-step batch, and
    # the three satisfaction sims share a batch. Per-scenario results are
    # bit-identical either way (tests assert it); on CPU the per-solution
    # loop is currently faster at typical candidate-set widths, so the
    # default stays off — see BENCH_simspeed.json's batch section.
    use_batch: bool = False
    batch_workers: int = 1
    # Batched-engine selection: "numpy" (bit-exact lock-step) or "compiled"
    # (jitted jax.lax.while_loop core; documented float tolerance, falls
    # back to numpy transparently when jax is unavailable or the workload
    # is unsupported). See repro.core.batchsim_compiled.
    batch_engine: str = "numpy"
    # Device-in-the-loop conformance: after picking Puzzle's best schedule,
    # execute it on the virtual-clock PuzzleRuntime and diff the task trace
    # against the simulator at zero tolerance; the scalar diff summary lands
    # in ``ScenarioResult.runtime_conformance``. Adds one runtime replay per
    # scenario (~ms); results are otherwise unchanged.
    validate_runtime: bool = False
    # Static pre-screening (repro.analysis): route GA offspring through the
    # schedule linter before simulation (proven-infeasible chromosomes get
    # worst-rank fitness without simulating) and let the α*-searches skip
    # probes below each solution's proven infeasibility bound. Sound-only:
    # results can differ from a non-prescreened run only by excluding
    # chromosomes the linter *proves* can never score feasible. Also records
    # per-scenario ``prescreen_stats`` and a lint summary of Puzzle's chosen
    # schedule in the results.
    prescreen: bool = False

    def to_json(self) -> Dict[str, object]:
        return asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "SweepConfig":
        return cls(**d)


class EvalContext:
    """Shared immutable problem context: graphs, processors, profiler, comm.

    Built once per process (per sweep worker) and reused across scenarios —
    the profiler's ProfileDB cache and the cost-graph zoo then amortize
    across every scenario the worker evaluates. Sharing is safe because the
    profiler is deterministic per profile key: cache state affects speed,
    never values.
    """

    def __init__(self) -> None:
        self.graphs = all_cost_graphs()
        self.processors = mobile_processors()
        self.profiler = Profiler(TableBackend(
            processors=self.processors,
            tables=paper_profile_tables(),
            fallback=AnalyticMobileBackend(self.processors),
        ))
        self.comm_model = PAPER_COMM_MODEL


_DEFAULT_CONTEXT: Optional[EvalContext] = None


def default_context() -> EvalContext:
    """Process-wide singleton :class:`EvalContext` (lazy)."""
    global _DEFAULT_CONTEXT
    if _DEFAULT_CONTEXT is None:
        _DEFAULT_CONTEXT = EvalContext()
    return _DEFAULT_CONTEXT


@dataclass
class ScenarioResult:
    """Everything the sweep records for one scenario.

    ``alpha_star`` maps method → saturation multiplier under the paper's
    §6.2 convention (the **median** over the method's candidate set: GA
    Pareto front, Best Mapping archive, or the single NPU Only solution);
    ``alpha_star_best`` is the **minimum** over the same set — what the
    method achieves if the deployer picks its single best schedule. Both may
    be ``inf`` when the score never saturates up to the search ceiling
    (serialized as JSON ``null``). ``ratios`` maps baseline →
    ``α*_baseline / α*_puzzle`` (median convention) with
    both sides capped at ``alpha_cap`` first — the per-scenario frequency
    gain (higher = Puzzle sustains proportionally shorter periods).
    ``satisfaction`` maps method → pooled fraction of requests meeting their
    deadline at ``satisfaction_alpha``. ``base_periods_s`` is φ̄ per group in
    seconds. ``wall_s`` is the scenario's evaluation wall-clock in seconds.
    """

    spec: ScenarioSpec
    base_periods_s: List[float]
    alpha_star: Dict[str, float]
    alpha_star_best: Dict[str, float]
    ratios: Dict[str, float]
    satisfaction: Dict[str, float]
    ga_generations: int
    ga_evaluations: int
    pareto_size: int
    wall_s: float
    # scalar summary of the runtime↔simulator conformance check (only when
    # SweepConfig.validate_runtime; see ConformanceReport.summary())
    runtime_conformance: Optional[Dict[str, object]] = None
    # GA pre-screen counters {checked, pruned, simulations_avoided} and the
    # lint summary of Puzzle's chosen schedule (only when
    # SweepConfig.prescreen; see repro.analysis)
    prescreen_stats: Optional[Dict[str, int]] = None
    lint: Optional[Dict[str, object]] = None

    def __post_init__(self) -> None:
        # NaN has no JSON representation and poisons every downstream
        # aggregate (min/percentile/geomean all propagate it silently), so
        # reject it at construction instead of serializing garbage.
        nan_fields = [
            f"{name}[{k}]"
            for name, mapping in (("alpha_star", self.alpha_star),
                                  ("alpha_star_best", self.alpha_star_best),
                                  ("ratios", self.ratios),
                                  ("satisfaction", self.satisfaction))
            for k, v in mapping.items() if math.isnan(v)
        ] + [f"base_periods_s[{i}]" for i, v in enumerate(self.base_periods_s)
             if math.isnan(v)]
        if nan_fields:
            raise ValueError(
                f"NaN in ScenarioResult({self.spec.name}): "
                + ", ".join(nan_fields))

    def to_json(self) -> Dict[str, object]:
        return {
            "spec": self.spec.to_json(),
            "base_periods_s": list(self.base_periods_s),
            "alpha_star": {
                k: (None if math.isinf(v) else v)
                for k, v in self.alpha_star.items()
            },
            "alpha_star_best": {
                k: (None if math.isinf(v) else v)
                for k, v in self.alpha_star_best.items()
            },
            "ratios": dict(self.ratios),
            "satisfaction": dict(self.satisfaction),
            "ga_generations": self.ga_generations,
            "ga_evaluations": self.ga_evaluations,
            "pareto_size": self.pareto_size,
            "wall_s": self.wall_s,
            **({"runtime_conformance": dict(self.runtime_conformance)}
               if self.runtime_conformance is not None else {}),
            **({"prescreen_stats": dict(self.prescreen_stats)}
               if self.prescreen_stats is not None else {}),
            **({"lint": dict(self.lint)} if self.lint is not None else {}),
        }

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "ScenarioResult":
        return cls(
            spec=ScenarioSpec.from_json(d["spec"]),
            base_periods_s=[float(x) for x in d["base_periods_s"]],
            alpha_star={
                k: (float("inf") if v is None else float(v))
                for k, v in d["alpha_star"].items()
            },
            alpha_star_best={
                k: (float("inf") if v is None else float(v))
                for k, v in d["alpha_star_best"].items()
            },
            ratios={k: float(v) for k, v in d["ratios"].items()},
            satisfaction={k: float(v) for k, v in d["satisfaction"].items()},
            ga_generations=int(d["ga_generations"]),
            ga_evaluations=int(d["ga_evaluations"]),
            pareto_size=int(d["pareto_size"]),
            wall_s=float(d["wall_s"]),
            runtime_conformance=d.get("runtime_conformance"),
            prescreen_stats=d.get("prescreen_stats"),
            lint=d.get("lint"),
        )


def capped_ratio(baseline: float, puzzle: float, cap: float) -> float:
    """``min(baseline, cap) / min(puzzle, cap)``, the per-scenario frequency
    gain; 1.0 when both sides are unsaturated (both capped)."""
    return min(baseline, cap) / min(puzzle, cap)


def evaluate_scenario(
    spec: ScenarioSpec,
    config: Optional[SweepConfig] = None,
    context: Optional[EvalContext] = None,
) -> ScenarioResult:
    """Run the full per-scenario pipeline; see the module docstring.

    Puzzle's α* is the **median** over its Pareto front (paper §6.2); the
    baselines' α* are the median over the Best Mapping archive and the
    single NPU Only solution respectively. Satisfaction rates are measured
    on each method's best (lowest-α*) solution under the measured (noisy)
    simulator, with the noise stream seeded from ``spec.seed``.
    """
    config = config or SweepConfig()
    context = context or default_context()
    t0 = time.perf_counter()

    # a spec carrying faults threads its ensemble into every evaluation
    # path below (GA, α*-search, satisfaction) via the analyzer — the
    # robustness objective: the GA optimizes under the faulted simulator
    scenario = build_scenario(spec.name, [list(g) for g in spec.groups],
                              context.graphs, arrival=spec.arrival,
                              faults=spec.faults)
    analyzer = StaticAnalyzer(
        scenario, context.processors, context.profiler, context.comm_model,
        AnalyzerConfig(
            engine=config.engine,
            saturation_mode=config.saturation_mode,
            batch_workers=config.batch_workers,
            batch_engine=config.batch_engine,
            prescreen=config.prescreen,
            ga=GAConfig(
                pop_size=config.pop_size,
                max_generations=config.max_generations,
                min_generations=config.min_generations,
                seed=spec.seed,
                prescreen=config.prescreen,
            ),
        ),
    )

    try:
        return _evaluate_with(analyzer, scenario, spec, config, context, t0)
    finally:
        analyzer.close()  # batch process pool, if one was spun up


def _evaluate_with(
    analyzer: StaticAnalyzer,
    scenario,
    spec: ScenarioSpec,
    config: SweepConfig,
    context: EvalContext,
    t0: float,
) -> ScenarioResult:
    # The Best Mapping archive doubles as GA seed material (Puzzle's search
    # space strictly contains the mapping-only space), so run the hillclimb
    # once and share it between the baseline and the GA's seed population.
    bm_solutions = analyzer.best_mapping(
        max_evals=config.bm_max_evals, seed=spec.seed)
    ga_seeds = [analyzer.factory.seeded_solution(p.pid)
                for p in context.processors]
    ga = analyzer.run_ga(seeds=ga_seeds + bm_solutions)
    candidates: Dict[str, List[Solution]] = {
        "puzzle": list(ga.pareto),
        "best_mapping": bm_solutions,
        "npu_only": [analyzer.npu_only()],
    }

    alpha_star: Dict[str, float] = {}
    alpha_star_best: Dict[str, float] = {}
    best_solution: Dict[str, Solution] = {}
    if config.use_batch:
        # one batched bisection over the whole candidate population (all
        # methods at once): every round's α probes run as one lock-step pass
        flat = [(m, s) for m in METHODS for s in candidates[m]]
        sat_results = analyzer.population_saturation([s for _, s in flat])
        per_method: Dict[str, List[float]] = {m: [] for m in METHODS}
        for (method, _), sat in zip(flat, sat_results):
            per_method[method].append(sat.alpha_star)
        for method, sats in per_method.items():
            alpha_star[method] = percentile(sats, 50.0)
            alpha_star_best[method] = min(sats)
            best_solution[method] = candidates[method][sats.index(min(sats))]
    else:
        for method, sols in candidates.items():
            sats = [analyzer.saturation(s).alpha_star for s in sols]
            alpha_star[method] = percentile(sats, 50.0)
            alpha_star_best[method] = min(sats)
            best_solution[method] = sols[sats.index(min(sats))]

    satisfaction: Dict[str, float] = {}
    deadlines = [config.satisfaction_alpha * p for p in analyzer.base_periods]
    methods_order = list(best_solution)
    if config.use_batch:
        batch = analyzer.simulate_batch(
            [(best_solution[m], config.satisfaction_alpha)
             for m in methods_order],
            config.satisfaction_requests, measured=True, seed=spec.seed,
        )
        for ix, method in enumerate(methods_order):
            per_group = [batch.makespans(ix, g)
                         for g in range(scenario.num_groups)]
            satisfaction[method] = deadline_satisfaction(per_group, deadlines)
    else:
        for method, sol in best_solution.items():
            res = analyzer.simulate(
                sol, config.satisfaction_alpha, config.satisfaction_requests,
                measured=True, seed=spec.seed, collect_tasks=False,
            )
            per_group = [[] for _ in range(scenario.num_groups)]
            for r in res.requests:
                per_group[r.group].append(r.makespan)
            satisfaction[method] = deadline_satisfaction(per_group, deadlines)

    ratios = {
        m: capped_ratio(alpha_star[m], alpha_star["puzzle"], config.alpha_cap)
        for m in ("npu_only", "best_mapping")
    }

    conformance = None
    if config.validate_runtime:
        # execute Puzzle's chosen schedule on the virtual-clock runtime under
        # the same measured conditions as the satisfaction check; the diff
        # against the simulator must be exact (report.passed)
        report = analyzer.validate_on_runtime(
            best_solution["puzzle"], alpha=config.satisfaction_alpha,
            num_requests=config.satisfaction_requests, measured=True,
            seed=spec.seed,
        )
        conformance = report.summary()

    prescreen_stats = None
    lint_summary = None
    if config.prescreen:
        prescreen_stats = dict(ga.prescreen_stats)
        # lint the deployed schedule at the satisfaction α: findings and the
        # proven α lower bound land next to the α* it constrains from below
        report = analyzer.lint(best_solution["puzzle"],
                               alpha=config.satisfaction_alpha)
        lint_summary = {
            "counts": report.counts(),
            "errors": len(report.errors()),
            "warnings": len(report.warnings()),
            "infeasible": report.infeasible,
            "alpha_lower_bound": report.alpha_lower_bound,
        }

    return ScenarioResult(
        spec=spec,
        base_periods_s=list(analyzer.base_periods),
        alpha_star=alpha_star,
        alpha_star_best=alpha_star_best,
        ratios=ratios,
        satisfaction=satisfaction,
        ga_generations=ga.generations,
        ga_evaluations=ga.evaluations,
        pareto_size=len(ga.pareto),
        wall_s=time.perf_counter() - t0,
        runtime_conformance=conformance,
        prescreen_stats=prescreen_stats,
        lint=lint_summary,
    )
