"""repro: Puzzle (GA-based multi-model scheduling) reproduced at framework
scale in JAX, plus the assigned-architecture serving/training stack.

Subpackages:
    core      — the paper's contribution (Static Analyzer, GA, simulator)
    zoo       — the paper's nine mobile networks + measured profiles
    models    — dense/MoE/SSM/hybrid/enc-dec/VLM JAX stacks
    kernels   — Pallas TPU kernels + jnp oracles
    sharding  — logical-axis sharding rules
    launch    — production meshes, steps, dry-run, roofline
    train     — optimizers, data, checkpointing, training loop
    runtime   — threaded Coordinator/Worker/Engine serving runtime
    configs   — the ten assigned architectures
"""
__version__ = "1.0.0"
