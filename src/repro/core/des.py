"""A small deterministic discrete-event simulation engine (SimPy stand-in).

The paper's Static Analyzer uses SimPy to replay runtime behaviour cheaply
(§4.3). SimPy is not installed in this offline environment, so this module
implements the subset we need with matching semantics:

* :class:`Environment` — binary-heap event loop with ``now``/``run``;
* :class:`Process` — generator coroutines that ``yield`` events;
* :meth:`Environment.timeout` — delay events;
* :class:`PriorityStore` — a put/get queue delivering lowest-priority-key
  items first (workers pull tasks from these).

Determinism: ties in time are broken by a monotonically increasing sequence
number, so a given seed always produces the same trace.

This engine backs the *reference* simulator (:class:`RuntimeSimulator`).
The GA search hot path uses :mod:`repro.core.fastsim`, an array-based event
loop with identical semantics but no Event/Process object churn; the two are
kept in lock-step by the parity tests in ``tests/test_fastsim.py``.
"""
from __future__ import annotations

import heapq
from typing import Any, Callable, Generator, List, Optional, Tuple


class Event:
    """A one-shot event; callbacks fire when it triggers."""

    __slots__ = ("env", "callbacks", "triggered", "value")

    def __init__(self, env: "Environment"):
        self.env = env
        self.callbacks: List[Callable[["Event"], None]] = []
        self.triggered = False
        self.value: Any = None

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self.value = value
        self.env._schedule(self, 0.0)
        return self


class Timeout(Event):
    __slots__ = ()

    def __init__(self, env: "Environment", delay: float, value: Any = None):
        super().__init__(env)
        if delay < 0:
            raise ValueError("negative delay")
        self.triggered = True
        self.value = value
        env._schedule(self, delay)


class Process(Event):
    """Drives a generator; the process event triggers when the generator ends."""

    __slots__ = ("_gen",)

    def __init__(self, env: "Environment", gen: Generator[Event, Any, Any]):
        super().__init__(env)
        self._gen = gen
        init = Event(env)
        init.succeed()
        init.callbacks.append(self._resume)

    def _resume(self, trigger: Event) -> None:
        try:
            target = self._gen.send(trigger.value)
        except StopIteration as stop:
            if not self.triggered:
                self.succeed(getattr(stop, "value", None))
            return
        if not isinstance(target, Event):
            raise TypeError(f"process yielded non-event {target!r}")
        target.callbacks.append(self._resume)


class Environment:
    """Event loop. Times are floats (seconds in our simulators)."""

    def __init__(self) -> None:
        self.now: float = 0.0
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = 0

    def _schedule(self, event: Event, delay: float) -> None:
        heapq.heappush(self._heap, (self.now + delay, self._seq, event))
        self._seq += 1

    def timeout(self, delay: float, value: Any = None) -> Timeout:
        return Timeout(self, delay, value)

    def event(self) -> Event:
        return Event(self)

    def process(self, gen: Generator[Event, Any, Any]) -> Process:
        return Process(self, gen)

    def run(self, until: Optional[float] = None) -> None:
        while self._heap:
            t, _, ev = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = t
            # fire callbacks registered at pop time; callbacks appended while
            # firing belong to future triggers of other events.
            callbacks, ev.callbacks = ev.callbacks, []
            for cb in callbacks:
                cb(ev)
        if until is not None:
            self.now = max(self.now, until)


class PriorityStore:
    """FIFO-within-priority item store with blocking ``get``."""

    def __init__(self, env: Environment):
        self.env = env
        self._items: List[Tuple[Any, int, Any]] = []  # (prio_key, seq, item)
        self._seq = 0
        self._getters: List[Event] = []

    def put(self, item: Any, priority: Any = 0) -> None:
        heapq.heappush(self._items, (priority, self._seq, item))
        self._seq += 1
        if self._getters:
            getter = self._getters.pop(0)
            _, _, it = heapq.heappop(self._items)
            getter.succeed(it)

    def get(self) -> Event:
        ev = Event(self.env)
        if self._items:
            _, _, it = heapq.heappop(self._items)
            ev.succeed(it)
        else:
            self._getters.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self._items)
