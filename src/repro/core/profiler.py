"""Device-in-the-loop profiling with a Merkle-keyed database (paper §4.3).

The Profiler answers "how long does this *subgraph* take on this processor
with this (dtype, backend) configuration" — never by summing per-layer
times (§2.1.2 non-linearity). Results are cached in a :class:`ProfileDB`
keyed by the subgraph's Merkle hash mixed with the execution configuration,
so repeated GA evaluations across generations reuse measurements.

Backends:

* :class:`AnalyticMobileBackend` — calibrated cost model for the paper's
  Galaxy S23U processors (Tables 2–4 magnitudes). Captures non-linearity:
  fragmenting a graph loses fusion/parallelism (``fragmentation_ratio``).
* :class:`TableBackend` — reads the paper's measured model-level times
  (zoo/profiles.py) and distributes them over subgraphs MAC-proportionally
  with the fragmentation penalty; the most paper-faithful option.
* :class:`JaxExecBackend` — genuinely executes the subgraph (jit-compiled
  JAX on this host's CPU device) and measures wall time: literal
  device-in-the-loop for the executable zoo models.
* :class:`LaneRooflineBackend` — TPU-lane serving adaptation: roofline time
  from FLOPs/bytes vs lane capacity.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Protocol, Sequence, Tuple

from .chromosome import PlacedSubgraph
from .graph import Subgraph
from .processors import Processor


class ProfileDB:
    """Merkle-hash keyed measurement store with optional JSON persistence."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._data: Dict[str, float] = {}
        self.hits = 0
        self.misses = 0
        self.measured_updates = 0
        if path and os.path.exists(path):
            with open(path) as f:
                self._data = json.load(f)

    def get(self, key: str) -> Optional[float]:
        v = self._data.get(key)
        if v is not None:
            self.hits += 1
        return v

    def put(self, key: str, value: float) -> None:
        self.misses += 1
        self._data[key] = value

    def update(self, key: str, value: float) -> bool:
        """Overwrite a profile entry with a *measured* value (the
        device-in-the-loop feedback path); returns True when the stored
        value actually changed. Callers that depend on cached derivations
        of this entry (spec/objective caches) must invalidate them —
        ``StaticAnalyzer.apply_measured_costs`` does both."""
        old = self._data.get(key)
        self._data[key] = value
        changed = old is None or old != value
        if changed:
            self.measured_updates += 1
        return changed

    def save(self) -> None:
        if self.path:
            with open(self.path, "w") as f:
                json.dump(self._data, f)

    def __len__(self) -> int:
        return len(self._data)


class ProfilerBackend(Protocol):
    def measure(self, placed: PlacedSubgraph) -> float: ...


def fragmentation_penalty(proc: Processor, sg: Subgraph) -> float:
    """Per-MAC slowdown of a fragment vs the fully fused graph.

    Interpolates geometrically between 1.0 (whole graph as one subgraph) and
    ``proc.fragmentation_ratio`` (single-layer subgraph), mirroring the
    Σ(layers)/measured ratios of Table 4.
    """
    total = sg.graph.num_layers
    k = len(sg.layer_ids)
    if total <= 1 or k >= total:
        return 1.0
    frac = (total - k) / (total - 1)  # 0 = whole graph, 1 = single layer
    return proc.fragmentation_ratio ** frac


@dataclass
class AnalyticMobileBackend:
    """Closed-form mobile cost model calibrated against the paper's tables."""

    processors: Sequence[Processor]

    def measure(self, placed: PlacedSubgraph) -> float:
        proc = self.processors[placed.processor]
        thr = proc.thr(placed.dtype, placed.backend)
        penalty = 1.0
        if thr is None:
            # Unsupported config: fall back to the slowest supported one
            # with a large penalty (the NNAPI rows of Table 2).
            supported = [v for _, v in proc.throughput]
            thr = min(supported) if supported else 1e9
            penalty = proc.fallback_penalty
        sg = placed.subgraph
        compute = sg.macs / thr * fragmentation_penalty(proc, sg) * penalty
        # memory-bound floor: streaming weights once
        mem = sg.param_bytes / 40e9
        return proc.invocation_overhead + proc.layer_overhead * len(sg.layer_ids) + max(
            compute, mem
        )


@dataclass
class TableBackend:
    """Distributes the paper's measured model-level times over subgraphs.

    ``tables[model_name][(proc_kind, dtype, backend)] = seconds`` for the
    whole model; a subgraph gets its MAC-share with the fragmentation
    penalty, plus the processor invocation overhead. Missing configurations
    fall back to the analytic backend.
    """

    processors: Sequence[Processor]
    tables: Dict[str, Dict[Tuple[str, str, str], float]]
    fallback: Optional[ProfilerBackend] = None

    def measure(self, placed: PlacedSubgraph) -> float:
        proc = self.processors[placed.processor]
        sg = placed.subgraph
        table = self.tables.get(sg.graph.name, {})
        t_model = table.get((proc.kind, placed.dtype, placed.backend))
        if t_model is None:
            if self.fallback is None:
                raise KeyError(
                    f"no profile for {sg.graph.name} on {proc.kind}/{placed.dtype}/{placed.backend}"
                )
            return self.fallback.measure(placed)
        share = sg.macs / max(sg.graph.total_macs, 1.0)
        return (
            proc.invocation_overhead
            + t_model * share * fragmentation_penalty(proc, sg)
        )


@dataclass
class JaxExecBackend:
    """Executes the subgraph for real (jit on the host CPU) and times it.

    ``executables[model_name]`` must provide ``build_subgraph_fn(layer_ids,
    dtype) -> (fn, example_inputs)``; the zoo models implement this. Each
    measurement compiles once, then takes the median of ``repeats`` timed
    runs — the paper's brief on-device execution.
    """

    executables: Dict[str, Any]
    repeats: int = 5
    # hardware heterogeneity emulation on a single-CPU host: relative speed
    # multipliers per processor id (documented in DESIGN.md §2).
    speed_scale: Optional[Dict[int, float]] = None

    def measure(self, placed: PlacedSubgraph) -> float:
        model = self.executables[placed.subgraph.graph.name]
        fn, args = model.build_subgraph_fn(placed.subgraph.layer_ids, placed.dtype)
        import jax

        jfn = jax.jit(fn)
        out = jfn(*args)
        jax.block_until_ready(out)
        times = []
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            out = jfn(*args)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
        t = sorted(times)[len(times) // 2]
        if self.speed_scale:
            t *= self.speed_scale.get(placed.processor, 1.0)
        return t


@dataclass
class LaneRooflineBackend:
    """TPU-lane serving cost: max(compute, memory) roofline + overheads.

    Efficiency falls with lane size for small subgraphs (the per-chip work
    shrinks below the MXU-utilization knee), which is exactly why the
    biggest lane is not optimal for every model — the paper's Table 3
    observation transplanted to TPU.
    """

    lanes: Sequence[Processor]
    dtype_bytes: Tuple[Tuple[str, float], ...] = (("fp32", 4.0), ("fp16", 2.0), ("int8", 1.0))
    min_work_per_chip: float = 2e8  # FLOPs per chip below which efficiency decays

    def measure(self, placed: PlacedSubgraph) -> float:
        lane = self.lanes[placed.processor]
        sg = placed.subgraph
        flops = 2.0 * sg.macs
        dbytes = dict(self.dtype_bytes)[placed.dtype]
        weight_bytes = sg.param_bytes * (dbytes / 4.0)
        # efficiency: perfect when each chip has >= min_work, else linear decay
        per_chip = flops / max(lane.chips, 1)
        eff = min(1.0, per_chip / self.min_work_per_chip) * 0.55 + 0.05
        speed = {"fp16": 1.0, "fp32": 0.5, "int8": 2.0}[placed.dtype]
        t_compute = flops / (lane.peak_flops * eff * speed)
        t_memory = weight_bytes / lane.hbm_bw
        return lane.invocation_overhead + max(t_compute, t_memory)


class Profiler:
    """Front end: Merkle-cache + backend dispatch (Fig. 4 'Profiler')."""

    def __init__(self, backend: ProfilerBackend, db: Optional[ProfileDB] = None):
        self.backend = backend
        # NB: `db or ProfileDB()` would discard an *empty* ProfileDB
        # (len == 0 is falsy) — compare to None explicitly.
        self.db = db if db is not None else ProfileDB()

    def subgraph_time(self, placed: PlacedSubgraph) -> float:
        key = placed.profile_key()
        cached = self.db.get(key)
        if cached is not None:
            return cached
        t = self.backend.measure(placed)
        self.db.put(key, t)
        return t

    def model_time(self, placed_list: Sequence[PlacedSubgraph]) -> float:
        return sum(self.subgraph_time(p) for p in placed_list)
