"""Static Analyzer: Optimizer + Simulator + Runtime Evaluator (paper §4, Fig. 4).

Ties the chromosome factory, the device-in-the-loop profiler, the comm cost
model and the discrete-event simulator into the GA search, and provides the
evaluation entry points used by the experiments:

* ``objectives(solution, alpha)`` — the GA fitness: per model group
  (average makespan, 90th-percentile makespan), flattened; minimized.
* ``score(solution, alpha)`` — XRBench scenario score at a period
  multiplier.
* ``saturation(solution)`` — α* sweep for the headline metric.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

if TYPE_CHECKING:  # typing-only: core must not import these at runtime
    from ..analysis import LintReport, ScheduleLinter
    from ..runtime.conformance import ConformanceReport
    from .batchsim import BatchResult

from .arrivals import ArrivalSpec
from .baselines import best_mapping_solutions, npu_only_solution
from .batchsim import BatchLane, batch_objectives, run_batch
from .chromosome import Solution, SolutionFactory, decode_solution
from .comm import PiecewiseLinearCommModel
from .fastsim import FastSimSpec, FastSimulator, SpecBuilder
from .faults import FaultSpec
from .ga import GAConfig, GAResult, GeneticScheduler
from .processors import Processor
from .profiler import Profiler
from .scenarios import Scenario, base_periods, best_model_times
from .scoring import (
    ALPHA_GRID,
    SaturationResult,
    bisect_alpha_probes,
    deadline_satisfaction,
    percentile,
    saturation_multiplier,
    saturation_multiplier_bisect,
    scenario_score,
)
from .simulator import NoiseModel, RuntimeSimulator, SimResult

#: Per-axis fitness assigned to chromosomes the static analyzer proves
#: infeasible: strictly above the simulator's 1e6 dropped-request cap, so a
#: pruned chromosome is dominated by (or ties) every simulated one and can
#: never displace a feasible solution from the front.
PRESCREEN_OBJECTIVE = 2.0e6


@dataclass
class AnalyzerConfig:
    search_alpha: float = 1.0       # period multiplier used during search (§6.3)
    fast_requests: int = 12         # simulator requests for local-search evals
    accurate_requests: int = 36     # "brief on-target execution" equivalent
    input_home_pid: int = 0
    # "Measurement" fidelity: the fast simulator is clean (like the paper's
    # SimPy model); accurate evaluation and final scoring inject the
    # on-device effects of §6.3 — execution-time fluctuation and Coordinator
    # dispatch load on the CPU.
    noise: NoiseModel = field(default_factory=NoiseModel)
    dispatch_overhead: float = 150e-6
    dispatch_pid: int = 0
    ga: GAConfig = field(default_factory=GAConfig)
    # Evaluation engine: "fast" runs the array-based FastSimulator with a
    # per-solution decode/cost cache; "reference" re-decodes and replays the
    # generator-coroutine RuntimeSimulator (the oracle fastsim is verified
    # against). Both produce bit-identical results.
    engine: str = "fast"
    decode_cache_size: int = 2048
    # α*-search: "bisect" brackets-then-bisects the near-monotone score curve
    # (~15 score() calls); "grid" is the paper-faithful 117-point linear scan.
    saturation_mode: str = "bisect"
    # Generation-batched evaluation (repro.core.batchsim): ``batch_workers``
    # shards batch lanes across a persistent process pool (1 = in-process
    # single lock-step pass). Results are bit-identical for any value. The
    # GA routes its generation evaluations through the batch path when
    # ``ga.batch_eval`` is set. Sharding only engages above
    # ``batchsim.SHARD_MIN_LANES`` lanes (measured crossover; below it the
    # in-process pass is faster — see BENCH_simspeed.json).
    batch_workers: int = 1
    # Lock-step batch backend: "numpy" (bit-exact, the parity tier) or
    # "compiled" (jitted jax.lax.while_loop core, documented float
    # tolerance, falls back to numpy when unsupported — see
    # repro.core.batchsim_compiled). Opt-in: the default stays "numpy"
    # because every batched entry point is contractually bit-identical to
    # its scalar counterpart (tests/test_ga_determinism.py,
    # tests/test_experiments.py); "compiled" trades that for throughput.
    batch_engine: str = "numpy"
    # Device-in-the-loop measurement rounds (used when the analyzer holds
    # executables and ga.device_in_loop_interval > 0): how many of the
    # front's candidates are executed for real per round, and with how many
    # requests per group — the paper's "brief on-target execution".
    device_in_loop_topk: int = 1
    device_in_loop_requests: int = 3
    # Static pre-screening (repro.analysis): when set, the α*-searches skip
    # lattice probes below the linter's proven infeasibility bound (answered
    # as score 0.0 without simulating — sound by the SL030 deadline proof),
    # and run_ga() hands the GA a prescreen callable (which additionally
    # requires GAConfig.prescreen to engage). Results are unchanged by
    # construction: only probes the score contract already determines are
    # skipped, and only proven-infeasible chromosomes are pruned.
    prescreen: bool = False


class StaticAnalyzer:
    def __init__(
        self,
        scenario: Scenario,
        processors: Sequence[Processor],
        profiler: Profiler,
        comm_model: PiecewiseLinearCommModel,
        config: Optional[AnalyzerConfig] = None,
        executables: Optional[Dict] = None,
    ):
        self.scenario = scenario
        self.processors = processors
        self.profiler = profiler
        self.comm = comm_model
        self.cfg = config or AnalyzerConfig()
        # real executables (zoo models) enable the device-in-the-loop paths:
        # real-exec conformance validation and measured-cost GA feedback
        self.executables = executables
        self.best_times = best_model_times(scenario.graphs, processors, profiler)
        self.base_periods = base_periods(scenario, self.best_times)
        # The scenario's request arrival process (None = periodic). Every
        # simulation path below threads it through, and its content key
        # participates in the objective cache key: two simulations of the
        # same spec under different arrival processes are different results.
        self.arrival: Optional[ArrivalSpec] = scenario.arrival
        self._arrival_key = (self.arrival.key()
                             if self.arrival is not None else None)
        # The scenario's fault ensemble (None = clean). Like the arrival
        # process it is threaded through every simulation path and joined
        # into the objective memo keys — a scenario with faults makes the
        # GA search fault-tolerant schedules (the robustness objective).
        faults = scenario.faults
        self.faults: Optional[FaultSpec] = (
            None if faults is None or faults.empty else faults)
        self._fault_key = (self.faults.key()
                           if self.faults is not None else None)
        self.factory = SolutionFactory(
            scenario.graphs, num_processors=len(processors),
            processors=processors,
        )
        # Decode + cost cache: a solution is decoded and cost-annotated once
        # (FastSimSpec) and then re-simulated across all α values, request
        # counts and noise seeds. LRU-bounded by cfg.decode_cache_size. The
        # SpecBuilder additionally shares partition and exec-cost memos
        # *across* solutions (GA populations overlap heavily).
        self._spec_cache: "OrderedDict[Tuple, FastSimSpec]" = OrderedDict()
        self._spec_builder = SpecBuilder(
            scenario.graphs, processors, profiler, comm_model,
            input_home_pid=self.cfg.input_home_pid,
        )
        self.spec_cache_hits = 0
        self.spec_cache_misses = 0
        # Objective memo keyed by spec *content* signature: chromosomes that
        # decode to the same placed configuration share evaluation results.
        self._objective_cache: "OrderedDict[Tuple, Tuple[float, ...]]" = OrderedDict()
        self.objective_cache_hits = 0
        self.objective_cache_misses = 0
        # invalid/absent samples skipped by the last apply_measured_costs
        self.measured_skips = 0
        self._batch_pool = None  # lazy ProcessPoolExecutor (batch_workers > 1)
        self._linter = None  # lazy ScheduleLinter (prescreen / lint paths)

    # -- batch plumbing ------------------------------------------------------
    def _pool(self) -> Optional[object]:
        if self.cfg.batch_workers > 1 and self._batch_pool is None:
            from concurrent.futures import ProcessPoolExecutor
            self._batch_pool = ProcessPoolExecutor(
                max_workers=self.cfg.batch_workers)
        return self._batch_pool

    def close(self) -> None:
        """Shut down the batch process pool (no-op when unused)."""
        if self._batch_pool is not None:
            self._batch_pool.shutdown()
            self._batch_pool = None

    def _lane(
        self,
        solution: Solution,
        alpha: float,
        num_requests: int,
        measured: bool,
        seed: int = 0,
    ) -> BatchLane:
        """One batch lane, mirroring :meth:`simulate`'s parameters."""
        return BatchLane(
            spec=self.solution_spec(solution),
            periods=[alpha * p for p in self.base_periods],
            num_requests=num_requests,
            noise=(NoiseModel(self.cfg.noise.sigma_by_kind, seed=seed)
                   if measured else None),
            dispatch_overhead=self.cfg.dispatch_overhead if measured else 0.0,
            dispatch_pid=self.cfg.dispatch_pid,
            arrivals=self.arrival,
            faults=self.faults,
        )

    # -- simulation ------------------------------------------------------------
    def solution_spec(self, solution: Solution) -> FastSimSpec:
        """Decoded + cost-annotated static structure for ``solution``, cached."""
        key = solution.key()
        spec = self._spec_cache.get(key)
        if spec is not None:
            self.spec_cache_hits += 1
            self._spec_cache.move_to_end(key)
            return spec
        self.spec_cache_misses += 1
        spec = self._spec_builder.build(solution)
        self._spec_cache[key] = spec
        if len(self._spec_cache) > self.cfg.decode_cache_size:
            self._spec_cache.popitem(last=False)
        return spec

    def simulate(
        self,
        solution: Solution,
        alpha: float,
        num_requests: int,
        measured: bool = False,
        seed: int = 0,
        engine: Optional[str] = None,
        collect_tasks: bool = True,
        faults: Optional[FaultSpec] = None,
    ) -> SimResult:
        """Simulate ``solution``; ``faults=None`` injects the scenario's own
        ensemble (pass an empty :class:`FaultSpec` to force a clean run)."""
        engine = engine or self.cfg.engine
        periods = [alpha * p for p in self.base_periods]
        noise = None
        if measured:
            noise = NoiseModel(self.cfg.noise.sigma_by_kind, seed=seed)
        dispatch_overhead = self.cfg.dispatch_overhead if measured else 0.0
        faults = faults if faults is not None else self.faults
        if engine == "fast":
            sim = FastSimulator(
                self.solution_spec(solution),
                groups=self.scenario.groups,
                periods=periods,
                num_requests=num_requests,
                noise=noise,
                dispatch_overhead=dispatch_overhead,
                dispatch_pid=self.cfg.dispatch_pid,
                arrivals=self.arrival,
                faults=faults,
            )
            return sim.run(collect_tasks=collect_tasks)
        placed = decode_solution(solution, self.scenario.graphs)
        ref = RuntimeSimulator(
            placed=placed,
            processors=self.processors,
            profiler=self.profiler,
            comm_model=self.comm,
            groups=self.scenario.groups,
            periods=periods,
            num_requests=num_requests,
            input_home_pid=self.cfg.input_home_pid,
            noise=noise,
            dispatch_overhead=dispatch_overhead,
            dispatch_pid=self.cfg.dispatch_pid,
            arrivals=self.arrival,
            faults=faults,
        )
        return ref.run()

    def objectives(
        self,
        solution: Solution,
        alpha: Optional[float] = None,
        num_requests: Optional[int] = None,
        measured: bool = False,
        engine: Optional[str] = None,
    ) -> Tuple[float, ...]:
        alpha = alpha if alpha is not None else self.cfg.search_alpha
        num_requests = num_requests or self.cfg.fast_requests
        engine = engine or self.cfg.engine
        key = None
        if engine == "fast":
            # the arrival/fault keys are constant per analyzer today, but
            # they MUST be part of the memo key: a cache shared or persisted
            # across arrival processes or fault ensembles would otherwise
            # serve one configuration's results for the other
            key = (self.solution_spec(solution).signature(), alpha,
                   num_requests, measured, self._arrival_key,
                   self._fault_key)
            hit = self._objective_cache.get(key)
            if hit is not None:
                self.objective_cache_hits += 1
                # LRU semantics: a hit must refresh recency (like the spec
                # cache above) or eviction degrades to insertion order and
                # the incumbent Pareto front — re-scored every generation —
                # is exactly what gets evicted once the cache fills.
                self._objective_cache.move_to_end(key)
                return hit
            self.objective_cache_misses += 1
        res = self.simulate(
            solution, alpha, num_requests, measured=measured, engine=engine,
            collect_tasks=False,
        )
        cap = 1e6  # finite stand-in for dropped requests so NSGA ordering works
        per_group: List[List[float]] = [[] for _ in range(self.scenario.num_groups)]
        for r in res.requests:
            per_group[r.group].append(min(r.makespan, cap))
        objs: List[float] = []
        for ms in per_group:
            objs.append(sum(ms) / len(ms))
            objs.append(percentile(ms, 90.0))
        out = tuple(objs)
        if key is not None:
            self._objective_cache[key] = out
            if len(self._objective_cache) > 4 * self.cfg.decode_cache_size:
                self._objective_cache.popitem(last=False)
        return out

    def objectives_batch(
        self,
        solutions: Sequence[Solution],
        alpha: Optional[float] = None,
        num_requests: Optional[int] = None,
        measured: bool = False,
        engine: Optional[str] = None,
    ) -> List[Tuple[float, ...]]:
        """GA objectives for a whole generation in one batched pass.

        Deduplicates against (and fills) the same signature-keyed objective
        cache as :meth:`objectives`, builds one padded struct-of-arrays
        batch for the misses and runs them through the lock-step
        :class:`~repro.core.batchsim.BatchSimulator` (sharded across
        ``cfg.batch_workers`` processes when configured). With the default
        ``engine="numpy"`` (or ``cfg.batch_engine``), per-solution results
        are bit-identical to calling :meth:`objectives` in a loop —
        enforced by the differential property suite. ``engine="compiled"``
        routes the misses through the jitted lock-step core instead
        (documented float tolerance, see ``repro.core.batchsim_compiled``).
        """
        alpha = alpha if alpha is not None else self.cfg.search_alpha
        num_requests = num_requests or self.cfg.fast_requests
        keys = [
            (self.solution_spec(s).signature(), alpha, num_requests, measured,
             self._arrival_key, self._fault_key)
            for s in solutions
        ]
        lane_of_key: Dict[Tuple, int] = {}
        lanes: List[BatchLane] = []
        for sol, key in zip(solutions, keys):
            if key in self._objective_cache:
                # count + refresh exactly like the scalar path's hit, so
                # batch-mode hit rates are honest and the LRU eviction
                # order stays identical to calling objectives() in a loop
                self.objective_cache_hits += 1
                self._objective_cache.move_to_end(key)
                continue
            if key in lane_of_key:
                # in-generation duplicate: the scalar loop's second call
                # would hit the cache, so report it as a hit here too
                self.objective_cache_hits += 1
                continue
            self.objective_cache_misses += 1
            lane_of_key[key] = len(lanes)
            lanes.append(self._lane(sol, alpha, num_requests, measured))
        fresh: List[Tuple[float, ...]] = []
        if lanes:
            result = run_batch(
                lanes, self.scenario.groups, self.processors,
                workers=self.cfg.batch_workers, pool=self._pool(),
                engine=engine or self.cfg.batch_engine,
            )
            fresh = batch_objectives(result)
            for key, lane_ix in lane_of_key.items():
                self._objective_cache[key] = fresh[lane_ix]
            while len(self._objective_cache) > 4 * self.cfg.decode_cache_size:
                self._objective_cache.popitem(last=False)
        out: List[Tuple[float, ...]] = []
        for sol, key in zip(solutions, keys):
            hit = self._objective_cache.get(key)
            if hit is not None:
                # recency refresh only (hits/misses were accounted in the
                # dedup pass): the final LRU order matches the scalar
                # loop's last-access order over ``solutions``
                self._objective_cache.move_to_end(key)
            else:
                # a generation larger than the cache bound evicted this key
                # before read-back: take the batch value directly when it
                # was computed this call, else the scalar path.
                ix = lane_of_key.get(key)
                hit = fresh[ix] if ix is not None else self.objectives(
                    sol, alpha=alpha, num_requests=num_requests,
                    measured=measured)
            out.append(hit)
        return out

    def score(
        self,
        solution: Solution,
        alpha: float,
        num_requests: Optional[int] = None,
        measured: bool = True,
        seed: int = 0,
    ) -> float:
        """XRBench score; by default under measured (noisy) conditions —
        saturation multipliers are an *on-device* metric in the paper."""
        num_requests = num_requests or self.cfg.accurate_requests
        res = self.simulate(
            solution, alpha, num_requests, measured=measured, seed=seed,
            collect_tasks=False,
        )
        per_group: List[List[float]] = [[] for _ in range(self.scenario.num_groups)]
        for r in res.requests:
            per_group[r.group].append(r.makespan)
        deadlines = [alpha * p for p in self.base_periods]
        return scenario_score(per_group, deadlines)

    def saturation(
        self,
        solution: Solution,
        alphas: Optional[Sequence[float]] = None,
        mode: Optional[str] = None,
    ) -> SaturationResult:
        def evaluate(a: float) -> float:
            return self.score(solution, a)

        if alphas is not None:
            return saturation_multiplier(evaluate, alphas)
        mode = mode or self.cfg.saturation_mode
        if mode == "grid":
            return saturation_multiplier(evaluate)
        return saturation_multiplier_bisect(
            evaluate, skip_below=self.alpha_floor(solution))

    # -- static pre-screen (repro.analysis) -----------------------------------
    def linter(self) -> "ScheduleLinter":
        """:class:`~repro.analysis.ScheduleLinter` sharing this analyzer's
        scenario context and SpecBuilder (lazy; import deferred so the core
        package never depends on repro.analysis at import time)."""
        if self._linter is None:
            from ..analysis import ScheduleLinter
            self._linter = ScheduleLinter.from_analyzer(self)
        return self._linter

    def lint(self, solution: Solution,
             alpha: Optional[float] = None) -> "LintReport":
        """Static :class:`~repro.analysis.LintReport` for ``solution``."""
        return self.linter().lint(solution, alpha=alpha)

    def alpha_floor(self, solution: Solution) -> float:
        """Proven-infeasible α bound for probe skipping (0.0 when the
        pre-screen is disabled or nothing can be proven)."""
        if not self.cfg.prescreen:
            return 0.0
        return self.linter().alpha_lower_bound(self.solution_spec(solution))

    def prescreen_objectives(
        self, solution: Solution
    ) -> Optional[Tuple[float, ...]]:
        """Sound GA pre-screen: worst-rank objectives when the static
        analyzer *proves* ``solution`` infeasible, else ``None`` (simulate).
        """
        report = self.linter().prescreen_report(solution)
        if report is None:
            return None
        return (PRESCREEN_OBJECTIVE,) * (2 * self.scenario.num_groups)

    def simulate_batch(
        self,
        pairs: Sequence[Tuple[Solution, float]],
        num_requests: int,
        measured: bool = False,
        seed: int = 0,
    ) -> "BatchResult":
        """Simulate many ``(solution, α)`` pairs in one lock-step batch.

        The returned :class:`~repro.core.batchsim.BatchResult` indexes lanes
        in ``pairs`` order; each lane is bit-identical to the corresponding
        :meth:`simulate` call (``collect_tasks=False``).
        """
        lanes = [
            self._lane(sol, alpha, num_requests, measured, seed=seed)
            for sol, alpha in pairs
        ]
        return run_batch(
            lanes, self.scenario.groups, self.processors,
            workers=self.cfg.batch_workers, pool=self._pool(),
            engine=self.cfg.batch_engine,
        )

    def score_batch(
        self,
        requests: Sequence[Tuple[Solution, float]],
        num_requests: Optional[int] = None,
        measured: bool = True,
        seed: int = 0,
    ) -> List[float]:
        """XRBench scores for many ``(solution, α)`` pairs in one batch.

        Identical per pair to :meth:`score` (same measured simulation, same
        python-float score arithmetic); duplicate ``(spec, α)`` pairs within
        the batch simulate once.
        """
        if not requests:
            return []
        num_requests = num_requests or self.cfg.accurate_requests
        lane_of_key: Dict[Tuple, int] = {}
        lanes: List[BatchLane] = []
        keys: List[Tuple] = []
        for sol, alpha in requests:
            key = (self.solution_spec(sol).signature(), alpha,
                   self._arrival_key, self._fault_key)
            keys.append(key)
            if key not in lane_of_key:
                lane_of_key[key] = len(lanes)
                lanes.append(self._lane(sol, alpha, num_requests,
                                        measured, seed=seed))
        result = run_batch(
            lanes, self.scenario.groups, self.processors,
            workers=self.cfg.batch_workers, pool=self._pool(),
            engine=self.cfg.batch_engine,
        )
        num_groups = self.scenario.num_groups
        lane_scores: List[float] = []
        for lane_ix, lane in enumerate(lanes):
            per_group = [
                result.makespans(lane_ix, g) for g in range(num_groups)
            ]
            # deadline = α·base period = the lane's periods, same floats as
            # score()'s `[alpha * p for p in self.base_periods]`
            lane_scores.append(scenario_score(per_group, list(lane.periods)))
        return [lane_scores[lane_of_key[k]] for k in keys]

    def population_saturation(
        self,
        solutions: Sequence[Solution],
        mode: Optional[str] = None,
    ) -> List[SaturationResult]:
        """α*-search for a whole candidate population, batched per round.

        Drives one :func:`bisect_alpha_probes` state machine per solution in
        lock-step rounds: every round gathers each unfinished solution's
        next lattice probe, evaluates all of them as a single measured
        batch (deduplicated, sharded when configured) and feeds the scores
        back. The probe sequence per solution is exactly the scalar
        bisection's, so results equal ``[self.saturation(s) for s in
        solutions]`` bit for bit; only the wall-clock differs. ``mode``
        "grid" batches the 117-point scan per round instead.
        """
        if not solutions:
            return []
        mode = mode or self.cfg.saturation_mode
        if mode == "grid":
            alphas = ALPHA_GRID
            scores = self.score_batch(
                [(s, a) for s in solutions for a in alphas])
            out: List[SaturationResult] = []
            for ix in range(len(solutions)):
                chunk = dict(zip(
                    alphas, scores[ix * len(alphas):(ix + 1) * len(alphas)]))
                out.append(saturation_multiplier(lambda a: chunk[a]))
            return out
        # same per-solution probe skipping as the scalar path, so the batched
        # search stays bit-identical to [self.saturation(s) for s in ...]
        gens = [bisect_alpha_probes(skip_below=self.alpha_floor(s))
                for s in solutions]
        pending: Dict[int, float] = {}
        results: Dict[int, SaturationResult] = {}
        for ix, gen in enumerate(gens):
            try:
                pending[ix] = next(gen)
            except StopIteration as stop:  # pragma: no cover (never empty)
                results[ix] = stop.value
        while pending:
            order = sorted(pending)
            scores = self.score_batch(
                [(solutions[ix], pending[ix]) for ix in order])
            nxt: Dict[int, float] = {}
            for ix, sc in zip(order, scores):
                try:
                    nxt[ix] = gens[ix].send(sc)
                except StopIteration as stop:
                    results[ix] = stop.value
            pending = nxt
        return [results[ix] for ix in range(len(solutions))]

    # -- device-in-the-loop ---------------------------------------------------
    def validate_on_runtime(
        self,
        solution: Solution,
        alpha: float = 1.0,
        num_requests: Optional[int] = None,
        measured: bool = False,
        seed: int = 0,
        mode: str = "virtual",
        executables: Optional[Dict] = None,
        rel_tol: float = 0.35,
    ) -> "ConformanceReport":
        """Execute ``solution`` on :class:`~repro.runtime.PuzzleRuntime` and
        diff its task trace against the simulator's prediction.

        Returns a :class:`~repro.runtime.conformance.ConformanceReport`
        whose traces use the golden-trace schema (``tests/golden/``).

        ``mode="virtual"`` replays this analyzer's own cost spec on the
        runtime's virtual clock — the comparison is at **zero tolerance**
        (identical ordering and timestamps; ``measured`` adds the same
        noise stream and dispatch load to both sides). ``mode="real"``
        genuinely executes the models (``executables`` or the analyzer's
        own) under wall-clock timing and checks per-request makespans
        within ``rel_tol`` relative error.
        """
        from ..runtime import PuzzleRuntime  # lazy: runtime pulls in jax
        from ..runtime.conformance import (
            build_report, run_virtual_schedule, runtime_result,
        )

        num_requests = num_requests or self.cfg.fast_requests
        periods = [alpha * p for p in self.base_periods]
        sim = self.simulate(
            solution, alpha, num_requests, measured=measured, seed=seed,
            engine="fast", collect_tasks=True,
        )
        if mode == "virtual":
            noise = (NoiseModel(self.cfg.noise.sigma_by_kind, seed=seed)
                     if measured else None)
            rt_res = run_virtual_schedule(
                self.scenario.graphs, solution, self.processors,
                self.solution_spec(solution), self.scenario.groups, periods,
                num_requests, noise=noise,
                dispatch_overhead=(self.cfg.dispatch_overhead
                                   if measured else 0.0),
                dispatch_pid=self.cfg.dispatch_pid,
                arrivals=self.arrival,
                faults=self.faults,
            )
            return build_report("virtual", rt_res, sim, rel_tol=0.0)
        if mode != "real":
            raise ValueError(f"unknown conformance mode {mode!r}")
        executables = executables if executables is not None else self.executables
        if executables is None:
            raise ValueError("real-exec conformance needs executables")
        with PuzzleRuntime(self.scenario.graphs, solution, self.processors,
                           executables) as rt:
            states = rt.run_periodic(
                [list(g) for g in self.scenario.groups], periods,
                num_requests=num_requests, arrivals=self.arrival,
            )
            rt_res = runtime_result(rt, states, periods, num_requests,
                                    rebase=True, arrivals=self.arrival)
        return build_report("real", rt_res, sim, rel_tol=rel_tol)

    def measure_on_runtime(
        self,
        solution: Solution,
        executables: Optional[Dict] = None,
        num_requests: Optional[int] = None,
        alpha: float = 1.0,
    ) -> Dict[str, float]:
        """Brief on-target execution of ``solution``: run the schedule for
        real and return median measured exec time per Merkle profile key."""
        from ..runtime import PuzzleRuntime  # lazy: runtime pulls in jax

        executables = executables if executables is not None else self.executables
        if executables is None:
            raise ValueError("measure_on_runtime needs executables")
        num_requests = num_requests or self.cfg.device_in_loop_requests
        with PuzzleRuntime(self.scenario.graphs, solution, self.processors,
                           executables) as rt:
            rt.run_periodic(
                [list(g) for g in self.scenario.groups],
                [alpha * p for p in self.base_periods],
                num_requests=num_requests, arrivals=self.arrival,
            )
            return rt.measured_costs()

    def apply_measured_costs(
        self,
        measurements: Dict[str, float],
        rel_tol: float = 0.05,
    ) -> int:
        """Write measured per-subgraph timings into the ProfileDB and
        invalidate every evaluation cache derived from the affected keys.

        Measurements within ``rel_tol`` relative distance of the stored
        value are treated as statistically unchanged (wall-clock medians
        never repeat exactly) and skipped entirely, so repeated
        device-in-the-loop rounds on a stable device keep every cache warm
        instead of thrashing them on timing jitter. Returns the number of
        profile entries that actually changed; when non-zero, the
        SpecBuilder's exec memo drops exactly the affected keys (plus the
        derived per-network cost entries), and the analyzer's
        spec/objective caches are flushed — they key on solution
        identity/spec content, either of which may now map to different
        costs.

        A partial measurement set is fine: keys carrying no usable sample
        (``None``, non-finite or non-positive — a worker that died or a
        request dropped by an injected fault leaves such holes) are skipped
        rather than poisoning the ProfileDB; the count of skips is exposed
        as ``self.measured_skips`` for conformance reports.
        """
        changed: List[str] = []
        skipped = 0
        for key, t in measurements.items():
            if t is None or not math.isfinite(t) or t <= 0.0:
                skipped += 1
                continue
            old = self.profiler.db.get(key)
            if old is not None and old > 0 and abs(t - old) <= rel_tol * old:
                continue
            if self.profiler.db.update(key, t):
                changed.append(key)
        self.measured_skips = skipped
        if changed:
            self._spec_builder.invalidate(changed)
            self._spec_cache.clear()
            self._objective_cache.clear()
        return len(changed)

    # -- robustness -----------------------------------------------------------
    def score_under_faults(
        self,
        solution: Solution,
        faults: Optional[FaultSpec] = None,
        alpha: float = 1.0,
        num_requests: Optional[int] = None,
        measured: bool = True,
        seed: int = 0,
    ) -> Dict[str, float]:
        """Degradation report: clean vs faulted evaluation of ``solution``.

        Runs the same simulation twice — once clean, once under ``faults``
        (the scenario's own ensemble by default) — and reports deadline
        satisfaction, XRBench score and dropped-request counts for both,
        plus the deltas. This is the robustness objective surfaced to
        experiments and benchmarks; the GA optimizes it implicitly when the
        scenario carries a fault ensemble (every objective evaluation is
        then faulted).
        """
        from .faults import NO_FAULTS

        faults = faults if faults is not None else self.faults
        if faults is None:
            faults = NO_FAULTS
        num_requests = num_requests or self.cfg.accurate_requests
        deadlines = [alpha * p for p in self.base_periods]
        out: Dict[str, float] = {}
        for tag, spec in (("clean", NO_FAULTS), ("faulted", faults)):
            res = self.simulate(
                solution, alpha, num_requests, measured=measured, seed=seed,
                collect_tasks=False, faults=spec,
            )
            per_group: List[List[float]] = [
                [] for _ in range(self.scenario.num_groups)]
            dropped = 0
            for r in res.requests:
                per_group[r.group].append(r.makespan)
                if r.makespan == float("inf"):
                    dropped += 1
            out[f"satisfaction_{tag}"] = deadline_satisfaction(
                per_group, deadlines)
            out[f"score_{tag}"] = scenario_score(per_group, deadlines)
            out[f"dropped_{tag}"] = float(dropped)
        out["satisfaction_delta"] = (
            out["satisfaction_clean"] - out["satisfaction_faulted"])
        out["score_delta"] = out["score_clean"] - out["score_faulted"]
        return out

    def backup_mapping(
        self,
        solution: Solution,
        dead_pid: int,
    ) -> Tuple[Solution, Dict[Tuple[int, int], int]]:
        """Next-best placement excluding ``dead_pid``: the fallback remap.

        Keeps the solution's partition/priority/config and moves every
        subgraph placed on ``dead_pid`` to its *fastest surviving* processor
        (profiler exec time; ties break on pid — deterministic). Returns the
        backup solution plus the ``(net, k) -> new_pid`` remap the runtime
        applies at a permanent dropout (``PuzzleRuntime.set_backup``); the
        backup's :meth:`solution_spec` provides the post-remap cost arrays.
        """
        from dataclasses import replace as _replace

        survivors = [p for p in self.processors if p.pid != dead_pid]
        if not survivors:
            raise ValueError("no surviving processors for a backup mapping")
        placed = decode_solution(solution, self.scenario.graphs)
        backup = solution.copy()
        remap: Dict[Tuple[int, int], int] = {}
        for net, plist in enumerate(placed):
            for k, p in enumerate(plist):
                if p.processor != dead_pid:
                    continue
                best = min(
                    survivors,
                    key=lambda pr: (self.profiler.subgraph_time(
                        _replace(p, processor=pr.pid)), pr.pid),
                )
                remap[(net, k)] = best.pid
                for lid in p.subgraph.layer_ids:
                    backup.mapping[net][lid] = best.pid
        return backup, remap

    def rerank_pareto(
        self,
        solutions: Sequence[Solution],
        num_requests: Optional[int] = None,
    ) -> List[Solution]:
        """Re-evaluate candidates on current (e.g. freshly measured) costs
        and return the new first front, refreshing ``fitness`` in place."""
        from .nsga import fast_non_dominated_sort

        objs = [
            self.objectives(
                s, num_requests=num_requests or self.cfg.accurate_requests,
                measured=True,
            )
            for s in solutions
        ]
        for s, o in zip(solutions, objs):
            s.fitness = o
        front0 = fast_non_dominated_sort([list(o) for o in objs])[0]
        return [solutions[i] for i in front0]

    def _device_in_loop(self, solutions: Sequence[Solution]) -> int:
        """GA measurement round: execute the front's best candidates on the
        real runtime and feed the measured costs back. Returns the number of
        changed profile entries (the GA re-ranks when non-zero)."""
        ranked = sorted(
            solutions,
            key=lambda s: sum(s.fitness) if s.fitness else float("inf"),
        )
        changed = 0
        for sol in ranked[: max(1, self.cfg.device_in_loop_topk)]:
            changed += self.apply_measured_costs(self.measure_on_runtime(sol))
        return changed

    # -- search ------------------------------------------------------------
    def run_ga(self, seeds: Sequence[Solution] = ()) -> GAResult:
        scheduler = GeneticScheduler(
            factory=self.factory,
            evaluate_fast=lambda s: self.objectives(s, num_requests=self.cfg.fast_requests),
            evaluate_accurate=lambda s: self.objectives(
                s, num_requests=self.cfg.accurate_requests, measured=True
            ),
            # RuntimeSimulator stays available as the reference oracle: with
            # ga.oracle_interval > 0 the GA periodically re-evaluates its best
            # candidate through the reference DES and records any drift
            # (expected 0.0 — the engines are bit-identical).
            evaluate_oracle=lambda s: self.objectives(
                s, num_requests=self.cfg.fast_requests, engine="reference"
            ),
            # Whole-generation evaluation through the lock-step batch engine
            # (used when ga.batch_eval is set); bit-identical to the
            # per-child loop with the numpy backend. ga.batch_eval may also
            # name the backend ("compiled" = the jitted core, documented
            # float tolerance instead of bit-exactness).
            evaluate_batch=lambda sols, accurate: self.objectives_batch(
                sols,
                num_requests=(self.cfg.accurate_requests if accurate
                              else self.cfg.fast_requests),
                measured=accurate,
                engine=(self.cfg.ga.batch_eval
                        if isinstance(self.cfg.ga.batch_eval, str) else None),
            ),
            config=self.cfg.ga,
            # Sound static pre-screen: only engages when ga.prescreen is set
            # (the scheduler drops the callable otherwise).
            prescreen=self.prescreen_objectives,
            # Device-in-the-loop measurement rounds (only when this analyzer
            # holds real executables): brief on-target execution of the
            # front, ProfileDB write-back, cache invalidation, re-rank.
            measure_device=(
                self._device_in_loop
                if self.executables is not None
                and self.cfg.ga.device_in_loop_interval > 0
                else None
            ),
        )
        default_seeds = list(seeds)
        if not default_seeds:
            # heuristic seeds: everything on each processor, plus the Best
            # Mapping Pareto archive — Puzzle's search space strictly
            # contains the mapping-only space, so seeding with it makes the
            # containment explicit and focuses the GA budget on partition/
            # priority/config exploration.
            for proc in self.processors:
                default_seeds.append(self.factory.seeded_solution(proc.pid))
            default_seeds.extend(self.best_mapping(max_evals=120))
        return scheduler.run(seeds=default_seeds)

    # -- baselines ------------------------------------------------------------
    def npu_only(self) -> Solution:
        npu = max(
            self.processors,
            key=lambda p: (p.kind == "npu", p.chips, -min(
                self.best_times[m][p.pid][0] for m in range(len(self.scenario.graphs))
            )),
        )
        return npu_only_solution(self.scenario.graphs, npu.pid, self.best_times)

    def best_mapping(self, max_evals: int = 150, seed: int = 0) -> List[Solution]:
        return best_mapping_solutions(
            self.scenario.graphs,
            [p.pid for p in self.processors],
            self.best_times,
            evaluate=lambda s: self.objectives(s, num_requests=self.cfg.fast_requests),
            max_evals=max_evals,
            seed=seed,
        )

    # -- reporting ------------------------------------------------------------
    def median_saturation(self, solutions: Sequence[Solution]) -> float:
        """Median α* across multiple Pareto solutions (paper §6.2)."""
        vals = sorted(self.saturation(s).alpha_star for s in solutions)
        if not vals:
            return float("inf")
        mid = len(vals) // 2
        if len(vals) % 2:
            return vals[mid]
        return 0.5 * (vals[mid - 1] + vals[mid])
