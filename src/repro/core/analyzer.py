"""Static Analyzer: Optimizer + Simulator + Runtime Evaluator (paper §4, Fig. 4).

Ties the chromosome factory, the device-in-the-loop profiler, the comm cost
model and the discrete-event simulator into the GA search, and provides the
evaluation entry points used by the experiments:

* ``objectives(solution, alpha)`` — the GA fitness: per model group
  (average makespan, 90th-percentile makespan), flattened; minimized.
* ``score(solution, alpha)`` — XRBench scenario score at a period
  multiplier.
* ``saturation(solution)`` — α* sweep for the headline metric.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .baselines import best_mapping_solutions, npu_only_solution
from .chromosome import Solution, SolutionFactory, decode_solution
from .comm import PiecewiseLinearCommModel
from .ga import GAConfig, GAResult, GeneticScheduler
from .processors import Processor
from .profiler import Profiler
from .scenarios import Scenario, base_periods, best_model_times
from .scoring import SaturationResult, percentile, saturation_multiplier, scenario_score
from .simulator import NoiseModel, RuntimeSimulator, SimResult


@dataclass
class AnalyzerConfig:
    search_alpha: float = 1.0       # period multiplier used during search (§6.3)
    fast_requests: int = 12         # simulator requests for local-search evals
    accurate_requests: int = 36     # "brief on-target execution" equivalent
    input_home_pid: int = 0
    # "Measurement" fidelity: the fast simulator is clean (like the paper's
    # SimPy model); accurate evaluation and final scoring inject the
    # on-device effects of §6.3 — execution-time fluctuation and Coordinator
    # dispatch load on the CPU.
    noise: NoiseModel = field(default_factory=NoiseModel)
    dispatch_overhead: float = 150e-6
    dispatch_pid: int = 0
    ga: GAConfig = field(default_factory=GAConfig)


class StaticAnalyzer:
    def __init__(
        self,
        scenario: Scenario,
        processors: Sequence[Processor],
        profiler: Profiler,
        comm_model: PiecewiseLinearCommModel,
        config: Optional[AnalyzerConfig] = None,
    ):
        self.scenario = scenario
        self.processors = processors
        self.profiler = profiler
        self.comm = comm_model
        self.cfg = config or AnalyzerConfig()
        self.best_times = best_model_times(scenario.graphs, processors, profiler)
        self.base_periods = base_periods(scenario, self.best_times)
        self.factory = SolutionFactory(
            scenario.graphs, num_processors=len(processors),
        )

    # -- simulation ------------------------------------------------------------
    def simulate(
        self,
        solution: Solution,
        alpha: float,
        num_requests: int,
        measured: bool = False,
        seed: int = 0,
    ) -> SimResult:
        placed = decode_solution(solution, self.scenario.graphs)
        periods = [alpha * p for p in self.base_periods]
        noise = None
        if measured:
            noise = NoiseModel(self.cfg.noise.sigma_by_kind, seed=seed)
        sim = RuntimeSimulator(
            placed=placed,
            processors=self.processors,
            profiler=self.profiler,
            comm_model=self.comm,
            groups=self.scenario.groups,
            periods=periods,
            num_requests=num_requests,
            input_home_pid=self.cfg.input_home_pid,
            noise=noise,
            dispatch_overhead=self.cfg.dispatch_overhead if measured else 0.0,
            dispatch_pid=self.cfg.dispatch_pid,
        )
        return sim.run()

    def objectives(
        self,
        solution: Solution,
        alpha: Optional[float] = None,
        num_requests: Optional[int] = None,
        measured: bool = False,
    ) -> Tuple[float, ...]:
        alpha = alpha if alpha is not None else self.cfg.search_alpha
        num_requests = num_requests or self.cfg.fast_requests
        res = self.simulate(solution, alpha, num_requests, measured=measured)
        objs: List[float] = []
        cap = 1e6  # finite stand-in for dropped requests so NSGA ordering works
        for g in range(self.scenario.num_groups):
            ms = [min(m, cap) for m in res.makespans(g)]
            objs.append(sum(ms) / len(ms))
            objs.append(percentile(ms, 90.0))
        return tuple(objs)

    def score(
        self,
        solution: Solution,
        alpha: float,
        num_requests: Optional[int] = None,
        measured: bool = True,
        seed: int = 0,
    ) -> float:
        """XRBench score; by default under measured (noisy) conditions —
        saturation multipliers are an *on-device* metric in the paper."""
        num_requests = num_requests or self.cfg.accurate_requests
        res = self.simulate(solution, alpha, num_requests, measured=measured, seed=seed)
        per_group = [res.makespans(g) for g in range(self.scenario.num_groups)]
        deadlines = [alpha * p for p in self.base_periods]
        return scenario_score(per_group, deadlines)

    def saturation(self, solution: Solution, alphas: Optional[Sequence[float]] = None
                   ) -> SaturationResult:
        return saturation_multiplier(lambda a: self.score(solution, a), alphas)

    # -- search ------------------------------------------------------------
    def run_ga(self, seeds: Sequence[Solution] = ()) -> GAResult:
        scheduler = GeneticScheduler(
            factory=self.factory,
            evaluate_fast=lambda s: self.objectives(s, num_requests=self.cfg.fast_requests),
            evaluate_accurate=lambda s: self.objectives(
                s, num_requests=self.cfg.accurate_requests, measured=True
            ),
            config=self.cfg.ga,
        )
        default_seeds = list(seeds)
        if not default_seeds:
            # heuristic seeds: everything on each processor, plus the Best
            # Mapping Pareto archive — Puzzle's search space strictly
            # contains the mapping-only space, so seeding with it makes the
            # containment explicit and focuses the GA budget on partition/
            # priority/config exploration.
            for proc in self.processors:
                default_seeds.append(self.factory.seeded_solution(proc.pid))
            default_seeds.extend(self.best_mapping(max_evals=120))
        return scheduler.run(seeds=default_seeds)

    # -- baselines ------------------------------------------------------------
    def npu_only(self) -> Solution:
        npu = max(
            self.processors,
            key=lambda p: (p.kind == "npu", p.chips, -min(
                self.best_times[m][p.pid][0] for m in range(len(self.scenario.graphs))
            )),
        )
        return npu_only_solution(self.scenario.graphs, npu.pid, self.best_times)

    def best_mapping(self, max_evals: int = 150) -> List[Solution]:
        return best_mapping_solutions(
            self.scenario.graphs,
            [p.pid for p in self.processors],
            self.best_times,
            evaluate=lambda s: self.objectives(s, num_requests=self.cfg.fast_requests),
            max_evals=max_evals,
        )

    # -- reporting ------------------------------------------------------------
    def median_saturation(self, solutions: Sequence[Solution]) -> float:
        """Median α* across multiple Pareto solutions (paper §6.2)."""
        vals = sorted(self.saturation(s).alpha_star for s in solutions)
        if not vals:
            return float("inf")
        mid = len(vals) // 2
        if len(vals) % 2:
            return vals[mid]
        return 0.5 * (vals[mid - 1] + vals[mid])
