"""Chromosome encoding and genetic operators (paper §4.2, Fig. 6/7).

A :class:`Solution` bundles the three chromosome types:

* ``partition`` — per-network binary arrays over edges (1 = cut);
* ``mapping``  — per-network integer arrays over layers (preferred processor);
  the subgraph's processor is the majority vote of its layers;
* ``priority`` — a permutation over networks;

plus the per-network execution *configuration* genes (data type, backend
implementation) that extend the search space to ``M × T × BE`` (Table 1).

Operators follow the paper: one-point crossover for partition/mapping,
Uniform Partially-Matched Crossover (UPMX) for priority, bit/gene-flip
mutation for the rest.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import ModelGraph, Subgraph
from .processors import Processor

# Execution-configuration gene domains. These mirror ORT's (backend, dtype)
# choices on mobile; on the TPU adaptation they select the kernel
# implementation and compute dtype per subgraph.
DTYPES: Tuple[str, ...] = ("fp32", "fp16", "int8")
BACKENDS: Tuple[str, ...] = ("default", "xnnpack", "nnapi")


@dataclass
class Solution:
    """One GA individual: a complete scheduling decision for all networks."""

    partition: List[List[int]]          # per network: bit per edge
    mapping: List[List[int]]            # per network: processor id per layer
    priority: List[int]                 # permutation of network indices
    dtype: List[int]                    # per network: index into DTYPES
    backend: List[int]                  # per network: index into BACKENDS
    fitness: Optional[Tuple[float, ...]] = None  # lower is better for every axis

    def copy(self) -> "Solution":
        return Solution(
            partition=[list(p) for p in self.partition],
            mapping=[list(m) for m in self.mapping],
            priority=list(self.priority),
            dtype=list(self.dtype),
            backend=list(self.backend),
            fitness=self.fitness,
        )

    def key(self) -> Tuple:
        """Hashable chromosome identity, memoized on first call.

        The GA only mutates freshly-copied (never-yet-keyed) solutions, so
        memoization is safe; ``copy()`` deliberately does not carry the
        cache over. Do not mutate a solution after calling ``key()`` on it.
        """
        k = self.__dict__.get("_key_cache")
        if k is None:
            k = self.__dict__["_key_cache"] = (
                tuple(tuple(p) for p in self.partition),
                tuple(tuple(m) for m in self.mapping),
                tuple(self.priority),
                tuple(self.dtype),
                tuple(self.backend),
            )
        return k


def subgraph_processor(sg: Subgraph, layer_mapping: Sequence[int]) -> int:
    """Majority vote of the subgraph's layers' processor preferences (Fig. 7b)."""
    votes: Dict[int, int] = {}
    for i in sg.layer_ids:
        p = layer_mapping[i]
        votes[p] = votes.get(p, 0) + 1
    best_count = max(votes.values())
    # Deterministic tie-break: smallest processor id among the winners.
    return min(p for p, c in votes.items() if c == best_count)


@dataclass(frozen=True)
class PlacedSubgraph:
    """A subgraph with its execution decision resolved from the chromosomes."""

    subgraph: Subgraph
    network: int
    processor: int
    dtype: str
    backend: str
    priority: int

    @property
    def name(self) -> str:
        return self.subgraph.name

    def profile_key(self) -> str:
        return self.subgraph.merkle_hash(extra=(self.processor, self.dtype, self.backend))


def decode_solution(
    sol: Solution, graphs: Sequence[ModelGraph]
) -> List[List[PlacedSubgraph]]:
    """Interpret chromosomes into per-network placed subgraph lists."""
    out: List[List[PlacedSubgraph]] = []
    prio_rank = {net: r for r, net in enumerate(sol.priority)}
    for net, g in enumerate(graphs):
        sgs = g.partition(sol.partition[net])
        placed = [
            PlacedSubgraph(
                subgraph=sg,
                network=net,
                processor=subgraph_processor(sg, sol.mapping[net]),
                dtype=DTYPES[sol.dtype[net]],
                backend=BACKENDS[sol.backend[net]],
                priority=prio_rank[net],
            )
            for sg in sgs
        ]
        out.append(placed)
    return out


class SolutionFactory:
    """Creates and perturbs :class:`Solution`\\ s for a fixed problem instance."""

    def __init__(
        self,
        graphs: Sequence[ModelGraph],
        num_processors: int,
        rng: Optional[random.Random] = None,
        cut_prob: float = 0.15,
        num_dtypes: int = len(DTYPES),
        num_backends: int = len(BACKENDS),
        processors: Optional[Sequence[Processor]] = None,
    ):
        self.graphs = list(graphs)
        self.num_processors = num_processors
        self.rng = rng or random.Random(0)
        self.cut_prob = cut_prob
        self.num_dtypes = num_dtypes
        self.num_backends = num_backends
        # optional capability knowledge: lets heuristic seeds avoid pinning
        # a processor to a (dtype, backend) it cannot execute
        self.processors = list(processors) if processors is not None else None

    # -- creation -----------------------------------------------------------
    def random_solution(self) -> Solution:
        r = self.rng
        partition = [
            [1 if r.random() < self.cut_prob else 0 for _ in range(g.num_edges)]
            for g in self.graphs
        ]
        mapping = [
            [r.randrange(self.num_processors) for _ in range(g.num_layers)]
            for g in self.graphs
        ]
        priority = list(range(len(self.graphs)))
        r.shuffle(priority)
        dtype = [r.randrange(self.num_dtypes) for _ in self.graphs]
        backend = [r.randrange(self.num_backends) for _ in self.graphs]
        return Solution(partition, mapping, priority, dtype, backend)

    def seeded_solution(self, processor: int, cuts: bool = False) -> Solution:
        """A heuristic seed: everything on ``processor``, no (or random) cuts.

        The (dtype, backend) genes default to (0, 0) = (fp32, default); when
        the factory knows its processors and the pinned one cannot execute
        that configuration (e.g. an fp16/int8-only NPU), the seed instead
        carries the pinned processor's fastest *supported* configuration —
        otherwise the "everything on P" seed simulates under the capability
        fallback penalty and is useless as GA seeding material.
        """
        r = self.rng
        partition = [
            [1 if (cuts and r.random() < self.cut_prob) else 0 for _ in range(g.num_edges)]
            for g in self.graphs
        ]
        mapping = [[processor] * g.num_layers for g in self.graphs]
        priority = list(range(len(self.graphs)))
        di, bi = self._seed_config(processor)
        return Solution(partition, mapping, priority,
                        [di] * len(self.graphs), [bi] * len(self.graphs))

    def _seed_config(self, processor: int) -> Tuple[int, int]:
        """(dtype, backend) gene pair for a seed pinned to ``processor``:
        (0, 0) when supported (or capabilities unknown), else the supported
        pair with the highest throughput. Deterministic — no RNG draw, so
        adding capability knowledge never perturbs the seed RNG stream."""
        if self.processors is None:
            return (0, 0)
        proc = next((p for p in self.processors if p.pid == processor), None)
        if proc is None or proc.thr(DTYPES[0], BACKENDS[0]) is not None:
            return (0, 0)
        best: Optional[Tuple[float, int, int]] = None
        for di in range(min(self.num_dtypes, len(DTYPES))):
            for bi in range(min(self.num_backends, len(BACKENDS))):
                t = proc.thr(DTYPES[di], BACKENDS[bi])
                if t is not None and (best is None or t > best[0]):
                    best = (t, di, bi)
        return (best[1], best[2]) if best is not None else (0, 0)

    # -- crossover ------------------------------------------------------------
    def crossover(self, a: Solution, b: Solution) -> Tuple[Solution, Solution]:
        """One-point crossover on partition+mapping, UPMX on priority (§4.3)."""
        r = self.rng
        c1, c2 = a.copy(), b.copy()
        c1.fitness = c2.fitness = None
        for net in range(len(self.graphs)):
            if len(c1.partition[net]) > 1:
                pt = r.randrange(1, len(c1.partition[net]))
                c1.partition[net][pt:], c2.partition[net][pt:] = (
                    c2.partition[net][pt:],
                    c1.partition[net][pt:],
                )
            if len(c1.mapping[net]) > 1:
                pt = r.randrange(1, len(c1.mapping[net]))
                c1.mapping[net][pt:], c2.mapping[net][pt:] = (
                    c2.mapping[net][pt:],
                    c1.mapping[net][pt:],
                )
        c1.priority, c2.priority = upmx(c1.priority, c2.priority, r)
        # uniform swap for config genes
        for net in range(len(self.graphs)):
            if r.random() < 0.5:
                c1.dtype[net], c2.dtype[net] = c2.dtype[net], c1.dtype[net]
            if r.random() < 0.5:
                c1.backend[net], c2.backend[net] = c2.backend[net], c1.backend[net]
        return c1, c2

    # -- mutation -------------------------------------------------------------
    def mutate(
        self,
        sol: Solution,
        p_bit: float = 0.03,
        p_map: float = 0.05,
        p_prio: float = 0.2,
        p_cfg: float = 0.1,
    ) -> Solution:
        r = self.rng
        m = sol.copy()
        m.fitness = None
        for net in range(len(self.graphs)):
            for i in range(len(m.partition[net])):
                if r.random() < p_bit:
                    m.partition[net][i] ^= 1
            for i in range(len(m.mapping[net])):
                if r.random() < p_map:
                    m.mapping[net][i] = r.randrange(self.num_processors)
            if r.random() < p_cfg:
                m.dtype[net] = r.randrange(self.num_dtypes)
            if r.random() < p_cfg:
                m.backend[net] = r.randrange(self.num_backends)
        if len(m.priority) > 1 and r.random() < p_prio:
            i, j = r.sample(range(len(m.priority)), 2)
            m.priority[i], m.priority[j] = m.priority[j], m.priority[i]
        return m


def upmx(p1: List[int], p2: List[int], rng: random.Random, indpb: float = 0.5
         ) -> Tuple[List[int], List[int]]:
    """Uniform Partially-Matched Crossover for permutations (Cicirello 2000).

    For each position, with probability ``indpb`` swap the genes and repair
    both permutations via the PMX mapping so they stay valid permutations.
    """
    c1, c2 = list(p1), list(p2)
    n = len(c1)
    pos1 = {v: i for i, v in enumerate(c1)}
    pos2 = {v: i for i, v in enumerate(c2)}
    for i in range(n):
        if rng.random() < indpb:
            v1, v2 = c1[i], c2[i]
            # swap v1 and v2 inside each child
            c1[i], c1[pos1[v2]] = v2, v1
            c2[i], c2[pos2[v1]] = v1, v2
            pos1[v1], pos1[v2] = pos1[v2], pos1[v1]
            pos2[v1], pos2[v2] = pos2[v2], pos2[v1]
    return c1, c2
