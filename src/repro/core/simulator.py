"""Discrete-event simulation of the Puzzle Runtime (paper §4.3 'Simulator').

Replays the Coordinator → Worker → Engine workflow of §5.2 over a candidate
solution: per-group request sources (periodic by default; any
:class:`~repro.core.arrivals.ArrivalSpec` process), subgraph tasks released
when their dependencies resolve, per-processor non-preemptive workers
draining priority queues, communication costs at processor boundaries and
(de)quantization at dtype boundaries.

Computation costs come from the device-in-the-loop :class:`Profiler`;
communication from the piecewise-linear comm model (§4.1).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, Generator, List, Optional, Sequence, Tuple

from .arrivals import ArrivalSpec, arrival_horizon, draw_arrivals
from .chromosome import PlacedSubgraph
from .comm import PiecewiseLinearCommModel, quantization_cost
from .des import Environment, PriorityStore
from .faults import FaultSpec, FaultStream
from .processors import Processor
from .profiler import Profiler


def derive_dependencies(
    placed: Sequence[Sequence[PlacedSubgraph]],
) -> Tuple[List[List[List[int]]], List[List[List[int]]], List[Dict[int, int]]]:
    """Static per-network dependency structure over subgraphs.

    Returns ``(deps, succs, owner)`` where ``deps[net][k]`` lists producer
    subgraph ids of subgraph ``k``, ``succs`` is the reverse relation, and
    ``owner[net]`` maps layer id -> owning subgraph index. Shared by the
    reference DES (:class:`RuntimeSimulator`) and the fast array engine
    (:mod:`repro.core.fastsim`) so both see identical structure.
    """
    all_deps: List[List[List[int]]] = []
    all_succs: List[List[List[int]]] = []
    owners: List[Dict[int, int]] = []
    for net_placed in placed:
        owner: Dict[int, int] = {}
        for k, p in enumerate(net_placed):
            for lid in p.subgraph.layer_ids:
                owner[lid] = k
        deps: List[List[int]] = [[] for _ in net_placed]
        succs: List[List[int]] = [[] for _ in net_placed]
        for k, p in enumerate(net_placed):
            prods = sorted({owner[e.src] for e in p.subgraph.in_cut_edges()})
            deps[k] = prods
            for pr in prods:
                succs[pr].append(k)
        all_deps.append(deps)
        all_succs.append(succs)
        owners.append(owner)
    return all_deps, all_succs, owners


def subgraph_task_costs(
    placed: Sequence[Sequence[PlacedSubgraph]],
    net: int,
    k: int,
    owner: Dict[int, int],
    has_deps: bool,
    profiler: Profiler,
    comm_model: PiecewiseLinearCommModel,
    input_home_pid: int,
    exec_cache: Optional[Dict] = None,
    exec_key: Optional[Tuple] = None,
    in_cut: Optional[Sequence] = None,
) -> Tuple[float, float, float]:
    """(comm, quant, exec) seconds for subgraph ``k`` of network ``net``.

    Float operations happen in a fixed order so the reference and fast
    engines compute bit-identical costs. ``exec_cache``/``exec_key`` let the
    fast path memoize the profiler lookup (Merkle hashing dominates it) and
    ``in_cut`` lets it supply the subgraph's precomputed boundary edges; the
    profiler is deterministic per key and the edge list is a pure function
    of the subgraph, so cached values are identical.
    """
    p = placed[net][k]
    comm = 0.0
    quant = 0.0
    if in_cut is None:
        in_cut = p.subgraph.in_cut_edges()
    for e in in_cut:
        prod = placed[net][owner[e.src]]
        if prod.processor != p.processor:
            comm += comm_model.cost(e.bytes_)
        if prod.dtype != p.dtype:
            quant += quantization_cost(e.bytes_, comm_model.bandwidth)
    if not has_deps:
        # model input arrives at the input home processor
        in_bytes = p.subgraph.input_bytes()
        if p.processor != input_home_pid:
            comm += comm_model.cost(in_bytes)
    if exec_cache is not None:
        exec_t = exec_cache.get(exec_key)
        if exec_t is None:
            exec_t = profiler.subgraph_time(p)
            exec_cache[exec_key] = exec_t
    else:
        exec_t = profiler.subgraph_time(p)
    return comm, quant, exec_t


@dataclass(frozen=True)
class NoiseModel:
    """Execution-time fluctuation per processor kind (§6.3).

    The paper observes large run-to-run variance, worst on the CPU (which
    also runs the scheduler/dispatcher and system tasks) and small on the
    NPU. Samples are lognormal multipliers around 1.0. The *fast* simulator
    runs clean (the paper's SimPy model is deterministic too); the
    *measurement* evaluation applies noise — that is the device-in-the-loop
    distinction that let Puzzle reject fluctuation-sensitive solutions.
    """

    sigma_by_kind: Tuple[Tuple[str, float], ...] = (
        ("cpu", 0.22), ("gpu", 0.07), ("npu", 0.03), ("tpu-lane", 0.02),
    )
    seed: int = 0

    def sigma(self, kind: str) -> float:
        for k, s in self.sigma_by_kind:
            if k == kind:
                return s
        return 0.05


@dataclass
class TaskRecord:
    """Execution trace of one subgraph instance."""

    group: int
    request: int
    network: int
    sg_index: int
    processor: int
    released: float = 0.0
    started: float = 0.0
    finished: float = 0.0
    comm_time: float = 0.0
    exec_time: float = 0.0
    quant_time: float = 0.0


@dataclass
class RequestRecord:
    group: int
    request: int
    arrival: float
    first_start: float = float("inf")
    last_finish: float = 0.0
    done_tasks: int = 0
    total_tasks: int = 0

    @property
    def makespan(self) -> float:
        # Θ = max_m T_f − min_m T_s (paper §6.2); T_s is the first actual
        # execution start among the group's models.
        if self.done_tasks < self.total_tasks:
            return float("inf")  # unfinished request at horizon = dropped frame
        return self.last_finish - min(self.first_start, self.arrival)


@dataclass
class SimResult:
    requests: List[RequestRecord]
    tasks: List[TaskRecord]
    busy_time: Dict[int, float]
    horizon: float

    def makespans(self, group: Optional[int] = None) -> List[float]:
        return [
            r.makespan
            for r in self.requests
            if group is None or r.group == group
        ]

    def utilization(self, pid: int) -> float:
        return self.busy_time.get(pid, 0.0) / max(self.horizon, 1e-12)


class RuntimeSimulator:
    """Simulates one scenario execution for a decoded solution."""

    def __init__(
        self,
        placed: Sequence[Sequence[PlacedSubgraph]],   # per network
        processors: Sequence[Processor],
        profiler: Profiler,
        comm_model: PiecewiseLinearCommModel,
        groups: Sequence[Sequence[int]],              # per group: network ids
        periods: Sequence[float],                     # per group
        num_requests: int = 20,
        input_home_pid: int = 0,
        overlap_comm: bool = False,
        noise: Optional[NoiseModel] = None,
        dispatch_overhead: float = 0.0,
        dispatch_pid: int = 0,
        arrivals: Optional[ArrivalSpec] = None,
        faults: Optional[FaultSpec] = None,
    ):
        self.placed = placed
        self.processors = processors
        self.profiler = profiler
        self.comm = comm_model
        self.groups = groups
        self.periods = periods
        self.num_requests = num_requests
        self.input_home_pid = input_home_pid
        self.overlap_comm = overlap_comm
        self.noise = noise
        # request-source arrival process; None = periodic (arrival = rid·Φ)
        self.arrivals = arrivals
        # fault ensemble; an empty spec normalizes to None so the clean
        # path stays byte-for-byte what it was before the fault layer
        self.faults = None if faults is None or faults.empty else faults
        self._noise_rng = random.Random(noise.seed if noise else 0)
        # The Coordinator runs on the CPU (paper §6.3: dispatch/system work
        # makes the CPU a contended, fluctuating resource). Every task
        # dispatch steals `dispatch_overhead` seconds of the dispatch
        # processor's worker time.
        self.dispatch_overhead = dispatch_overhead
        self.dispatch_pid = dispatch_pid
        # Static per-network dependency structure over subgraphs (shared with
        # the fast array engine so both see identical structure).
        self._deps, self._succs, self._producer_of_layer = derive_dependencies(placed)
        # Task costs are request-independent: precompute once per solution.
        self._costs: List[List[Tuple[float, float, float]]] = [
            [
                subgraph_task_costs(
                    placed, net, k, self._producer_of_layer[net],
                    bool(self._deps[net][k]), profiler, comm_model,
                    input_home_pid,
                )
                for k in range(len(net_placed))
            ]
            for net, net_placed in enumerate(placed)
        ]

    # -- simulation -----------------------------------------------------------
    def run(self) -> SimResult:
        env = Environment()
        stores = {proc.pid: PriorityStore(env) for proc in self.processors}
        busy: Dict[int, float] = {proc.pid: 0.0 for proc in self.processors}
        tasks: List[TaskRecord] = []
        req_records: Dict[Tuple[int, int], RequestRecord] = {}
        # pending dep counters per (group, request, net, sg)
        pending: Dict[Tuple[int, int, int, int], int] = {}
        release_seq = [0]

        def release(gid: int, rid: int, net: int, k: int) -> None:
            p = self.placed[net][k]
            rec = TaskRecord(
                group=gid, request=rid, network=net, sg_index=k,
                processor=p.processor, released=env.now,
            )
            tasks.append(rec)
            if self.dispatch_overhead > 0 and self.dispatch_pid in stores:
                # Coordinator dispatch work occupies the dispatch processor
                # before the task can start executing anywhere.
                release_seq[0] += 1
                stores[self.dispatch_pid].put(
                    ("dispatch",), priority=(-1, 0, release_seq[0])
                )
            release_seq[0] += 1
            stores[p.processor].put(
                (rec, net, k, gid, rid), priority=(0, p.priority, release_seq[0])
            )

        def task_done(gid: int, rid: int, net: int, k: int) -> None:
            key = (gid, rid)
            rr = req_records[key]
            rr.done_tasks += 1
            rr.last_finish = max(rr.last_finish, env.now)
            for s in self._succs[net][k]:
                pk = (gid, rid, net, s)
                pending[pk] -= 1
                if pending[pk] == 0:
                    release(gid, rid, net, s)

        fault_stream = FaultStream(self.faults) if self.faults else None

        def worker(proc: Processor) -> Generator:
            store = stores[proc.pid]
            sigma = self.noise.sigma(proc.kind) if self.noise else 0.0
            while True:
                item = yield store.get()
                if item[0] == "dispatch":
                    busy[proc.pid] += self.dispatch_overhead
                    yield env.timeout(self.dispatch_overhead)
                    continue
                rec, net, k, gid, rid = item
                comm, quant, exec_t = self._costs[net][k]
                if sigma > 0.0:
                    # mean-1 lognormal fluctuation (§6.3 run-to-run variance)
                    exec_t *= math.exp(
                        self._noise_rng.gauss(-0.5 * sigma * sigma, sigma)
                    )
                stall = 0.0
                if fault_stream is not None:
                    exec_t, stall = fault_stream.service(
                        proc.pid, env.now, exec_t)
                rec.comm_time, rec.quant_time, rec.exec_time = comm, quant, exec_t
                rec.started = env.now
                rr = req_records[(gid, rid)]
                rr.first_start = min(rr.first_start, env.now)
                total = exec_t + quant + (0.0 if self.overlap_comm else comm)
                if stall > 0.0:
                    # delivered to a dropped processor: wait out the repair
                    # (forever when permanent — the END event at t=inf never
                    # fires, so the request is dropped at the horizon)
                    total = stall + total
                if not math.isinf(total):
                    busy[proc.pid] += total
                yield env.timeout(total)
                rec.finished = env.now
                task_done(gid, rid, net, k)

        def request_source(gid: int, nets: Sequence[int],
                           table: Sequence[float]) -> Generator:
            for rid in range(self.num_requests):
                arrival = table[rid]
                if arrival > env.now:
                    yield env.timeout(arrival - env.now)
                total_tasks = sum(len(self.placed[n]) for n in nets)
                req_records[(gid, rid)] = RequestRecord(
                    group=gid, request=rid, arrival=env.now, total_tasks=total_tasks
                )
                for n in nets:
                    for k in range(len(self.placed[n])):
                        d = len(self._deps[n][k])
                        pending[(gid, rid, n, k)] = d
                        if d == 0:
                            release(gid, rid, n, k)

        # one shared table per run: every engine tier draws the identical
        # arrival timestamps (periodic when self.arrivals is None)
        arrival_tables = draw_arrivals(
            self.arrivals, self.periods, self.num_requests)
        for proc in self.processors:
            env.process(worker(proc))
        for gid, nets in enumerate(self.groups):
            env.process(request_source(gid, nets, arrival_tables[gid]))

        # run to quiescence with a generous horizon: all requests issued plus
        # slack for stragglers (periodic: the historical expression verbatim).
        horizon = arrival_horizon(
            arrival_tables, self.periods, self.num_requests)
        env.run(until=horizon)
        return SimResult(
            requests=sorted(req_records.values(), key=lambda r: (r.group, r.request)),
            tasks=tasks,
            busy_time=busy,
            horizon=env.now,
        )
