"""Fast array-based search-time simulator (drop-in for :class:`RuntimeSimulator`).

The GA evaluates tens of thousands of candidate schedules per scenario, and
every evaluation replays the runtime in the discrete-event simulator. The
reference implementation (:mod:`repro.core.simulator`) drives generator
coroutines through a SimPy-style :class:`~repro.core.des.Environment`; that
is faithful but slow — every event allocates an ``Event`` object, every
worker step is a generator ``send``, and every solution re-derives its
dependency structure and cost table.

This module splits that work in two:

* :class:`FastSimSpec` — the *static* part of a decoded solution: flattened
  CSR-style dependency arrays and per-subgraph ``(comm, quant, exec)`` cost
  vectors, built once per solution (see ``StaticAnalyzer``'s decode cache)
  and reused across every ``(alpha, num_requests, noise seed)`` evaluation.
* :class:`FastSimulator` — a single ``heapq`` event loop over plain tuples.
  No ``Environment``/``Process``/``Event`` objects, no generator dispatch.

Semantics are *bit-identical* to :class:`RuntimeSimulator` — same
non-preemptive priority queues, same tie-breaking at equal timestamps, same
dispatch-overhead injection, and the same lognormal noise stream for a given
seed — so the measured (noisy) evaluation path can use it too. The parity is
enforced by ``tests/test_fastsim.py`` and the ``simspeed`` benchmark section;
``RuntimeSimulator`` remains the reference oracle.
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .arrivals import ArrivalSpec, arrival_horizon, draw_arrivals
from .chromosome import BACKENDS, DTYPES, PlacedSubgraph, subgraph_processor
from .comm import PiecewiseLinearCommModel
from .faults import FaultSpec, FaultStream
from .processors import Processor
from .profiler import Profiler
from .simulator import (
    NoiseModel,
    RequestRecord,
    SimResult,
    TaskRecord,
    derive_dependencies,
    subgraph_task_costs,
)

# Event codes. Heap entries are ``(time, seq, code, ...)`` with a globally
# unique ``seq``, so comparison never reaches the payload.
_SRC = 0       # request source fires: release one request of one group
_DELIVER = 1   # a store item is handed to an idle worker
_END = 2       # a worker finishes its current item

_DISPATCH = ("dispatch",)  # sentinel store item, mirrors the reference sim


@dataclass
class FastSimSpec:
    """Static per-solution arrays, reusable across simulator runs.

    ``placed`` is metadata for inspection/debugging; the event loop reads
    only the flat arrays. :class:`SpecBuilder` leaves it ``None`` on its hot
    path (use :meth:`SpecBuilder.decode` when the decoded view is needed).
    """

    placed: Optional[Sequence[Sequence[PlacedSubgraph]]]
    processors: Sequence[Processor]
    # flat subgraph indexing: global id g = offsets[net] + k
    offsets: List[int]
    counts: List[int]
    net_of: List[int]
    k_of: List[int]
    proc_of: List[int]           # processor pid per flat subgraph
    prio_of: List[int]           # decoded network priority rank per subgraph
    comm: List[float]
    quant: List[float]
    exec_: List[float]
    dep_count: List[int]
    succ_indptr: List[int]       # CSR over successors
    succ_flat: List[int]

    @property
    def num_subgraphs(self) -> int:
        return len(self.proc_of)

    def roots(self) -> List[List[int]]:
        """Per-network flat ids of dependency-free subgraphs, cached.

        These are released at every request arrival, so all three engines
        (fast heapq loop, lean loop, batch lock-step pass) need them for
        every run of the same spec — compute once per spec instead.
        """
        r = getattr(self, "_roots", None)
        if r is None:
            r = self._roots = [
                [g for g in range(self.offsets[n],
                                  self.offsets[n] + self.counts[n])
                 if self.dep_count[g] == 0]
                for n in range(len(self.counts))
            ]
        return r

    def signature(self) -> Tuple:
        """Content key: two specs with equal signatures simulate identically.

        Distinct chromosomes often decode to the same placed configuration
        (mapping mutations that flip no majority vote, priority swaps between
        networks with equal rank) — callers can memoize evaluation results on
        this key. Cached on first use.
        """
        sig = getattr(self, "_signature", None)
        if sig is None:
            sig = self._signature = (
                tuple(self.offsets),
                tuple(self.proc_of),
                tuple(self.prio_of),
                tuple(self.comm),
                tuple(self.quant),
                tuple(self.exec_),
                tuple(self.dep_count),
                tuple(self.succ_indptr),
                tuple(self.succ_flat),
            )
        return sig


def build_spec(
    placed: Sequence[Sequence[PlacedSubgraph]],
    processors: Sequence[Processor],
    profiler: Profiler,
    comm_model: PiecewiseLinearCommModel,
    input_home_pid: int = 0,
) -> FastSimSpec:
    """Flatten a decoded solution into the arrays the event loop consumes."""
    deps, succs, owners = derive_dependencies(placed)
    offsets: List[int] = []
    counts: List[int] = []
    net_of: List[int] = []
    k_of: List[int] = []
    proc_of: List[int] = []
    prio_of: List[int] = []
    comm: List[float] = []
    quant: List[float] = []
    exec_: List[float] = []
    dep_count: List[int] = []
    succ_indptr: List[int] = [0]
    succ_flat: List[int] = []
    base = 0
    for net, net_placed in enumerate(placed):
        offsets.append(base)
        counts.append(len(net_placed))
        for k, p in enumerate(net_placed):
            net_of.append(net)
            k_of.append(k)
            proc_of.append(p.processor)
            prio_of.append(p.priority)
            c, q, x = subgraph_task_costs(
                placed, net, k, owners[net], bool(deps[net][k]),
                profiler, comm_model, input_home_pid,
            )
            comm.append(c)
            quant.append(q)
            exec_.append(x)
            dep_count.append(len(deps[net][k]))
            succ_flat.extend(base + s for s in succs[net][k])
            succ_indptr.append(len(succ_flat))
        base += len(net_placed)
    return FastSimSpec(
        placed=placed, processors=processors, offsets=offsets, counts=counts,
        net_of=net_of, k_of=k_of, proc_of=proc_of, prio_of=prio_of,
        comm=comm, quant=quant, exec_=exec_, dep_count=dep_count,
        succ_indptr=succ_indptr, succ_flat=succ_flat,
    )


class SpecBuilder:
    """Builds :class:`FastSimSpec`\\ s for a fixed problem instance, with
    cross-solution caching.

    GA populations share genetic material: distinct solutions frequently
    carry identical partition bit-vectors per network, and the same
    ``(subgraph, processor, dtype, backend)`` execution decisions recur
    constantly. Decoding and cost annotation are the dominant per-candidate
    cost once the event loop itself is fast, so this builder memoizes

    * ``graph.partition(bits)`` per network and bit-pattern, and
    * profiled execution time per ``(net, bits, k, processor, dtype, backend)``

    while recomputing the cheap boundary terms (comm/quant, which depend on
    neighbouring placements) fresh for every solution. Values are identical
    to the uncached path — the profiler is deterministic per profile key —
    so engine parity is unaffected.
    """

    def __init__(
        self,
        graphs: Sequence,
        processors: Sequence[Processor],
        profiler: Profiler,
        comm_model: PiecewiseLinearCommModel,
        input_home_pid: int = 0,
        max_partitions_per_net: int = 8192,
    ):
        self.graphs = list(graphs)
        self.processors = processors
        self.profiler = profiler
        self.comm_model = comm_model
        self.input_home_pid = input_home_pid
        self.max_partitions_per_net = max_partitions_per_net
        self._partitions: List[Dict[Tuple[int, ...], tuple]] = [
            {} for _ in self.graphs
        ]
        self._exec: Dict[Tuple, float] = {}
        # content exec-key -> Merkle profile key, so device-in-the-loop
        # ProfileDB updates can invalidate exactly the affected memo entries
        self._exec_profile_key: Dict[Tuple, str] = {}
        # per-network decode+cost cache: one network's placed subgraphs and
        # cost vectors depend only on its own genes (+ priority rank), so
        # they are reusable across the many solutions that share them.
        self._net_cache: List[Dict[Tuple, tuple]] = [{} for _ in self.graphs]
        # majority-vote memo per (partition bits, mapping genes)
        self._votes: List[Dict[Tuple, Tuple[int, ...]]] = [{} for _ in self.graphs]

    def _structure(self, net: int, bits: Sequence[int]) -> tuple:
        """(subgraphs, deps, succs, owner, in_cuts) for one network's
        partition bits.

        The dependency structure and boundary-edge lists are pure functions
        of the partition, so they cache alongside the subgraph list (same
        derivation as :func:`derive_dependencies`).
        """
        key = tuple(bits)
        cache = self._partitions[net]
        hit = cache.get(key)
        if hit is None:
            if len(cache) >= self.max_partitions_per_net:
                cache.clear()
            sgs = self.graphs[net].partition(list(bits))
            owner: Dict[int, int] = {}
            for k, sg in enumerate(sgs):
                for lid in sg.layer_ids:
                    owner[lid] = k
            in_cuts = [sg.in_cut_edges() for sg in sgs]
            deps = [sorted({owner[e.src] for e in ic}) for ic in in_cuts]
            succs: List[List[int]] = [[] for _ in sgs]
            for k, prods in enumerate(deps):
                for pr in prods:
                    succs[pr].append(k)
            hit = cache[key] = (sgs, deps, succs, owner, in_cuts)
        return hit

    def decode(self, sol: Solution) -> List[List[PlacedSubgraph]]:
        """`decode_solution` with the partition cache."""
        out: List[List[PlacedSubgraph]] = []
        prio_rank = {n: r for r, n in enumerate(sol.priority)}
        for net in range(len(self.graphs)):
            sgs = self._structure(net, sol.partition[net])[0]
            mapping = sol.mapping[net]
            out.append([
                PlacedSubgraph(
                    subgraph=sg,
                    network=net,
                    processor=subgraph_processor(sg, mapping),
                    dtype=DTYPES[sol.dtype[net]],
                    backend=BACKENDS[sol.backend[net]],
                    priority=prio_rank[net],
                )
                for sg in sgs
            ])
        return out

    def _net_entry(self, sol: Solution, net: int) -> tuple:
        """Cached (sgs, procs, dep_counts, succ_indptr, succ_flat, comm,
        quant, exec) for one network under one *decoded* assignment.

        Keyed by the majority-voted processor per subgraph rather than the
        raw mapping genes — many mapping mutations flip no vote, so they all
        share one entry — and priority is deliberately excluded: it only
        shapes queue ordering at run time, never costs.
        """
        bits_key = tuple(sol.partition[net])
        sgs, deps, succs, owner, in_cuts = self._structure(net, bits_key)
        mapping = sol.mapping[net]
        vote_key = (bits_key, tuple(mapping))
        votes = self._votes[net]
        procs = votes.get(vote_key)
        if procs is None:
            if len(votes) >= self.max_partitions_per_net:
                votes.clear()
            procs = votes[vote_key] = tuple(
                subgraph_processor(sg, mapping) for sg in sgs
            )
        key = (bits_key, procs, sol.dtype[net], sol.backend[net])
        cache = self._net_cache[net]
        ent = cache.get(key)
        if ent is not None:
            return ent
        if len(cache) >= self.max_partitions_per_net:
            cache.clear()
        dtype = DTYPES[sol.dtype[net]]
        backend = BACKENDS[sol.backend[net]]
        # cost annotation is priority-independent; priority 0 placeholder
        placed_net = [
            PlacedSubgraph(
                subgraph=sg, network=net, processor=proc,
                dtype=dtype, backend=backend, priority=0,
            )
            for sg, proc in zip(sgs, procs)
        ]
        gkey = id(self.graphs[net])  # graphs list pins the objects, ids stable
        comm: List[float] = []
        quant: List[float] = []
        exec_: List[float] = []
        dep_counts: List[int] = []
        succ_indptr: List[int] = [0]
        succ_flat: List[int] = []
        one_net = [placed_net]  # subgraph_task_costs only reads placed[net]
        for k, p in enumerate(placed_net):
            # content key: the same layer set under the same execution
            # config costs the same across partitions and solutions
            exec_key = (gkey, p.subgraph.layer_ids, p.processor,
                        p.dtype, p.backend)
            if exec_key not in self._exec_profile_key:
                # merkle_hash memoizes on the (shared) Subgraph instance,
                # so this is a dict hit on all but the first computation
                self._exec_profile_key[exec_key] = p.profile_key()
            c, q, x = subgraph_task_costs(
                one_net, 0, k, owner, bool(deps[k]),
                self.profiler, self.comm_model, self.input_home_pid,
                exec_cache=self._exec,
                exec_key=exec_key,
                in_cut=in_cuts[k],
            )
            comm.append(c)
            quant.append(q)
            exec_.append(x)
            dep_counts.append(len(deps[k]))
            succ_flat.extend(succs[k])
            succ_indptr.append(len(succ_flat))
        ent = cache[key] = (
            sgs, procs, dep_counts, succ_indptr, succ_flat,
            comm, quant, exec_,
        )
        return ent

    def invalidate(self, profile_keys: Optional[Sequence[str]] = None) -> int:
        """Drop cost memos stale after a ProfileDB change; returns how many
        exec-cache entries were dropped.

        With ``profile_keys`` only the exec memo entries whose Merkle
        profile key is affected are evicted (the map recorded at memo-fill
        time makes this exact); ``None`` evicts everything. The per-network
        decode+cost entries embed exec times, so they are cleared wholesale
        either way — they rebuild from the surviving partition/vote/exec
        caches on the next ``build``. Structure caches (partitions, votes)
        are cost-independent and always survive.
        """
        if profile_keys is None:
            dropped = len(self._exec)
            self._exec.clear()
            self._exec_profile_key.clear()
        else:
            keys = set(profile_keys)
            stale = [ek for ek, pk in self._exec_profile_key.items()
                     if pk in keys]
            dropped = 0
            for ek in stale:
                del self._exec_profile_key[ek]
                if self._exec.pop(ek, None) is not None:
                    dropped += 1
        for cache in self._net_cache:
            cache.clear()
        return dropped

    def build(self, sol: Solution) -> FastSimSpec:
        prio_rank = {n: r for r, n in enumerate(sol.priority)}
        offsets: List[int] = []
        counts: List[int] = []
        net_of: List[int] = []
        k_of: List[int] = []
        proc_of: List[int] = []
        prio_of: List[int] = []
        comm: List[float] = []
        quant: List[float] = []
        exec_: List[float] = []
        dep_count: List[int] = []
        succ_indptr: List[int] = [0]
        succ_flat: List[int] = []
        base = 0
        for net in range(len(self.graphs)):
            prio = prio_rank[net]
            (sgs, procs, net_dep_counts, net_indptr, net_succ,
             net_comm, net_quant, net_exec) = self._net_entry(sol, net)
            n_sg = len(sgs)
            offsets.append(base)
            counts.append(n_sg)
            net_of.extend([net] * n_sg)
            k_of.extend(range(n_sg))
            proc_of.extend(procs)
            prio_of.extend([prio] * n_sg)
            comm.extend(net_comm)
            quant.extend(net_quant)
            exec_.extend(net_exec)
            dep_count.extend(net_dep_counts)
            succ_flat.extend(base + s for s in net_succ)
            top = succ_indptr[-1]
            succ_indptr.extend(top + o for o in net_indptr[1:])
            base += n_sg
        return FastSimSpec(
            placed=None, processors=self.processors, offsets=offsets,
            counts=counts, net_of=net_of, k_of=k_of, proc_of=proc_of,
            prio_of=prio_of, comm=comm, quant=quant, exec_=exec_,
            dep_count=dep_count, succ_indptr=succ_indptr, succ_flat=succ_flat,
        )


class FastSimulator:
    """Array-based replay of one scenario execution for a prepared solution.

    Constructor mirrors :class:`RuntimeSimulator`'s run-time parameters; the
    solution-static part lives in the :class:`FastSimSpec`.
    """

    def __init__(
        self,
        spec: FastSimSpec,
        groups: Sequence[Sequence[int]],
        periods: Sequence[float],
        num_requests: int = 20,
        overlap_comm: bool = False,
        noise: Optional[NoiseModel] = None,
        dispatch_overhead: float = 0.0,
        dispatch_pid: int = 0,
        arrivals: Optional[ArrivalSpec] = None,
        faults: Optional[FaultSpec] = None,
    ):
        self.spec = spec
        self.groups = groups
        self.periods = periods
        self.num_requests = num_requests
        self.overlap_comm = overlap_comm
        self.noise = noise
        self.dispatch_overhead = dispatch_overhead
        self.dispatch_pid = dispatch_pid
        # request-source arrival process; None = periodic (arrival = rid·Φ)
        self.arrivals = arrivals
        # fault ensemble; empty specs normalize to None (clean path intact)
        self.faults = None if faults is None or faults.empty else faults

    @classmethod
    def from_placed(
        cls,
        placed: Sequence[Sequence[PlacedSubgraph]],
        processors: Sequence[Processor],
        profiler: Profiler,
        comm_model: PiecewiseLinearCommModel,
        groups: Sequence[Sequence[int]],
        periods: Sequence[float],
        num_requests: int = 20,
        input_home_pid: int = 0,
        overlap_comm: bool = False,
        noise: Optional[NoiseModel] = None,
        dispatch_overhead: float = 0.0,
        dispatch_pid: int = 0,
        arrivals: Optional[ArrivalSpec] = None,
        faults: Optional[FaultSpec] = None,
    ) -> "FastSimulator":
        """Build spec + simulator with :class:`RuntimeSimulator`'s signature."""
        spec = build_spec(placed, processors, profiler, comm_model, input_home_pid)
        return cls(
            spec, groups, periods, num_requests=num_requests,
            overlap_comm=overlap_comm, noise=noise,
            dispatch_overhead=dispatch_overhead, dispatch_pid=dispatch_pid,
            arrivals=arrivals, faults=faults,
        )

    def run(self, collect_tasks: bool = True) -> SimResult:
        if (not collect_tasks and self.noise is None
                and self.dispatch_overhead <= 0 and self.faults is None):
            # GA fast-evaluation configuration: no task records, no noise
            # draws, no dispatch injection, no faults — take the lean loop.
            return self._run_lean()
        return self._run_full(collect_tasks)

    def _run_lean(self) -> SimResult:
        """Specialized event loop for clean no-record runs.

        Identical semantics to :meth:`_run_full` with ``collect_tasks=False,
        noise=None, dispatch_overhead=0`` (asserted by the test suite); the
        per-event branches for those features are compiled out because this
        is the innermost loop of the GA search.
        """
        spec = self.spec
        proc_of = spec.proc_of
        prio_of = spec.prio_of
        comm_v, quant_v, exec_v = spec.comm, spec.quant, spec.exec_
        dep_count = spec.dep_count
        indptr, succ = spec.succ_indptr, spec.succ_flat
        counts = spec.counts
        overlap = self.overlap_comm

        pids = [p.pid for p in spec.processors]
        n_pid = max(pids) + 1
        items: List[list] = [[] for _ in range(n_pid)]
        idle: List[bool] = [False] * n_pid
        for pid in pids:
            idle[pid] = True
        busy_v: List[float] = [0.0] * n_pid
        group_tasks = [sum(counts[n] for n in g) for g in self.groups]

        req_records: Dict[Tuple[int, int], RequestRecord] = {}
        roots = spec.roots()

        arrival_tables = draw_arrivals(
            self.arrivals, self.periods, self.num_requests)
        events: list = []
        push = heapq.heappush
        pop = heapq.heappop
        seq = 0
        release_seq = 0
        now = 0.0
        for gid in range(len(self.groups)):
            push(events, (0.0, seq, _SRC, gid, 0))
            seq += 1

        horizon = arrival_horizon(
            arrival_tables, self.periods, self.num_requests)

        while events and events[0][0] <= horizon:
            now, _, code, pid, item = pop(events)
            if code == _DELIVER:
                g, rr, pend = item
                exec_t = exec_v[g]
                if now < rr.first_start:
                    rr.first_start = now
                total = exec_t + quant_v[g] + (0.0 if overlap else comm_v[g])
                busy_v[pid] += total
                push(events, (now + total, seq, _END, pid, item))
                seq += 1
            elif code == _END:
                g, rr, pend = item
                rr.done_tasks += 1
                if now > rr.last_finish:
                    rr.last_finish = now
                for s in succ[indptr[g]:indptr[g + 1]]:
                    pend[s] -= 1
                    if pend[s] == 0:
                        # no dispatch tokens in the lean loop, so the leading
                        # priority class of the full loop's key is dropped
                        release_seq += 1
                        spid = proc_of[s]
                        if idle[spid]:
                            idle[spid] = False
                            push(events, (now, seq, _DELIVER, spid, (s, rr, pend)))
                            seq += 1
                        else:
                            push(items[spid],
                                 ((prio_of[s], release_seq), (s, rr, pend)))
                store = items[pid]
                if store:
                    _, nxt = pop(store)
                    push(events, (now, seq, _DELIVER, pid, nxt))
                    seq += 1
                else:
                    idle[pid] = True
            else:  # _SRC
                gid, rid = pid, item
                if rid == 0 and arrival_tables[gid][0] > now:
                    # non-zero first arrival: the reference source fires its
                    # init at t=0 and *then* times out to the first arrival —
                    # deferring here reproduces that heap-sequence order
                    arrival = arrival_tables[gid][0]
                    push(events, (now + (arrival - now), seq, _SRC, gid, 0))
                    seq += 1
                    continue
                rr = RequestRecord(
                    group=gid, request=rid, arrival=now,
                    total_tasks=group_tasks[gid],
                )
                req_records[(gid, rid)] = rr
                pend = list(dep_count)
                for n in self.groups[gid]:
                    for g in roots[n]:
                        release_seq += 1
                        rpid = proc_of[g]
                        if idle[rpid]:
                            idle[rpid] = False
                            push(events, (now, seq, _DELIVER, rpid, (g, rr, pend)))
                            seq += 1
                        else:
                            push(items[rpid],
                                 ((prio_of[g], release_seq), (g, rr, pend)))
                if rid + 1 < self.num_requests:
                    arrival = arrival_tables[gid][rid + 1]
                    push(events, (now + (arrival - now), seq, _SRC, gid, rid + 1))
                    seq += 1

        return SimResult(
            requests=sorted(req_records.values(), key=lambda r: (r.group, r.request)),
            tasks=[],
            busy_time={pid: busy_v[pid] for pid in pids},
            horizon=horizon,
        )

    def _run_full(self, collect_tasks: bool = True) -> SimResult:
        spec = self.spec
        proc_of = spec.proc_of
        prio_of = spec.prio_of
        comm_v, quant_v, exec_v = spec.comm, spec.quant, spec.exec_
        dep_count = spec.dep_count
        indptr, succ = spec.succ_indptr, spec.succ_flat
        net_of, k_of = spec.net_of, spec.k_of
        counts = spec.counts
        overlap = self.overlap_comm
        dispatch_ov = self.dispatch_overhead
        dispatch_pid = self.dispatch_pid
        noise = self.noise
        rng_gauss = random.Random(noise.seed if noise else 0).gauss
        exp = math.exp
        fault_service = (FaultStream(self.faults).service
                         if self.faults else None)

        # dense per-pid arrays (pids are small non-negative ints)
        pids = [p.pid for p in spec.processors]
        n_pid = max(pids) + 1
        sigma_of = [0.0] * n_pid
        for p in spec.processors:
            sigma_of[p.pid] = noise.sigma(p.kind) if noise else 0.0
        items: List[list] = [[] for _ in range(n_pid)]
        idle: List[bool] = [False] * n_pid
        for pid in pids:
            idle[pid] = True
        busy_v: List[float] = [0.0] * n_pid
        dispatch_known = dispatch_ov > 0 and dispatch_pid in pids
        group_tasks = [sum(counts[n] for n in g) for g in self.groups]

        tasks: List[TaskRecord] = []
        req_records: Dict[Tuple[int, int], RequestRecord] = {}
        # per-network flat ids of dependency-free subgraphs, released at arrival
        roots = spec.roots()

        arrival_tables = draw_arrivals(
            self.arrivals, self.periods, self.num_requests)
        events: list = []
        push = heapq.heappush
        pop = heapq.heappop
        seq = 0
        release_seq = 0
        now = 0.0

        # request sources fire in group order at t=0, like the reference
        # sim's Process init events; a non-zero first arrival defers inside
        # the _SRC handler (mirroring the reference source's first timeout).
        for gid in range(len(self.groups)):
            push(events, (0.0, seq, _SRC, gid, 0))
            seq += 1

        # Work items carry their request record and pending-counter array so
        # the hot loop never re-keys into per-request dicts:
        #   item = (rec | None, flat sg id, RequestRecord, pending list)

        def release(gid: int, rid: int, g: int, rr: "RequestRecord",
                    pend: List[List[int]]) -> None:
            nonlocal seq, release_seq
            pid = proc_of[g]
            if collect_tasks:
                rec: Optional[TaskRecord] = TaskRecord(
                    group=gid, request=rid, network=net_of[g], sg_index=k_of[g],
                    processor=pid, released=now,
                )
                tasks.append(rec)
            else:
                rec = None
            if dispatch_known:
                release_seq += 1
                if idle[dispatch_pid]:
                    idle[dispatch_pid] = False
                    push(events, (now, seq, _DELIVER, dispatch_pid, _DISPATCH))
                    seq += 1
                else:
                    push(items[dispatch_pid], ((-1, 0, release_seq), _DISPATCH))
            release_seq += 1
            item = (rec, g, rr, pend)
            if idle[pid]:
                idle[pid] = False
                push(events, (now, seq, _DELIVER, pid, item))
                seq += 1
            else:
                push(items[pid], ((0, prio_of[g], release_seq), item))

        horizon = arrival_horizon(
            arrival_tables, self.periods, self.num_requests)

        while events and events[0][0] <= horizon:
            now, _, code, pid, item = pop(events)
            if code == _DELIVER:
                if item is _DISPATCH:
                    busy_v[pid] += dispatch_ov
                    push(events, (now + dispatch_ov, seq, _END, pid, None))
                    seq += 1
                    continue
                rec, g, rr, pend = item
                exec_t = exec_v[g]
                sigma = sigma_of[pid]
                if sigma > 0.0:
                    # mean-1 lognormal fluctuation (§6.3 run-to-run variance)
                    exec_t *= exp(rng_gauss(-0.5 * sigma * sigma, sigma))
                stall = 0.0
                if fault_service is not None:
                    exec_t, stall = fault_service(pid, now, exec_t)
                quant = quant_v[g]
                cm = comm_v[g]
                if rec is not None:
                    rec.comm_time, rec.quant_time, rec.exec_time = cm, quant, exec_t
                    rec.started = now
                if now < rr.first_start:
                    rr.first_start = now
                total = exec_t + quant + (0.0 if overlap else cm)
                if stall > 0.0:
                    # dropped processor: the task waits out the repair (the
                    # END at t=inf never pops when permanent)
                    total = stall + total
                if not math.isinf(total):
                    busy_v[pid] += total
                push(events, (now + total, seq, _END, pid, item))
                seq += 1
            elif code == _END:
                if item is not None:
                    rec, g, rr, pend = item
                    if rec is not None:
                        rec.finished = now
                    rr.done_tasks += 1
                    if now > rr.last_finish:
                        rr.last_finish = now
                    i0, i1 = indptr[g], indptr[g + 1]
                    if i0 != i1:
                        gid = rr.group
                        rid = rr.request
                        for s in succ[i0:i1]:
                            pend[s] -= 1
                            if pend[s] == 0:
                                release(gid, rid, s, rr, pend)
                # worker pulls its next item or goes idle
                store = items[pid]
                if store:
                    _, nxt = pop(store)
                    push(events, (now, seq, _DELIVER, pid, nxt))
                    seq += 1
                else:
                    idle[pid] = True
            else:  # _SRC
                gid, rid = pid, item  # payload slots carry (gid, rid)
                if rid == 0 and arrival_tables[gid][0] > now:
                    # defer to the first arrival (reference-source timeout
                    # order: init fires at t=0, then times out)
                    arrival = arrival_tables[gid][0]
                    push(events, (now + (arrival - now), seq, _SRC, gid, 0))
                    seq += 1
                    continue
                rr = RequestRecord(
                    group=gid, request=rid, arrival=now,
                    total_tasks=group_tasks[gid],
                )
                req_records[(gid, rid)] = rr
                pend = list(dep_count)
                for n in self.groups[gid]:
                    for g in roots[n]:
                        release(gid, rid, g, rr, pend)
                if rid + 1 < self.num_requests:
                    arrival = arrival_tables[gid][rid + 1]
                    # reference sim computes `timeout(arrival - now)`; keep the
                    # same float expression so tie-breaking stays identical
                    push(events, (now + (arrival - now), seq, _SRC, gid, rid + 1))
                    seq += 1

        return SimResult(
            requests=sorted(req_records.values(), key=lambda r: (r.group, r.request)),
            tasks=tasks,
            busy_time={pid: busy_v[pid] for pid in pids},
            horizon=horizon,
        )
