"""Tensor-memory chunk layout shared by the runtime pool and the analyzer.

The runtime's :class:`~repro.runtime.tensorpool.TensorPool` allocates in
fixed 2 KiB chunks (paper §5.3) so freed buffers re-serve any request of
the same rounded size. The static analyzer (:mod:`repro.analysis`) must
bound peak residency with *exactly* the pool's rounding — its SL020 memory
proofs are validated by provisioning through a capacity-bounded pool — so
the chunk math lives here, in a module with no runtime (jax) dependency,
and both sides import it.
"""
from __future__ import annotations

CHUNK = 2048  # bytes, paper §5.3


def rounded_chunk_bytes(nbytes: int) -> int:
    """Bytes actually consumed by an ``nbytes`` allocation: rounded up to
    the chunk quantum, minimum one chunk (a zero-byte tensor still holds a
    chunk — the pool hands out real buffers, never aliases of nothing)."""
    return max(CHUNK, ((int(nbytes) + CHUNK - 1) // CHUNK) * CHUNK)
