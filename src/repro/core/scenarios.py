"""Scenario construction: model groups, periods, random scenario generation
(paper §6.1, Fig. 11).

A *model group* is a set of models triggered together by one input source
(camera, microphone). The group's *base period* is

    φ̄_G = Σ_{m∈G} min_p τ_p(m) · N · (1 + ε)

with N the number of groups and ε = 0.1; the evaluated period is
Φ = α · φ̄_G for a period multiplier α. The group's request *arrival
process* defaults to strictly periodic at Φ (the paper's sources) but is
pluggable per scenario — see :class:`~repro.core.arrivals.ArrivalSpec` for
the jittered / Poisson / trace processes; Φ stays the mean inter-arrival
interval and the per-request relative deadline in every case.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .arrivals import ArrivalSpec
from .chromosome import BACKENDS, DTYPES, PlacedSubgraph
from .faults import FaultSpec
from .graph import ModelGraph
from .processors import Processor
from .profiler import Profiler

EPSILON = 0.1


@dataclass(frozen=True)
class Scenario:
    """A workload: model graphs partitioned into synchronized groups.

    ``arrival`` selects the request arrival process shared by all of the
    scenario's groups (``None`` = periodic at each group's period Φ —
    byte-identical to the pre-arrival-layer behavior). The evaluation
    stack (``StaticAnalyzer``, the batched engine, the virtual-clock
    runtime) reads it from here, so one scenario object fully describes
    the workload.

    ``faults`` injects a deterministic fault ensemble (processor dropouts,
    throttle windows, stragglers — :class:`~repro.core.faults.FaultSpec`)
    into every simulation of the scenario; ``None`` = clean. A scenario
    with faults makes the GA optimize under the ensemble — the robustness
    objective — since the analyzer threads it through all evaluation paths.
    """

    name: str
    graphs: Tuple[ModelGraph, ...]
    groups: Tuple[Tuple[int, ...], ...]   # per group: indices into graphs
    arrival: Optional[ArrivalSpec] = None
    faults: Optional[FaultSpec] = None

    @property
    def num_groups(self) -> int:
        return len(self.groups)


def whole_model_placement(
    graph: ModelGraph, net: int, processor: int, dtype_ix: int, backend_ix: int
) -> PlacedSubgraph:
    """The model as a single un-partitioned subgraph on one processor."""
    sg = graph.partition([0] * graph.num_edges)[0]
    return PlacedSubgraph(
        subgraph=sg, network=net, processor=processor,
        dtype=DTYPES[dtype_ix], backend=BACKENDS[backend_ix], priority=net,
    )


def best_model_times(
    graphs: Sequence[ModelGraph],
    processors: Sequence[Processor],
    profiler: Profiler,
) -> List[Dict[int, Tuple[float, int, int]]]:
    """For each network and processor: (best time, dtype_ix, backend_ix).

    Times are in **seconds** (the profiler's native unit; the paper's tables
    are milliseconds). This is the paper's per-model profiling step used both
    for base periods (min over processors) and by the Best Mapping baseline.
    Deterministic: the profiler caches by profile key, so repeated calls
    return identical values.
    """
    out: List[Dict[int, Tuple[float, int, int]]] = []
    for net, g in enumerate(graphs):
        per_proc: Dict[int, Tuple[float, int, int]] = {}
        for proc in processors:
            best: Optional[Tuple[float, int, int]] = None
            for di in range(len(DTYPES)):
                for bi in range(len(BACKENDS)):
                    t = profiler.subgraph_time(
                        whole_model_placement(g, net, proc.pid, di, bi)
                    )
                    if best is None or t < best[0]:
                        best = (t, di, bi)
            assert best is not None
            per_proc[proc.pid] = best
        out.append(per_proc)
    return out


def base_periods(
    scenario: Scenario,
    best_times: Sequence[Dict[int, Tuple[float, int, int]]],
    epsilon: float = EPSILON,
) -> List[float]:
    """φ̄ per group in **seconds** (paper §6.1).

    ``φ̄_G = Σ_{m∈G} min_p τ_p(m) · N · (1 + ε)`` with N the number of
    groups in the scenario and ε the slack factor (paper: 0.1).
    ``best_times`` is the output of :func:`best_model_times` (seconds).
    """
    n = scenario.num_groups
    periods = []
    for group in scenario.groups:
        s = sum(min(t for t, _, _ in best_times[m].values()) for m in group)
        periods.append(s * n * (1 + epsilon))
    return periods


def sample_groups(
    rng: random.Random,
    model_names: Sequence[str],
    min_groups: int = 1,
    max_groups: int = 3,
    min_models: int = 1,
    max_models: int = 4,
) -> List[Tuple[str, ...]]:
    """Sample one random scenario composition (paper §6.1 recipe).

    Draws a group count uniformly from ``[min_groups, max_groups]``, then for
    each group a model count uniformly from ``[min_models, max_models]`` and
    that many **distinct** models from ``model_names`` (models may repeat
    *across* groups — :func:`build_scenario` materializes duplicates as
    separate graph instances). All randomness comes from the caller-supplied
    ``rng``, so a given ``random.Random(seed)`` state replays the exact same
    composition; the function draws nothing from global RNG state.
    """
    groups: List[Tuple[str, ...]] = []
    for _ in range(rng.randint(min_groups, max_groups)):
        k = rng.randint(min_models, max_models)
        groups.append(tuple(rng.sample(list(model_names), k)))
    return groups


def random_scenarios(
    model_names: Sequence[str],
    count: int = 10,
    models_per_scenario: int = 6,
    num_groups: int = 1,
    seed: int = 2025,
) -> List[List[Tuple[str, ...]]]:
    """Random *fixed-size* scenario compositions (the Fig. 12/15 protocol).

    Single model group: ``num_groups=1`` with 6 models (paper §6.1).
    Multiple groups: ``num_groups=2`` with 3 models each. For the
    variable-size sweep recipe (1–3 groups × 1–4 models) see
    :func:`sample_groups` / :mod:`repro.experiments`.

    Seed semantics: one ``random.Random(seed)`` stream drives all ``count``
    compositions, so scenario *i* depends on ``seed`` **and** on every draw
    before it; the same ``(model_names, count, models_per_scenario,
    num_groups, seed)`` tuple always reproduces the same list.
    """
    rng = random.Random(seed)
    per_group = models_per_scenario // num_groups
    out: List[List[Tuple[str, ...]]] = []
    for _ in range(count):
        chosen = rng.sample(list(model_names), models_per_scenario)
        groups = [
            tuple(chosen[g * per_group : (g + 1) * per_group])
            for g in range(num_groups)
        ]
        out.append(groups)
    return out


def build_scenario(
    name: str,
    group_model_names: Sequence[Sequence[str]],
    graph_factory: Dict[str, ModelGraph],
    arrival: Optional[ArrivalSpec] = None,
    faults: Optional[FaultSpec] = None,
) -> Scenario:
    """Materialize a scenario from model names; duplicates get unique graphs.

    ``group_model_names`` is a sequence of per-group name sequences (the
    shape produced by :func:`sample_groups` / :func:`random_scenarios`).
    ``arrival`` selects the scenario's request arrival process (``None`` =
    periodic); ``faults`` its injected fault ensemble (``None`` = clean).
    Deterministic: graph indices are assigned in iteration order.
    """
    graphs: List[ModelGraph] = []
    groups: List[Tuple[int, ...]] = []
    for gnames in group_model_names:
        ids = []
        for n in gnames:
            ids.append(len(graphs))
            graphs.append(graph_factory[n])
        groups.append(tuple(ids))
    return Scenario(name=name, graphs=tuple(graphs), groups=tuple(groups),
                    arrival=arrival, faults=faults)
