"""Generation-batched evaluation engine (struct-of-arrays over solutions).

The GA evaluates whole generations (population + offspring, 40–80
candidates) and whole candidate sets (Pareto front × α lattice) against one
scenario. :class:`BatchSimulator` runs *all* of those simulations in one
numpy-vectorized event-stepping pass: every lane (one ``(solution spec,
periods, num_requests, noise seed, arrival spec)`` tuple) advances in
lock-step over a shared event frontier — each iteration pops the earliest pending event of
every live lane and applies all three event classes (request arrival,
worker completion, work delivery) as masked array operations.

Exactness contract
------------------
Results are **bit-identical** to :class:`~repro.core.fastsim.FastSimulator`
(and therefore to the :class:`~repro.core.simulator.RuntimeSimulator`
reference DES) per lane, including

* heap tie-breaking: events are ordered by ``(time, push sequence)`` with a
  per-lane push counter, exactly like the per-solution heap;
* dispatch-token injection and its ``(-1, 0, release_seq)`` queue-priority
  class;
* the lognormal noise stream: per-lane ``random.Random(seed).gauss`` draws
  are consumed in delivery order and the multiplier is computed with the
  same ``math.exp`` expression (numpy's SIMD ``exp`` can differ by an ULP,
  so it is deliberately *not* used for the noise path);
* float associativity: every arithmetic expression that feeds an event
  timestamp is evaluated with the same operation order as the per-solution
  engines (IEEE-754 double ops are bit-reproducible across numpy and
  CPython).

The parity is enforced three ways: the property-based differential suite
(``tests/test_batchsim_properties.py``), the golden task traces
(``tests/test_golden_traces.py``) and the ``simspeed`` benchmark section.

Performance notes
-----------------
The lock-step pass amortizes numpy dispatch overhead across the batch
width, so its per-event cost *falls* with lane count while the per-solution
loop's stays flat. On wide batches (hundreds of lanes) it approaches the
hand-tuned per-solution loop; the measured crossover on CPU is documented
in ``BENCH_simspeed.json``. Population-level throughput beyond that comes
from the pipeline around the pass — generation dedup against the objective
cache, one shared noise table per seed, vectorized objective extraction —
and from sharding lanes across a process pool (``workers > 1``), each shard
running its own lock-step pass; sharding only engages at
``SHARD_MIN_LANES`` lanes and above — below that the fork/pickle round trip
costs more than it saves. A jitted ``jax.lax.while_loop`` port of this pass
exists as the opt-in ``engine="compiled"`` backend
(:mod:`repro.core.batchsim_compiled`): it beats this numpy tier ~2.5-3.7x
on every measured workload but *not* the per-solution scalar loop on CPU —
XLA's full-width masked iteration has a ~2 µs/lane-iter floor while the
python event loop handles an event in ~0.75 µs, and lock-step pays for the
longest lane, not the mean. The measured crossover is recorded in
``BENCH_simspeed.json`` and ARCHITECTURE.md §engines; the bit-exact numpy
path therefore stays the default.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .arrivals import ArrivalSpec, arrival_horizon, draw_arrivals
from .fastsim import FastSimSpec
from .faults import FaultSpec, FaultStream
from .processors import Processor
from .simulator import NoiseModel, RequestRecord, SimResult, TaskRecord

# queue-priority packing: (class, priority, release_seq) -> one int64.
# class 0 = dispatch token (reference priority (-1, 0, seq)), 1 = real task
# (reference (0, prio, seq) / lean (prio, seq) — same relative order).
_CLS_SHIFT = np.int64(1) << 53
_PRIO_SHIFT = np.int64(1) << 40
_EMPTY = np.int64(1) << 62
_BIGSEQ = np.int64(1) << 62


@dataclass
class BatchLane:
    """One simulation in a batch: a prepared spec plus run-time parameters.

    Mirrors :class:`~repro.core.fastsim.FastSimulator`'s constructor
    arguments; ``noise_seed=None`` runs the lane clean (no draws), matching
    ``noise=None``. ``dispatch_overhead`` may differ per lane (the analyzer
    mixes clean search evals and measured accurate evals in one batch), as
    may the ``arrivals`` process (``None`` = periodic).
    """

    spec: FastSimSpec
    periods: Sequence[float]
    num_requests: int = 20
    noise: Optional[NoiseModel] = None
    dispatch_overhead: float = 0.0
    dispatch_pid: int = 0
    overlap_comm: bool = False
    arrivals: Optional[ArrivalSpec] = None
    faults: Optional[FaultSpec] = None


@dataclass
class BatchResult:
    """Per-lane request/busy arrays plus :class:`SimResult` reconstruction."""

    lanes: Sequence[BatchLane]
    groups: Sequence[Sequence[int]]
    num_requests: np.ndarray      # (W,) int64
    arrival: np.ndarray           # (W, R) float64
    first_start: np.ndarray       # (W, R)
    last_finish: np.ndarray       # (W, R)
    done: np.ndarray              # (W, R) int64
    group_tasks: np.ndarray       # (W, G) int64
    busy: np.ndarray              # (W, P) float64
    horizon: np.ndarray           # (W,) float64
    pids: Sequence[int]
    nr_max: int
    tasks: Optional[List[List[TaskRecord]]] = None

    @property
    def width(self) -> int:
        return len(self.lanes)

    def makespans(self, lane: int, group: Optional[int] = None) -> List[float]:
        """Per-request makespans of one lane, reference ordering."""
        out: List[float] = []
        nr = int(self.num_requests[lane])
        for gid in range(len(self.groups)):
            if group is not None and gid != group:
                continue
            for rid in range(nr):
                rr = gid * self.nr_max + rid
                if self.done[lane, rr] < self.group_tasks[lane, gid]:
                    out.append(float("inf"))
                else:
                    out.append(
                        self.last_finish[lane, rr]
                        - min(self.first_start[lane, rr], self.arrival[lane, rr])
                    )
        return out

    def result(self, lane: int) -> SimResult:
        """Reconstruct the lane's :class:`SimResult` (golden-trace fidelity)."""
        requests: List[RequestRecord] = []
        nr = int(self.num_requests[lane])
        for gid in range(len(self.groups)):
            for rid in range(nr):
                rr = gid * self.nr_max + rid
                requests.append(RequestRecord(
                    group=gid, request=rid,
                    arrival=float(self.arrival[lane, rr]),
                    first_start=float(self.first_start[lane, rr]),
                    last_finish=float(self.last_finish[lane, rr]),
                    done_tasks=int(self.done[lane, rr]),
                    total_tasks=int(self.group_tasks[lane, gid]),
                ))
        return SimResult(
            requests=requests,
            tasks=list(self.tasks[lane]) if self.tasks is not None else [],
            busy_time={pid: float(self.busy[lane, pid]) for pid in self.pids},
            horizon=float(self.horizon[lane]),
        )


class BatchSimulator:
    """Lock-step event engine over a batch of lanes (one shared scenario).

    All lanes must share the scenario structure (``groups`` and the
    processor set); specs, periods, request counts and noise seeds vary per
    lane. ``run()`` executes every lane to quiescence in one vectorized
    event-stepping pass and returns a :class:`BatchResult`.
    """

    def __init__(
        self,
        lanes: Sequence[BatchLane],
        groups: Sequence[Sequence[int]],
        processors: Sequence[Processor],
    ):
        if not lanes:
            raise ValueError("empty batch")
        self.lanes = list(lanes)
        self.groups = [list(g) for g in groups]
        self.processors = processors
        self.pids = [p.pid for p in processors]
        self.kind_of_pid = {p.pid: p.kind for p in processors}

    # -- batch assembly -----------------------------------------------------
    def _pad_specs(self) -> None:
        lanes = self.lanes
        W = len(lanes)
        S = max(ln.spec.num_subgraphs for ln in lanes)
        P = max(self.pids) + 1
        G = len(self.groups)
        proc_of = np.zeros((W, S), np.int64)
        prio_of = np.zeros((W, S), np.int64)
        exec_v = np.zeros((W, S))
        quant_v = np.zeros((W, S))
        comm_v = np.zeros((W, S))
        total_v = np.zeros((W, S))       # clean-lane (exec+quant)+comm
        dep_cnt = np.zeros((W, S), np.int16)
        net_of = np.zeros((W, S), np.int64)
        k_of = np.zeros((W, S), np.int64)
        dmax = 1
        jmax = 1
        for ln in lanes:
            sp = ln.spec
            n = sp.num_subgraphs
            for g in range(n):
                dmax = max(dmax, sp.succ_indptr[g + 1] - sp.succ_indptr[g])
        succ_pad = np.zeros((W, S, dmax), np.int64)
        succ_cnt = np.zeros((W, S), np.int64)
        roots_l: List[List[List[int]]] = []
        for b, ln in enumerate(lanes):
            sp = ln.spec
            n = sp.num_subgraphs
            proc_of[b, :n] = sp.proc_of
            prio_of[b, :n] = sp.prio_of
            exec_v[b, :n] = sp.exec_
            quant_v[b, :n] = sp.quant
            comm_v[b, :n] = sp.comm
            dep_cnt[b, :n] = sp.dep_count
            net_of[b, :n] = sp.net_of
            k_of[b, :n] = sp.k_of
            for g in range(n):
                lo, hi = sp.succ_indptr[g], sp.succ_indptr[g + 1]
                succ_cnt[b, g] = hi - lo
                succ_pad[b, g, :hi - lo] = sp.succ_flat[lo:hi]
            # same float expression as the per-solution loop:
            # total = exec + quant + comm (left to right)
            for g in range(n):
                total_v[b, g] = sp.exec_[g] + sp.quant[g] + (
                    0.0 if ln.overlap_comm else sp.comm[g])
            spec_roots = sp.roots()
            per_g: List[List[int]] = []
            for nets in self.groups:
                rl: List[int] = []
                for net in nets:
                    rl.extend(spec_roots[net])
                per_g.append(rl)
                jmax = max(jmax, len(rl))
            roots_l.append(per_g)
        roots = np.zeros((W, G, jmax), np.int64)
        roots_n = np.zeros((W, G), np.int64)
        group_tasks = np.zeros((W, G), np.int64)
        for b, per_g in enumerate(roots_l):
            sp = lanes[b].spec
            for gi, rl in enumerate(per_g):
                roots[b, gi, :len(rl)] = rl
                roots_n[b, gi] = len(rl)
                group_tasks[b, gi] = sum(sp.counts[n] for n in self.groups[gi])
        return (W, S, P, G, proc_of, prio_of, exec_v, quant_v, comm_v,
                total_v, dep_cnt, net_of, k_of, succ_pad, succ_cnt, dmax,
                roots, roots_n, jmax, group_tasks)

    # -- the lock-step pass -------------------------------------------------
    def run(self, collect_tasks: bool = False) -> BatchResult:
        (W, S, P, G, proc_of, prio_of, exec_v, quant_v, comm_v, total_v,
         dep_cnt, net_of, k_of, succ_pad, succ_cnt, dmax, roots, roots_n,
         jmax, group_tasks) = self._pad_specs()
        lanes = self.lanes
        groups = self.groups

        nr = np.array([ln.num_requests for ln in lanes], np.int64)
        nr_max = int(nr.max())
        periods = np.zeros((W, G))
        horizon = np.zeros(W)
        # per-lane arrival tables: the identical timestamps every other
        # engine tier draws for the lane's (arrivals, periods, num_requests)
        arrtab = np.zeros((W, G, max(nr_max, 1)))
        for b, ln in enumerate(lanes):
            periods[b] = ln.periods
            tables = draw_arrivals(ln.arrivals, ln.periods, ln.num_requests)
            for gi, tab in enumerate(tables):
                arrtab[b, gi, :len(tab)] = tab
            # same float expression as the per-solution engines
            horizon[b] = arrival_horizon(tables, ln.periods, ln.num_requests)
        dispatch_ov = np.array([ln.dispatch_overhead for ln in lanes])
        dispatch_pid = np.array([ln.dispatch_pid for ln in lanes], np.int64)
        dispatch_known = (dispatch_ov > 0) & np.isin(dispatch_pid,
                                                     np.array(self.pids))
        any_dispatch = bool(dispatch_known.any())

        # per-lane noise state: sigma/mu per pid, a standard-normal table
        # drawn from random.Random(seed).gauss (parameter-independent, so
        # the k-th draw matches the per-solution stream exactly), and a
        # cursor of consumed draws.
        noisy = np.zeros(W, bool)
        sigma_of = np.zeros((W, P))
        mu_of = np.zeros((W, P))
        rngs: List[Optional[random.Random]] = [None] * W
        for b, ln in enumerate(lanes):
            if ln.noise is not None:
                noisy[b] = True
                rngs[b] = random.Random(ln.noise.seed)
                for p in self.processors:
                    s = ln.noise.sigma(p.kind)
                    sigma_of[b, p.pid] = s
                    mu_of[b, p.pid] = -0.5 * s * s
        any_noise = bool(noisy.any())
        # One standard-normal draw is consumed per delivered task on a
        # noisy processor, and deliveries are bounded by the total task
        # count, so the whole per-lane stream can be drawn upfront (the
        # per-solution loop draws the identical values lazily). An overrun
        # is impossible by construction; if the bound were ever violated the
        # table index would raise loudly rather than desynchronize streams.
        zpos = np.zeros(W, np.int64)
        zcap = 1
        for b, ln in enumerate(lanes):
            if noisy[b]:
                zcap = max(zcap, ln.num_requests *
                           sum(ln.spec.counts[n]
                               for nets in self.groups for n in nets))
        ztab = np.zeros((W, zcap))
        for b in np.nonzero(noisy)[0]:
            rng = rngs[b]
            bound = lanes[b].num_requests * sum(
                lanes[b].spec.counts[n] for nets in self.groups for n in nets)
            ztab[b, :bound] = [rng.gauss(0.0, 1.0) for _ in range(bound)]

        # Per-lane fault streams, sampled scalar-side at delivery. The
        # lock-step drain visits each lane's deliveries in ring (= push
        # sequence) order — the same per-lane delivery order the scalar
        # engines walk, and the property the noise cursors already rely
        # on — so a live random.Random stream stays aligned; faulted
        # elements recompute exec/total with the scalar float expressions
        # for bit parity.
        fstreams: List[Optional[FaultStream]] = [None] * W
        for b, ln in enumerate(lanes):
            if ln.faults is not None and not ln.faults.empty:
                fstreams[b] = FaultStream(ln.faults)
        faulted = np.array([fs is not None for fs in fstreams], bool)
        any_fault = bool(faulted.any())

        # event frontier: per-lane candidate (time, seq) columns — one per
        # request source, one per worker completion, one for the head of the
        # pending-delivery ring. argmin over columns + seq tie-break = the
        # per-solution heap's (time, seq) pop.
        C = G + P + 1
        times = np.full((W, C), np.inf)
        seqs = np.full((W, C), _BIGSEQ, np.int64)
        seq = np.zeros(W, np.int64)
        rel_seq = np.zeros(W, np.int64)
        src_rid = np.zeros((W, G), np.int64)
        for gi in range(G):
            times[:, gi] = 0.0
            seqs[:, gi] = seq
            seq += 1
        idle = np.zeros((W, P), bool)
        idle[:, self.pids] = True
        end_g = np.full((W, P), -2, np.int64)
        end_rr = np.full((W, P), -1, np.int64)
        end_rec = np.full((W, P), -1, np.int64)

        R = G * nr_max
        arrival = np.zeros((W, R))
        first_start = np.full((W, R), np.inf)
        last_finish = np.zeros((W, R))
        done = np.zeros((W, R), np.int64)
        pend = np.zeros((W, R, S), np.int16)
        busy = np.zeros((W, P))

        # per-(lane, pid) ready queues: packed priority keys + payloads.
        # Capacity grows on demand; starts at a bound comfortable for GA
        # workloads (queues only grow under persistent overload). ``qn``
        # counts filled slots so emptiness/overflow checks stay O(1).
        QC = 32
        qkey = np.full((W, P, QC), _EMPTY, np.int64)
        qg = np.full((W, P, QC), -1, np.int64)
        qrr = np.full((W, P, QC), -1, np.int64)
        qrec = np.full((W, P, QC), -1, np.int64)
        qn = np.zeros((W, P), np.int64)
        overlap = np.array([ln.overlap_comm for ln in lanes], bool)

        K = P + 1  # pending deliveries mark their worker busy: at most P
        del_seq = np.full((W, K), _BIGSEQ, np.int64)
        del_pid = np.zeros((W, K), np.int64)
        del_g = np.zeros((W, K), np.int64)
        del_rr = np.zeros((W, K), np.int64)
        del_rec = np.full((W, K), -1, np.int64)
        del_n = np.zeros(W, np.int64)

        # optional task-trace collection (golden tests): python-side lists,
        # appended in release order like the reference engines.
        tasks: Optional[List[List[TaskRecord]]] = (
            [[] for _ in range(W)] if collect_tasks else None)

        def grow_queues() -> None:
            nonlocal qkey, qg, qrr, qrec, QC
            QC2 = QC * 2
            nk = np.full((W, P, QC2), _EMPTY, np.int64)
            nk[:, :, :QC] = qkey
            ng = np.full((W, P, QC2), -1, np.int64)
            ng[:, :, :QC] = qg
            nrr = np.full((W, P, QC2), -1, np.int64)
            nrr[:, :, :QC] = qrr
            nrec = np.full((W, P, QC2), -1, np.int64)
            nrec[:, :, :QC] = qrec
            qkey, qg, qrr, qrec, QC = nk, ng, nrr, nrec, QC2

        def append_deliver(bi: np.ndarray, pid: np.ndarray, g: np.ndarray,
                           rr: np.ndarray, rec: Optional[np.ndarray],
                           t: np.ndarray) -> None:
            """Hand items to (idle, now-busy) workers: push deliver events."""
            idle[bi, pid] = False
            pos = del_n[bi]
            del_seq[bi, pos] = seq[bi]
            del_pid[bi, pos] = pid
            del_g[bi, pos] = g
            del_rr[bi, pos] = rr
            if rec is not None:
                del_rec[bi, pos] = rec
            was_empty = pos == 0
            del_n[bi] += 1
            seq[bi] += 1
            we = bi[was_empty]
            if we.size:
                times[we, C - 1] = t[was_empty]
                seqs[we, C - 1] = del_seq[we, 0]

        def queue_push(bi: np.ndarray, pid: np.ndarray, key: np.ndarray,
                       g: np.ndarray, rr: np.ndarray,
                       rec: Optional[np.ndarray]) -> None:
            while qn[bi, pid].max() >= QC:
                grow_queues()
            slot = np.argmax(qkey[bi, pid] == _EMPTY, axis=1)
            qkey[bi, pid, slot] = key
            qg[bi, pid, slot] = g
            qrr[bi, pid, slot] = rr
            qn[bi, pid] += 1
            if rec is not None:
                qrec[bi, pid, slot] = rec

        def release(bi: np.ndarray, g: np.ndarray, rr: np.ndarray,
                    gid: np.ndarray, rid: np.ndarray,
                    t: np.ndarray) -> None:
            """Release one task per lane of ``bi`` (reference `release()`)."""
            rec = None
            if collect_tasks:
                rec = np.empty(len(bi), np.int64)
                for i, b in enumerate(bi):
                    lane_tasks = tasks[b]
                    rec[i] = len(lane_tasks)
                    lane_tasks.append(TaskRecord(
                        group=int(gid[i]), request=int(rid[i]),
                        network=int(net_of[b, g[i]]),
                        sg_index=int(k_of[b, g[i]]),
                        processor=int(proc_of[b, g[i]]),
                        released=float(t[i]),
                    ))
            if any_dispatch:
                dk = dispatch_known[bi]
                db = bi[dk]
                if db.size:
                    rel_seq[db] += 1
                    dpid = dispatch_pid[db]
                    d_idle = idle[db, dpid]
                    di = db[d_idle]
                    if di.size:
                        append_deliver(di, dpid[d_idle],
                                       np.full(di.size, -1, np.int64),
                                       np.full(di.size, -1, np.int64),
                                       None, t[dk][d_idle])
                    qi = db[~d_idle]
                    if qi.size:
                        queue_push(qi, dpid[~d_idle], rel_seq[qi],
                                   np.full(qi.size, -1, np.int64),
                                   np.full(qi.size, -1, np.int64), None)
            rel_seq[bi] += 1
            pid = proc_of[bi, g]
            is_idle = idle[bi, pid]
            di = bi[is_idle]
            if di.size:
                append_deliver(di, pid[is_idle], g[is_idle], rr[is_idle],
                               rec[is_idle] if rec is not None else None,
                               t[is_idle])
            qi = bi[~is_idle]
            if qi.size:
                key = (_CLS_SHIFT + prio_of[qi, g[~is_idle]] * _PRIO_SHIFT
                       + rel_seq[qi])
                queue_push(qi, pid[~is_idle], key, g[~is_idle], rr[~is_idle],
                           rec[~is_idle] if rec is not None else None)

        def pull_next(bi: np.ndarray, pid: np.ndarray,
                      t: np.ndarray) -> None:
            """Workers that just finished pop their queues or go idle."""
            has = qn[bi, pid] > 0
            hb, hp = bi[has], pid[has]
            if hb.size:
                slot = qkey[hb, hp].argmin(axis=1)
                g = qg[hb, hp, slot]
                rr = qrr[hb, hp, slot]
                rec = qrec[hb, hp, slot]
                qkey[hb, hp, slot] = _EMPTY
                qn[hb, hp] -= 1
                # the worker stays busy while its deliver is pending;
                # append_deliver keeps idle False.
                append_deliver(hb, hp, g, rr,
                               rec if collect_tasks else None, t[has])
            ib, ip = bi[~has], pid[~has]
            if ib.size:
                idle[ib, ip] = True

        while True:
            # -- frontier selection: per-lane earliest (time, seq) event ----
            tmin = np.min(times, axis=1)
            smask = np.where(times == tmin[:, None], seqs, _BIGSEQ)
            ci = smask.argmin(axis=1)
            act = tmin <= horizon
            if not act.any():
                break
            now = tmin

            # -- request arrivals ------------------------------------------
            bi = np.nonzero(act & (ci < G))[0]
            if bi.size:
                gid = ci[bi]
                rid = src_rid[bi, gid]
                t = now[bi]
                # rid-0 deferral: a non-zero first arrival re-arms the source
                # column (the reference source's init-then-timeout order)
                a0 = arrtab[bi, gid, 0]
                defer = (rid == 0) & (a0 > t)
                db = bi[defer]
                if db.size:
                    dg = gid[defer]
                    td = t[defer]
                    times[db, dg] = td + (a0[defer] - td)
                    seqs[db, dg] = seq[db]
                    seq[db] += 1
                    bi, gid, rid, t = (bi[~defer], gid[~defer], rid[~defer],
                                       t[~defer])
            if bi.size:
                rr = gid * nr_max + rid
                arrival[bi, rr] = t
                pend[bi, rr] = dep_cnt[bi]
                for j in range(jmax):
                    mj = j < roots_n[bi, gid]
                    if not mj.any():
                        break
                    bj = bi[mj]
                    release(bj, roots[bi, gid, j][mj], rr[mj], gid[mj],
                            rid[mj], t[mj])
                nrid = rid + 1
                has = nrid < nr[bi]
                hb = bi[has]
                if hb.size:
                    hg = gid[has]
                    tn = t[has]
                    arr = arrtab[hb, hg, nrid[has]]
                    # reference: push(.., now + (arrival - now), ..)
                    times[hb, hg] = tn + (arr - tn)
                    seqs[hb, hg] = seq[hb]
                    seq[hb] += 1
                    src_rid[hb, hg] = nrid[has]
                xb = bi[~has]
                if xb.size:
                    times[xb, gid[~has]] = np.inf
                    seqs[xb, gid[~has]] = _BIGSEQ

            # -- worker completions ----------------------------------------
            bi = np.nonzero(act & (ci >= G) & (ci < G + P))[0]
            if bi.size:
                pid = ci[bi] - G
                g = end_g[bi, pid]
                rr = end_rr[bi, pid]
                t = now[bi]
                if collect_tasks:
                    for i, b in enumerate(bi):
                        ri = end_rec[b, pid[i]]
                        if ri >= 0:
                            tasks[b][ri].finished = float(t[i])
                    end_rec[bi, pid] = -1
                real = g >= 0  # dispatch-token completions carry no task
                rb = bi[real]
                if rb.size:
                    rrr = rr[real]
                    done[rb, rrr] += 1
                    last_finish[rb, rrr] = np.maximum(
                        last_finish[rb, rrr], t[real])
                    gr = g[real]
                    gid_r = rrr // nr_max
                    rid_r = rrr - gid_r * nr_max
                    for j in range(dmax):
                        mj = j < succ_cnt[rb, gr]
                        if not mj.any():
                            break
                        bj = rb[mj]
                        sj = succ_pad[rb, gr, j][mj]
                        rrj = rrr[mj]
                        pj = pend[bj, rrj, sj] - np.int16(1)
                        pend[bj, rrj, sj] = pj
                        zero = pj == 0
                        if zero.any():
                            release(bj[zero], sj[zero], rrj[zero],
                                    gid_r[mj][zero], rid_r[mj][zero],
                                    t[real][mj][zero])
                times[bi, G + pid] = np.inf
                seqs[bi, G + pid] = _BIGSEQ
                end_g[bi, pid] = -2
                pull_next(bi, pid, t)

            # -- delivery drain: all pending deliveries of selected lanes --
            # When a lane's earliest event is its delivery-ring head, every
            # pending delivery of that lane precedes all other events (they
            # share the current time and carry the smallest sequence
            # numbers), so the whole ring drains in ring (= seq) order.
            bi = np.nonzero(act & (ci == C - 1))[0]
            if bi.size:
                t = now[bi]
                nact = int(del_n[bi].max())
                for j in range(nact):
                    mj = j < del_n[bi]
                    bj = bi[mj]
                    pidj = del_pid[bj, j]
                    gj = del_g[bj, j]
                    rrj = del_rr[bj, j]
                    tj = t[mj]
                    disp = gj < 0
                    db = bj[disp]
                    if db.size:
                        ov = dispatch_ov[db]
                        busy[db, pidj[disp]] += ov
                        times[db, G + pidj[disp]] = tj[disp] + ov
                        seqs[db, G + pidj[disp]] = seq[db]
                        seq[db] += 1
                        end_g[db, pidj[disp]] = -1
                    rb = bj[~disp]
                    if rb.size:
                        pidr = pidj[~disp]
                        gr = gj[~disp]
                        rrr = rrj[~disp]
                        tr = tj[~disp]
                        exec_t = exec_v[rb, gr]
                        total = total_v[rb, gr]
                        if any_noise:
                            draw = noisy[rb] & (sigma_of[rb, pidr] > 0.0)
                            nb = rb[draw]
                            if nb.size:
                                sg = sigma_of[nb, pidr[draw]]
                                z = ztab[nb, zpos[nb]]
                                zpos[nb] += 1
                                arg = mu_of[nb, pidr[draw]] + z * sg
                                mult = np.array(
                                    [math.exp(a) for a in arg.tolist()])
                                et = exec_t[draw] * mult
                                exec_t = exec_t.copy()
                                exec_t[draw] = et
                                tt = total.copy()
                                # same order as the scalar loop:
                                # exec + quant + (0 | comm)
                                cmv = np.where(
                                    overlap[nb], 0.0, comm_v[nb, gr[draw]])
                                tt[draw] = et + quant_v[nb, gr[draw]] + cmv
                                total = tt
                        if any_fault:
                            fmask = faulted[rb]
                            if fmask.any():
                                exec_t = exec_t.copy()
                                total = total.copy()
                                for i in np.nonzero(fmask)[0]:
                                    b = int(rb[i])
                                    et, stall = fstreams[b].service(
                                        int(pidr[i]), float(tr[i]),
                                        float(exec_t[i]))
                                    # scalar float order of the per-solution
                                    # loop: exec + quant + (0 | comm), then
                                    # stall + total
                                    cmv = (0.0 if overlap[b]
                                           else float(comm_v[b, gr[i]]))
                                    tt = et + float(quant_v[b, gr[i]]) + cmv
                                    if stall > 0.0:
                                        tt = stall + tt
                                    exec_t[i] = et
                                    total[i] = tt
                        if collect_tasks:
                            for i, b in enumerate(rb):
                                ri = del_rec[b, j]
                                if ri >= 0:
                                    trec = tasks[b][ri]
                                    trec.comm_time = float(comm_v[b, gr[i]])
                                    trec.quant_time = float(quant_v[b, gr[i]])
                                    trec.exec_time = float(exec_t[i])
                                    trec.started = float(tr[i])
                                end_rec[b, pidr[i]] = ri
                        first_start[rb, rrr] = np.minimum(
                            first_start[rb, rrr], tr)
                        if any_fault:
                            # permanent-dropout stalls are infinite: the
                            # worker's completion never fires (identical to
                            # the scalar engines) and busy must not go inf
                            fin = np.isfinite(total)
                            busy[rb[fin], pidr[fin]] += total[fin]
                        else:
                            busy[rb, pidr] += total
                        times[rb, G + pidr] = tr + total
                        seqs[rb, G + pidr] = seq[rb]
                        seq[rb] += 1
                        end_g[rb, pidr] = gr
                        end_rr[rb, pidr] = rrr
                del_seq[bi] = _BIGSEQ
                del_rec[bi] = -1
                del_n[bi] = 0
                times[bi, C - 1] = np.inf
                seqs[bi, C - 1] = _BIGSEQ

        return BatchResult(
            lanes=lanes, groups=groups, num_requests=nr, arrival=arrival,
            first_start=first_start, last_finish=last_finish, done=done,
            group_tasks=group_tasks, busy=busy, horizon=horizon,
            pids=self.pids, nr_max=nr_max, tasks=tasks,
        )


# -- batched objective extraction -------------------------------------------

def batch_objectives(
    result: BatchResult,
    cap: float = 1e6,
) -> List[Tuple[float, ...]]:
    """Per-lane GA objectives, bit-identical to ``StaticAnalyzer.objectives``.

    For every lane and model group: (mean makespan, 90th-percentile
    makespan), makespans capped at ``cap`` first (the analyzer's finite
    stand-in for dropped requests). Uses the same sequential-sum mean and
    interpolated percentile as the scalar code path — ``np.mean``'s pairwise
    summation would differ in the last ulp.
    """
    from .scoring import percentile

    out: List[Tuple[float, ...]] = []
    G = len(result.groups)
    for lane in range(result.width):
        objs: List[float] = []
        for gid in range(G):
            ms = [min(m, cap) for m in result.makespans(lane, gid)]
            objs.append(sum(ms) / len(ms))
            objs.append(percentile(ms, 90.0))
        out.append(tuple(objs))
    return out


# -- process-pool sharding ---------------------------------------------------

#: Minimum lane count before ``run_batch`` actually shards across worker
#: processes. Below this width the in-process lock-step pass wins: at GA
#: widths (~80 lanes) the measured sharded path is *slower* than in-process
#: (BENCH_simspeed.json: ``eval_us_batch_sharded`` 6053 vs
#: ``eval_us_batch_inprocess`` 4062 µs — pickling lanes + stitching results
#: costs more than the pass itself), so ``batch_workers > 1`` silently fell
#: into a regression. The threshold is recorded alongside both measurements
#: in the simspeed section; pass ``shard_min_lanes=0`` to force sharding.
SHARD_MIN_LANES = 256


def _run_shard(args: Tuple) -> Tuple:
    """Worker entry: run one lock-step pass over a shard of lanes."""
    lanes, groups, processors, collect_tasks = args
    res = BatchSimulator(lanes, groups, processors).run(
        collect_tasks=collect_tasks)
    return (res.num_requests, res.arrival, res.first_start, res.last_finish,
            res.done, res.group_tasks, res.busy, res.horizon, res.tasks,
            res.nr_max)


def run_batch(
    lanes: Sequence[BatchLane],
    groups: Sequence[Sequence[int]],
    processors: Sequence[Processor],
    collect_tasks: bool = False,
    workers: int = 1,
    pool: Optional[object] = None,
    engine: str = "numpy",
    shard_min_lanes: Optional[int] = None,
) -> BatchResult:
    """Run a batch, optionally sharded across a process pool.

    Lanes are independent, so sharding changes wall-clock only — every
    lane's result is bit-identical for any ``workers``. ``pool`` may supply
    a live ``ProcessPoolExecutor`` to amortize startup across calls;
    otherwise one is created per call when ``workers > 1``. Sharding only
    engages at ``shard_min_lanes`` (default :data:`SHARD_MIN_LANES`) lanes
    and up — below the measured crossover the in-process pass is faster.

    ``engine`` selects the lock-step backend: ``"numpy"`` (default, the
    bit-exact parity tier) or ``"compiled"`` (the jitted
    ``jax.lax.while_loop`` core from :mod:`repro.core.batchsim_compiled`,
    documented float tolerance). The compiled backend runs in-process and
    transparently falls back to numpy when a lane needs features it does
    not support (``collect_tasks``) or its fixed queue capacity overflows.
    """
    if engine == "compiled" and not collect_tasks:
        from .batchsim_compiled import run_batch_compiled

        res = run_batch_compiled(lanes, groups, processors)
        if res is not None:
            return res
        # unsupported shape or capacity overflow: bit-exact numpy fallback
    elif engine not in ("numpy", "compiled"):
        raise ValueError(f"unknown batch engine {engine!r}")
    min_lanes = SHARD_MIN_LANES if shard_min_lanes is None else shard_min_lanes
    if workers <= 1 or len(lanes) < max(2 * workers, min_lanes):
        return BatchSimulator(lanes, groups, processors).run(
            collect_tasks=collect_tasks)
    from concurrent.futures import ProcessPoolExecutor

    shards: List[Sequence[BatchLane]] = [
        lanes[i::workers] for i in range(workers)]
    shards = [s for s in shards if s]
    args = [(list(s), groups, processors, collect_tasks) for s in shards]
    own_pool = pool is None
    if own_pool:
        pool = ProcessPoolExecutor(max_workers=len(shards))
    try:
        parts = list(pool.map(_run_shard, args))
    finally:
        if own_pool:
            pool.shutdown()

    # stitch interleaved shards back into lane order
    W = len(lanes)
    G = len(groups)
    nr_max = max(p[9] for p in parts)
    R = G * nr_max
    P = max(p.pid for p in processors) + 1
    nr = np.zeros(W, np.int64)
    arrival = np.zeros((W, R))
    first_start = np.full((W, R), np.inf)
    last_finish = np.zeros((W, R))
    done = np.zeros((W, R), np.int64)
    group_tasks = np.zeros((W, G), np.int64)
    busy = np.zeros((W, P))
    horizon = np.zeros(W)
    tasks: Optional[List[List[TaskRecord]]] = (
        [[] for _ in range(W)] if collect_tasks else None)
    for si, part in enumerate(parts):
        (p_nr, p_arr, p_fs, p_lf, p_done, p_gt, p_busy, p_hor, p_tasks,
         p_nrm) = part
        lane_ids = list(range(si, W, len(parts)))[:p_nr.shape[0]]
        for li, b in enumerate(lane_ids):
            nr[b] = p_nr[li]
            for gid in range(G):
                lo_s, lo_d = gid * p_nrm, gid * nr_max
                n = int(p_nr[li])
                arrival[b, lo_d:lo_d + n] = p_arr[li, lo_s:lo_s + n]
                first_start[b, lo_d:lo_d + n] = p_fs[li, lo_s:lo_s + n]
                last_finish[b, lo_d:lo_d + n] = p_lf[li, lo_s:lo_s + n]
                done[b, lo_d:lo_d + n] = p_done[li, lo_s:lo_s + n]
            group_tasks[b] = p_gt[li]
            busy[b] = p_busy[li]
            horizon[b] = p_hor[li]
            if collect_tasks:
                tasks[b] = p_tasks[li]
    return BatchResult(
        lanes=lanes, groups=[list(g) for g in groups], num_requests=nr,
        arrival=arrival, first_start=first_start, last_finish=last_finish,
        done=done, group_tasks=group_tasks, busy=busy, horizon=horizon,
        pids=[p.pid for p in processors], nr_max=nr_max, tasks=tasks,
    )
