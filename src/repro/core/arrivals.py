"""Pluggable arrival processes for the request sources of every engine tier.

The paper's evaluation drives each model group with a strictly periodic
source (arrival_i = i·Φ). Real mobile traffic is burstier — sensor
pipelines jitter, event-driven models (voice, touch) arrive Poisson-like,
and replayed field traces follow neither — and both the multi-DNN
co-execution literature (arXiv:2503.21109) and the mobile-processor
variability study (arXiv:2405.01851) treat arrival structure as a
first-class workload axis. This module generalizes the request sources
into one shared, seeded arrival-timestamp generator that all **four**
engine tiers consume identically:

* :class:`~repro.core.simulator.RuntimeSimulator` (reference DES),
* :class:`~repro.core.fastsim.FastSimulator` (lean + full loops),
* :class:`~repro.core.batchsim.BatchSimulator` (lock-step lanes),
* the virtual-clock :class:`~repro.runtime.PuzzleRuntime`
  (``run_periodic``).

Supported processes (:class:`ArrivalSpec.kind`):

``periodic``
    ``arrival_i = i · Φ`` — the paper's sources and the default. Draws
    nothing from the RNG and reproduces the pre-arrival-layer engines
    byte for byte (same ``int · float`` expression, same event times).
``jittered``
    Periodic base plus per-request jitter. ``distribution="uniform"``
    offsets each arrival by ``U(−j·Φ, +j·Φ)`` with ``j = jitter``;
    ``distribution="lognormal"`` *delays* each arrival by a mean-one
    lognormal (shape ``sigma``) scaled to ``j·Φ`` — the §6.3 noise shape
    applied to the traffic instead of the execution times.
``poisson``
    Exponential inter-arrivals at rate ``1/Φ`` (first request at t = 0),
    so the mean load matches the periodic source at the same α while the
    instantaneous load is bursty.
``trace``
    Explicit per-group timestamp lists (JSON-serializable), replayed
    verbatim. Shorter traces are extended periodically past their last
    timestamp; longer ones are truncated to ``num_requests``.

Exactness contract
------------------
:func:`draw_arrivals` is the *single* source of arrival timestamps: every
tier calls it with the same ``(spec, periods, num_requests)`` and receives
the same floats, drawn from one seeded ``random.Random(spec.seed)``
consumed in a fixed order (group-major, request-minor — the same
convention as the engines' shared noise stream). The engines then schedule
each source event through the same float recurrence the periodic sources
always used (``next_time = now + (arrival − now)``), so their event heaps
stay bit-identical to the last ulp.

Two invariants make that recurrence safe for arbitrary processes and are
enforced here rather than in the four engines:

* arrivals are **non-negative** (the first timestamp is clamped to 0.0);
* the *realized event-time chain* ``t_e(i) = t_e(i−1) + (a_i − t_e(i−1))``
  is **strictly increasing** — raw timestamps that would regress or tie
  (possible under wide uniform jitter or adversarial traces) are bumped to
  ``math.nextafter`` of the previous realized time. Without this, the
  reference DES would clamp a late arrival to ``env.now`` synchronously
  while the heap-based tiers would push a stale event, and parity would
  break exactly one ulp at a time.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

ARRIVAL_KINDS = ("periodic", "jittered", "poisson", "trace")


@dataclass(frozen=True)
class ArrivalSpec:
    """Replayable identity of one arrival process.

    Frozen + hashable so it can participate in evaluation-cache keys
    (:meth:`key`) and in frozen scenario specs. ``seed`` feeds the one
    shared ``random.Random`` stream; two equal specs always draw identical
    timestamps for the same ``(periods, num_requests)``.
    """

    kind: str = "periodic"
    #: jittered: max offset (uniform) / mean delay (lognormal) as a
    #: fraction of the group period Φ
    jitter: float = 0.1
    #: jittered: "uniform" (bounded ±jitter·Φ) or "lognormal" (mean-one
    #: lognormal delay of shape ``sigma``, scaled to jitter·Φ)
    distribution: str = "uniform"
    sigma: float = 0.25
    seed: int = 0
    #: trace: per-group timestamp tuples (seconds); required iff
    #: ``kind == "trace"``
    trace: Optional[Tuple[Tuple[float, ...], ...]] = None

    def __post_init__(self) -> None:
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(
                f"unknown arrival kind {self.kind!r}; expected one of "
                f"{ARRIVAL_KINDS}")
        if self.distribution not in ("uniform", "lognormal"):
            raise ValueError(
                f"unknown jitter distribution {self.distribution!r}")
        if self.kind == "trace" and self.trace is None:
            raise ValueError("trace arrivals need explicit timestamps")
        # canonicalize fields the kind does not consume, so equality,
        # hashing, cache keys and JSON round-trips all agree on one
        # representation per process
        if self.kind != "jittered":
            object.__setattr__(self, "jitter", 0.0)
            object.__setattr__(self, "distribution", "uniform")
            object.__setattr__(self, "sigma", 0.0)
        elif self.distribution == "uniform":
            object.__setattr__(self, "sigma", 0.0)
        if self.kind != "trace":
            object.__setattr__(self, "trace", None)
        if self.trace is not None:
            # normalize to tuples so the spec stays hashable after
            # from_json (lists) or direct construction with sequences
            object.__setattr__(
                self, "trace", tuple(tuple(float(t) for t in g)
                                     for g in self.trace))

    def key(self) -> Tuple:
        """Hashable content key for evaluation caches.

        An arrival spec *must* participate in any cache key derived from a
        simulation (the analyzer's objective memo, batched dedup) — two
        runs of the same solution under different arrivals produce
        different results, and a key without the arrival axis would
        silently serve one process's results for the other.
        """
        return (self.kind, self.jitter, self.distribution, self.sigma,
                self.seed, self.trace)

    def to_json(self) -> Dict[str, object]:
        doc: Dict[str, object] = {"kind": self.kind, "seed": self.seed}
        if self.kind == "jittered":
            doc["jitter"] = self.jitter
            doc["distribution"] = self.distribution
            if self.distribution == "lognormal":
                doc["sigma"] = self.sigma
        if self.trace is not None:
            doc["trace"] = [list(g) for g in self.trace]
        return doc

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "ArrivalSpec":
        return cls(
            kind=str(d.get("kind", "periodic")),
            jitter=float(d.get("jitter", 0.1)),
            distribution=str(d.get("distribution", "uniform")),
            sigma=float(d.get("sigma", 0.25)),
            seed=int(d.get("seed", 0)),
            trace=(tuple(tuple(float(t) for t in g) for g in d["trace"])
                   if d.get("trace") is not None else None),
        )


#: The default process. ``None`` everywhere means "periodic": the engines
#: treat both identically and the default path stays byte-for-byte what it
#: was before the arrival layer existed.
PERIODIC = ArrivalSpec()


def _raw_timestamps(
    spec: ArrivalSpec,
    gid: int,
    period: float,
    num_requests: int,
    rng: random.Random,
) -> List[float]:
    """Unclamped per-group timestamps; RNG consumed request-minor."""
    if spec.kind == "periodic":
        return [rid * period for rid in range(num_requests)]
    if spec.kind == "jittered":
        out = []
        for rid in range(num_requests):
            if spec.distribution == "uniform":
                off = (2.0 * rng.random() - 1.0) * spec.jitter * period
            else:
                # mean-one lognormal delay (same shape as the §6.3
                # execution-noise multiplier), scaled to jitter·Φ
                off = spec.jitter * period * math.exp(
                    rng.gauss(-0.5 * spec.sigma * spec.sigma, spec.sigma))
            out.append(rid * period + off)
        return out
    if spec.kind == "poisson":
        out = []
        t = 0.0
        for rid in range(num_requests):
            out.append(t)
            if rid + 1 < num_requests and period > 0.0:
                t = t + rng.expovariate(1.0 / period)
        return out
    # trace: replay verbatim; extend periodically past the last timestamp
    # (an empty group trace degenerates to the periodic lattice from t=0),
    # truncate past num_requests
    tab = list(spec.trace[gid]) if gid < len(spec.trace) else []
    while len(tab) < num_requests:
        tab.append(tab[-1] + period if tab else 0.0)
    return tab[:num_requests]


def draw_arrivals(
    spec: Optional[ArrivalSpec],
    periods: Sequence[float],
    num_requests: int,
) -> List[List[float]]:
    """Per-group arrival timestamps, identical for every engine tier.

    One ``random.Random(spec.seed)`` stream drives all groups, consumed
    group-major then request-minor (the engines' noise-stream convention),
    so group *g*'s timestamps depend on the draws of groups ``< g`` — the
    whole table is a pure function of ``(spec, periods, num_requests)``.

    The returned timestamps are non-negative and chosen so the realized
    event-time chain ``t_e(i) = t_e(i−1) + (a_i − t_e(i−1))`` — the exact
    float recurrence every engine's source uses — is strictly increasing
    (see the module docstring). ``spec=None`` means periodic.
    """
    if spec is None:
        spec = PERIODIC
    rng = random.Random(spec.seed)
    tables: List[List[float]] = []
    for gid, period in enumerate(periods):
        raw = _raw_timestamps(spec, gid, period, num_requests, rng)
        out: List[float] = []
        prev_te: Optional[float] = None
        for t in raw:
            if prev_te is None:
                t = max(t, 0.0)
                te = t
            else:
                if t <= prev_te:
                    t = math.nextafter(prev_te, math.inf)
                te = prev_te + (t - prev_te)
                while te <= prev_te:  # pathological rounding: bump again
                    t = math.nextafter(t, math.inf)
                    te = prev_te + (t - prev_te)
            out.append(t)
            prev_te = te
        tables.append(out)
    return tables


def arrival_horizon(
    tables: Sequence[Sequence[float]],
    periods: Sequence[float],
    num_requests: int,
) -> float:
    """Quiescence horizon shared by all engine tiers.

    For periodic arrivals this returns the engines' historical expression
    ``max((num_requests + 2) · max(periods) · 4.0, 1.0)`` **unchanged**
    (same floats, so default-path results stay byte-identical). Bursty or
    traced arrivals can push the last request past that window, so the
    horizon is extended to the last arrival plus the same relative slack
    (``8 · max(periods)``) whenever that is later — every tier computes
    this from the same tables, so overloaded schedules drop the same
    requests everywhere.
    """
    base = max((num_requests + 2) * max(periods) * 4.0, 1.0)
    last = 0.0
    for tab in tables:
        if tab and tab[-1] > last:
            last = tab[-1]
    extra = last + max(periods) * 8.0
    return base if extra <= base else extra
