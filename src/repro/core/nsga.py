"""NSGA-II/III machinery (Deb & Jain 2013) used for population replacement.

The paper updates its population with NSGA-III (§4.3). DEAP is unavailable
offline, so this is a from-scratch implementation:

* fast non-dominated sorting,
* Das–Dennis structured reference points,
* normalization with ideal point + extreme-point intercepts,
* association + niching for the boundary front.

All objectives are minimized.
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff ``a`` Pareto-dominates ``b`` (minimization)."""
    not_worse = all(x <= y for x, y in zip(a, b))
    strictly_better = any(x < y for x, y in zip(a, b))
    return not_worse and strictly_better


def fast_non_dominated_sort(
    fits: Sequence[Sequence[float]], vectorized: bool = True
) -> List[List[int]]:
    """Return fronts (lists of indices), best front first.

    The O(M·N²) pairwise domination test is vectorized into one broadcasted
    comparison — this runs on ``pop + offspring`` every GA generation, so it
    is on the search hot path. Front peeling preserves the classic Deb
    ordering (indices within a front ascend in discovery order).
    ``vectorized=False`` selects the original pure-Python implementation,
    kept as the reference oracle (differential-tested in the suite) and for
    seed-path benchmarking.
    """
    n = len(fits)
    if n == 0:
        return []
    if vectorized:
        F = np.asarray(fits, dtype=np.float64)
        # dom[p, q] = fits[p] dominates fits[q]
        le = np.all(F[:, None, :] <= F[None, :, :], axis=2)
        lt = np.any(F[:, None, :] < F[None, :, :], axis=2)
        dom = le & lt
        dom_count = dom.sum(axis=0).tolist()   # times each q is dominated
        S: List[List[int]] = [np.flatnonzero(row).tolist() for row in dom]
    else:
        S = [[] for _ in range(n)]
        dom_count = [0] * n
        for p in range(n):
            for q in range(n):
                if p == q:
                    continue
                if dominates(fits[p], fits[q]):
                    S[p].append(q)
                elif dominates(fits[q], fits[p]):
                    dom_count[p] += 1
    fronts: List[List[int]] = [[p for p in range(n) if dom_count[p] == 0]]
    i = 0
    while fronts[i]:
        nxt: List[int] = []
        for p in fronts[i]:
            for q in S[p]:
                dom_count[q] -= 1
                if dom_count[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    fronts.pop()
    return fronts


def das_dennis(n_obj: int, divisions: int) -> List[Tuple[float, ...]]:
    """Structured reference points on the unit simplex."""
    pts: List[Tuple[float, ...]] = []

    def rec(prefix: List[float], left: int, dims: int) -> None:
        if dims == 1:
            pts.append(tuple(prefix + [left / divisions]))
            return
        for i in range(left + 1):
            rec(prefix + [i / divisions], left - i, dims - 1)

    rec([], divisions, n_obj)
    return pts


def _normalize_py(fits: List[Sequence[float]]) -> List[List[float]]:
    """Pure-Python reference for :func:`_normalize` (seed implementation)."""
    n_obj = len(fits[0])
    ideal = [min(f[k] for f in fits) for k in range(n_obj)]
    translated = [[f[k] - ideal[k] for k in range(n_obj)] for f in fits]
    intercepts = []
    for k in range(n_obj):
        weights = [1e-6] * n_obj
        weights[k] = 1.0
        ext = min(translated, key=lambda t: max(t[j] / weights[j] for j in range(n_obj)))
        intercepts.append(max(ext[k], 1e-12))
    return [[t[k] / intercepts[k] for k in range(n_obj)] for t in translated]


def _associate_py(norm: List[List[float]], refs: List[Tuple[float, ...]]
                  ) -> Tuple[List[int], List[float]]:
    """Pure-Python reference for :func:`_associate` (seed implementation)."""
    assoc, dist = [], []
    for p in norm:
        best_r, best_d = 0, float("inf")
        for r_i, r in enumerate(refs):
            rn = math.sqrt(sum(x * x for x in r)) or 1.0
            dot = sum(p[k] * r[k] for k in range(len(r))) / rn
            d2 = sum((p[k] - dot * r[k] / rn) ** 2 for k in range(len(r)))
            if d2 < best_d:
                best_d, best_r = d2, r_i
        assoc.append(best_r)
        dist.append(math.sqrt(best_d))
    return assoc, dist


def _normalize(fits: List[Sequence[float]]) -> List[List[float]]:
    """Ideal-point translation + intercept normalization (NSGA-III §IV-C)."""
    F = np.asarray(fits, dtype=np.float64)
    translated = F - F.min(axis=0)
    # extreme points via achievement scalarizing function
    n_obj = F.shape[1]
    weights = np.full((n_obj, n_obj), 1e-6)
    np.fill_diagonal(weights, 1.0)
    # asf[k, i] = max_j translated[i, j] / weights[k, j]
    asf = (translated[None, :, :] / weights[:, None, :]).max(axis=2)
    ext = translated[asf.argmin(axis=1)]            # (n_obj, n_obj)
    intercepts = np.maximum(np.diagonal(ext), 1e-12)
    # Gaussian-elimination-based hyperplane intercepts are ideal; extreme-point
    # axis values are a robust fallback that behaves identically for the 2-3
    # objective cases used here and cannot produce degenerate planes.
    return (translated / intercepts).tolist()


def _associate(norm: List[List[float]], refs: List[Tuple[float, ...]]
               ) -> Tuple[List[int], List[float]]:
    """Associate each point with its closest reference line (vectorized).

    Perpendicular distance² to the line through a unit reference ``u`` is
    ``|p|² − (p·u)²``; runs on every niching call, so it is broadcast over
    all (point, reference) pairs at once.
    """
    P = np.asarray(norm, dtype=np.float64)
    R = np.asarray(refs, dtype=np.float64)
    rn = np.sqrt((R * R).sum(axis=1))
    rn[rn == 0.0] = 1.0
    U = R / rn[:, None]
    dot = P @ U.T                                   # (n_points, n_refs)
    d2 = (P * P).sum(axis=1)[:, None] - dot * dot
    np.maximum(d2, 0.0, out=d2)                     # clamp fp cancellation
    assoc = d2.argmin(axis=1)
    dist = np.sqrt(d2[np.arange(len(norm)), assoc])
    return assoc.tolist(), dist.tolist()


def nsga3_select(
    fits: Sequence[Sequence[float]],
    k: int,
    rng: Optional[random.Random] = None,
    divisions: Optional[int] = None,
    vectorized: bool = True,
) -> List[int]:
    """Select ``k`` indices from ``fits`` by NSGA-III environmental selection."""
    rng = rng or random.Random(0)
    if k >= len(fits):
        return list(range(len(fits)))
    n_obj = len(fits[0])
    fronts = fast_non_dominated_sort(fits, vectorized=vectorized)
    chosen: List[int] = []
    last_front: List[int] = []
    for front in fronts:
        if len(chosen) + len(front) <= k:
            chosen.extend(front)
            if len(chosen) == k:
                return chosen
        else:
            last_front = front
            break
    # niche the boundary front
    if divisions is None:
        divisions = {1: 12, 2: 12, 3: 12, 4: 8, 5: 6}.get(n_obj, 4)
    refs = das_dennis(n_obj, divisions)
    pool = chosen + last_front
    fits_pool = [fits[i] for i in pool]
    if vectorized:
        norm = _normalize(list(fits_pool))
        assoc, dist = _associate(norm, refs)
    else:
        norm = _normalize_py(list(fits_pool))
        assoc, dist = _associate_py(norm, refs)
    niche_count: Dict[int, int] = {}
    for j in range(len(chosen)):
        niche_count[assoc[j]] = niche_count.get(assoc[j], 0) + 1
    candidates = list(range(len(chosen), len(pool)))  # indices into pool
    while len(chosen) < k and candidates:
        # pick the reference with the fewest members among candidate refs
        cand_refs = {assoc[c] for c in candidates}
        min_count = min(niche_count.get(r, 0) for r in cand_refs)
        ref_pool = [r for r in cand_refs if niche_count.get(r, 0) == min_count]
        r = rng.choice(sorted(ref_pool))
        members = [c for c in candidates if assoc[c] == r]
        if niche_count.get(r, 0) == 0:
            pick = min(members, key=lambda c: dist[c])  # closest to the ref line
        else:
            pick = rng.choice(sorted(members))
        chosen.append(pool[pick])
        candidates.remove(pick)
        niche_count[r] = niche_count.get(r, 0) + 1
    return chosen


def crowding_distance(fits: Sequence[Sequence[float]], front: List[int]) -> Dict[int, float]:
    """NSGA-II crowding distance (used by tests & as a tie-breaker utility)."""
    dist = {i: 0.0 for i in front}
    if len(front) <= 2:
        for i in front:
            dist[i] = float("inf")
        return dist
    n_obj = len(fits[front[0]])
    for k in range(n_obj):
        ordered = sorted(front, key=lambda i: fits[i][k])
        dist[ordered[0]] = dist[ordered[-1]] = float("inf")
        span = fits[ordered[-1]][k] - fits[ordered[0]][k] or 1.0
        for a, b, c in zip(ordered, ordered[1:], ordered[2:]):
            dist[b] += (fits[c][k] - fits[a][k]) / span
    return dist
