"""Puzzle core: the paper's contribution — GA-based multi-model scheduling."""
from .analyzer import AnalyzerConfig, StaticAnalyzer
from .arrivals import (
    ARRIVAL_KINDS,
    ArrivalSpec,
    arrival_horizon,
    draw_arrivals,
)
from .baselines import best_mapping_solutions, npu_only_solution
from .batchsim import (
    SHARD_MIN_LANES,
    BatchLane,
    BatchResult,
    BatchSimulator,
    batch_objectives,
    run_batch,
)
from .batchsim_compiled import (
    COMPILED_ABS_TOL,
    COMPILED_REL_TOL,
    run_batch_compiled,
)
from .chromosome import (
    BACKENDS,
    DTYPES,
    PlacedSubgraph,
    Solution,
    SolutionFactory,
    decode_solution,
    subgraph_processor,
    upmx,
)
from .comm import (
    PAPER_COMM_MODEL,
    TPU_COMM_MODEL,
    PiecewiseLinearCommModel,
    microbenchmark_host,
    quantization_cost,
)
from .des import Environment, PriorityStore
from .fastsim import FastSimSpec, FastSimulator, SpecBuilder, build_spec
from .faults import NO_FAULTS, FaultSpec, FaultStream
from .ga import GAConfig, GAResult, GeneticScheduler
from .graph import Edge, Layer, ModelGraph, Subgraph, branching_graph, chain_graph
from .nsga import crowding_distance, das_dennis, dominates, fast_non_dominated_sort, nsga3_select
from .processors import Processor, mobile_processors, tpu_lanes
from .profiler import (
    AnalyticMobileBackend,
    JaxExecBackend,
    LaneRooflineBackend,
    ProfileDB,
    Profiler,
    TableBackend,
    fragmentation_penalty,
)
from .scenarios import (
    Scenario,
    base_periods,
    best_model_times,
    build_scenario,
    random_scenarios,
    sample_groups,
    whole_model_placement,
)
from .scoring import (
    SaturationResult,
    absolute_deadlines,
    bisect_alpha_probes,
    deadline_satisfaction,
    group_scores,
    percentile,
    qoe_score,
    rt_score,
    saturation_multiplier,
    saturation_multiplier_bisect,
    scenario_score,
)
from .simulator import (
    NoiseModel,
    RequestRecord,
    RuntimeSimulator,
    SimResult,
    TaskRecord,
    derive_dependencies,
    subgraph_task_costs,
)

__all__ = [k for k in dir() if not k.startswith("_")]
