"""Deterministic fault injection shared by every engine tier.

Puzzle's evaluation assumes processors behave as profiled, but mobile SoCs
do not: thermal/DVFS throttling slows accelerators mid-run
(arXiv:2405.01851 measures sustained multi-× slowdowns), co-execution
contention produces heavy-tailed per-task stragglers (arXiv:2503.21109),
and drivers occasionally drop an accelerator outright. This module defines
one seeded, replayable description of such faults — :class:`FaultSpec` —
and one shared realization of it — :class:`FaultStream` — that all **four**
parity-enforced engine tiers consume identically:

* :class:`~repro.core.simulator.RuntimeSimulator` (reference DES),
* :class:`~repro.core.fastsim.FastSimulator` (full loop; the lean loop is
  bypassed whenever faults are present),
* :class:`~repro.core.batchsim.BatchSimulator` (lock-step lanes), and
* the virtual-clock :class:`~repro.runtime.PuzzleRuntime` (via
  :class:`~repro.runtime.clock.SimCostSource`).

Fault classes (:class:`FaultSpec`):

``dropouts``
    Processor ``pid`` stops serving at time ``start``; ``repair=None``
    means permanent, otherwise the processor resumes after ``repair``
    seconds. A task delivered to a dropped processor stalls until the
    repair time (forever when permanent — the request is dropped at the
    horizon, identically in every tier).
``throttles``
    Multiplicative slowdown ``factor`` (> 1 = slower) applied to every
    execution on ``pid`` that *starts* inside ``[t0, t1)`` — a piecewise-
    constant DVFS/thermal curve.
``straggler_prob`` / ``straggler_shape``
    Per-task stragglers: with probability ``p`` a delivered task's
    execution time is inflated by a Pareto(shape) multiplier ≥ 1 —
    heavy-tailed, mean-unbounded for ``shape <= 1``.

Exactness contract
------------------
The stream draws from one ``random.Random(spec.seed)``, consumed in
**global delivery order** — exactly the convention of the engines' shared
noise stream, and the reason all four tiers realize the same faults: their
delivery orders are already proven identical by the golden-trace and
differential machinery. :meth:`FaultStream.service` is the *only*
sampler; every tier calls it once per delivered real task (dispatch
tokens are exempt — they model coordinator work, not accelerator work),
after the noise multiplier and before the ``total = exec + quant + comm``
sum, and applies the returned ``stall`` as ``total = stall + total``.
Fault state is sampled at delivery time: the model is non-preemptive, so
a task that *starts* before a dropout completes normally — matching the
runtime, where an in-flight kernel cannot be recalled.

The stream itself is recovery-agnostic. Recovery (retry, backoff, the
dropout → backup-mapping remap) is a *policy* layered on the runtime and
analyzer (:mod:`repro.runtime.recovery`); parity-oracle runs inject
faults without recovery so the four tiers stay bit-comparable.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class FaultSpec:
    """Replayable identity of one fault ensemble.

    Frozen + hashable so it can join evaluation-cache keys (:meth:`key`)
    and frozen scenario specs, exactly like
    :class:`~repro.core.arrivals.ArrivalSpec`. ``seed`` feeds the one
    shared straggler stream; two equal specs always realize identical
    faults for the same delivery sequence.
    """

    #: ``(pid, start, repair)`` triples; ``repair=None`` = permanent.
    dropouts: Tuple[Tuple[int, float, Optional[float]], ...] = ()
    #: ``(pid, t0, t1, factor)`` windows; factor > 1 = slower.
    throttles: Tuple[Tuple[int, float, float, float], ...] = ()
    #: per-task straggler probability in [0, 1).
    straggler_prob: float = 0.0
    #: Pareto tail shape of the straggler multiplier (> 0 when prob > 0).
    straggler_shape: float = 2.0
    seed: int = 0

    def __post_init__(self) -> None:
        drops = []
        for pid, start, repair in self.dropouts:
            start = float(start)
            if start < 0.0:
                raise ValueError(f"dropout start must be >= 0, got {start}")
            if repair is not None:
                repair = float(repair)
                if repair <= 0.0:
                    raise ValueError(
                        f"dropout repair must be > 0, got {repair}")
            drops.append((int(pid), start, repair))
        throts = []
        for pid, t0, t1, factor in self.throttles:
            t0, t1, factor = float(t0), float(t1), float(factor)
            if not t0 < t1:
                raise ValueError(f"throttle window needs t0 < t1, got "
                                 f"[{t0}, {t1})")
            if factor <= 0.0:
                raise ValueError(f"throttle factor must be > 0, got {factor}")
            throts.append((int(pid), t0, t1, factor))
        if not 0.0 <= self.straggler_prob < 1.0:
            raise ValueError(
                f"straggler_prob must be in [0, 1), got {self.straggler_prob}")
        if self.straggler_prob > 0.0 and self.straggler_shape <= 0.0:
            raise ValueError(
                f"straggler_shape must be > 0, got {self.straggler_shape}")
        # canonicalize: sorted windows and one representation per ensemble,
        # so equality/hash/cache keys/JSON round-trips all agree
        object.__setattr__(
            self, "dropouts",
            tuple(sorted(drops, key=lambda d: (d[1], d[0]))))
        object.__setattr__(
            self, "throttles",
            tuple(sorted(throts, key=lambda w: (w[1], w[2], w[0]))))
        object.__setattr__(self, "straggler_prob",
                           float(self.straggler_prob))
        if self.straggler_prob == 0.0:
            # shape is never consumed without stragglers
            object.__setattr__(self, "straggler_shape", 0.0)
        else:
            object.__setattr__(self, "straggler_shape",
                               float(self.straggler_shape))

    @property
    def empty(self) -> bool:
        """True when the spec injects nothing (engines may skip the hook)."""
        return (not self.dropouts and not self.throttles
                and self.straggler_prob == 0.0)

    def dropped_pids(self) -> Tuple[int, ...]:
        """Pids that suffer a *permanent* dropout (recovery targets)."""
        return tuple(sorted({pid for pid, _, repair in self.dropouts
                             if repair is None}))

    def key(self) -> Tuple:
        """Hashable content key for evaluation caches.

        A fault spec *must* participate in any cache key derived from a
        simulation — the same solution under different faults produces
        different results, and a key without the fault axis would silently
        serve one ensemble's results for the other.
        """
        return (self.dropouts, self.throttles, self.straggler_prob,
                self.straggler_shape, self.seed)

    def to_json(self) -> Dict[str, object]:
        doc: Dict[str, object] = {"seed": self.seed}
        if self.dropouts:
            doc["dropouts"] = [list(d) for d in self.dropouts]
        if self.throttles:
            doc["throttles"] = [list(w) for w in self.throttles]
        if self.straggler_prob > 0.0:
            doc["straggler_prob"] = self.straggler_prob
            doc["straggler_shape"] = self.straggler_shape
        return doc

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "FaultSpec":
        return cls(
            dropouts=tuple(
                (int(p), float(s), None if r is None else float(r))
                for p, s, r in d.get("dropouts", ())),
            throttles=tuple(
                (int(p), float(t0), float(t1), float(f))
                for p, t0, t1, f in d.get("throttles", ())),
            straggler_prob=float(d.get("straggler_prob", 0.0)),
            straggler_shape=float(d.get("straggler_shape", 2.0)),
            seed=int(d.get("seed", 0)),
        )


#: The no-fault ensemble. ``None`` everywhere means the same thing: the
#: engines treat both identically and the clean path stays byte-for-byte
#: what it was before the fault layer existed.
NO_FAULTS = FaultSpec()


class FaultStream:
    """Seeded realization of a :class:`FaultSpec` for one simulation run.

    Every engine tier instantiates one stream per run and calls
    :meth:`service` once per delivered real task, in delivery order. The
    straggler draw consumes exactly one ``rng.random()`` per call whenever
    ``straggler_prob > 0`` (regardless of outcome or processor), so the
    stream position is a pure function of the delivery count — the same
    discipline that keeps the engines' noise streams aligned.
    """

    __slots__ = ("spec", "_rng", "_drop", "_throttle", "_prob", "_inv_shape")

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self._rng = random.Random(spec.seed)
        self._drop: Dict[int, List[Tuple[float, float]]] = {}
        for pid, start, repair in spec.dropouts:
            end = math.inf if repair is None else start + repair
            self._drop.setdefault(pid, []).append((start, end))
        self._throttle: Dict[int, List[Tuple[float, float, float]]] = {}
        for pid, t0, t1, factor in spec.throttles:
            self._throttle.setdefault(pid, []).append((t0, t1, factor))
        self._prob = spec.straggler_prob
        self._inv_shape = (1.0 / spec.straggler_shape
                           if spec.straggler_shape > 0.0 else 0.0)

    def service(self, pid: int, now: float,
                exec_t: float) -> Tuple[float, float]:
        """Fault-adjusted ``(exec_t, stall)`` for one task delivery.

        Applied in a fixed order so every tier computes identical floats:
        straggler inflation first (one RNG draw per call when enabled),
        then throttle multipliers for windows containing ``now``, then the
        dropout stall (``inf`` for a permanent dropout). The caller adds
        ``stall`` to the task's total service time when positive.
        """
        if self._prob > 0.0:
            u = self._rng.random()
            if u < self._prob:
                # inverse-CDF Pareto(shape) multiplier >= 1, reusing the
                # trigger draw so one call costs exactly one draw
                v = u / self._prob
                if v >= 1.0:  # division rounded up to the open bound
                    v = math.nextafter(1.0, 0.0)
                exec_t *= (1.0 - v) ** (-self._inv_shape)
        windows = self._throttle.get(pid)
        if windows is not None:
            for t0, t1, factor in windows:
                if t0 <= now < t1:
                    exec_t *= factor
        stall = 0.0
        drops = self._drop.get(pid)
        if drops is not None:
            for start, end in drops:
                if start <= now < end:
                    stall = end - now
                    break
        return exec_t, stall
