"""Compiled lock-step batch core: the numpy pass as one jitted XLA loop.

:mod:`repro.core.batchsim` advances every lane's event frontier with masked
numpy array ops — correct and bit-exact, but interpreter-bound: each event
touches ~30 scalars across ~60 numpy dispatches, so at GA widths the pass
*loses* to the per-solution Python loop (``BENCH_simspeed.json`` →
``batch_speedup`` ≈ 0.49). This module ports the identical pass into a
single ``jax.lax.while_loop`` compiled by XLA: one compiled program per
shape bucket, zero Python dispatch per event, every handler a masked
full-width update exactly mirroring the numpy op sequence.

Tolerance contract
------------------
The compiled tier is **not** contractually bit-exact; it is exact on
*inputs* and bounded on *arithmetic*:

* every RNG-derived quantity is precomputed host-side with the scalar
  engines' exact expressions — arrival tables via ``draw_arrivals``, noise
  z-draws via ``random.Random(seed).gauss`` with the multiplier computed by
  ``math.exp`` (per ``(draw index, pid)``, gathered in-loop), straggler
  multipliers via the one-draw-per-delivery ``random.Random`` stream with
  the scalar Pareto expression — so the compiled loop consumes bit-identical
  event inputs;
* the in-loop float arithmetic uses the same operation order as the scalar
  engines, but XLA owns the instruction selection (e.g. FMA contraction),
  so results carry a documented bounded tolerance instead of a bit-parity
  promise: :data:`COMPILED_REL_TOL` relative / :data:`COMPILED_ABS_TOL`
  absolute per reported float. In practice the observed diff on the golden
  traces and the differential suite is 0.0 on x86-64 (XLA CPU emits IEEE
  double ops for this graph); the tolerance is the contract, the zero is
  the measurement. The numpy tier remains the bit-exact parity oracle.

Fallbacks (transparent, handled by :func:`repro.core.batchsim.run_batch`)
-------------------------------------------------------------------------
* ``collect_tasks=True`` — task-trace collection is python-side by design;
* ready-queue overflow — each ``(lane, pid, priority class)`` FIFO ring has
  a fixed capacity (host-computed from the lane's task-count bound, capped
  at :data:`QUEUE_CAP_MAX`); blowing it sets an in-carry overflow flag and
  the batch re-runs on the numpy tier, whose queues grow without bound;
* iteration-cap guard — a generous host-computed event bound; hitting it
  (impossible by construction, like the numpy z-table bound) falls back
  rather than hanging inside XLA;
* missing/failed jax import — the module degrades to "always fall back".

Ready queues: FIFO rings instead of scanned slots
-------------------------------------------------
The numpy tier keeps per-``(lane, pid)`` slot arrays and scans them
(argmin over packed ``(class, priority, release_seq)`` keys) on every pop —
O(capacity) per event, fine when capacity stays small, ruinous inside a
compiled loop where GA overload lanes push hundreds of entries. The
compiled core exploits a structural property instead: ``release_seq`` is a
per-lane monotone counter, so pushes into any single ``(class, priority)``
bucket already arrive in key order. Pop order ``(class, priority, seq)``
therefore reduces to "first non-empty FIFO in class order" — one dispatch-
token FIFO (class 0) plus one FIFO per priority rank — giving O(1) pushes
and pops with no key storage and no scans, at any capacity.

``float64`` everywhere: calls run under ``jax.experimental.enable_x64`` so
the repo's global default (float32, required by the kernel/model stacks)
is untouched.

A Pallas scatter kernel was considered and rejected for this CPU target:
XLA already lowers the masked scatters to vectorized loops, and Pallas on
CPU executes through the interpreter (the guide's TPU lowering does not
apply), which benchmarks far slower than XLA's native lowering.
"""
from __future__ import annotations

import math
import random
from functools import partial
from typing import Optional, Sequence

import numpy as np

from .arrivals import arrival_horizon, draw_arrivals
from .processors import Processor

#: Documented tolerance of the compiled tier relative to the bit-exact
#: numpy tier, per reported float (makespans, busy times, timestamps).
COMPILED_REL_TOL = 1e-9
COMPILED_ABS_TOL = 1e-12

#: Hard cap on the per-(lane, pid, priority class) FIFO-ring capacity. The
#: actual capacity is the power-of-two bucket of the lane set's exact
#: released-task bound (``num_requests × tasks per request``), so overflow
#: is impossible below the cap; workloads whose bound exceeds it run on the
#: numpy tier (its queues grow without bound).
QUEUE_CAP_MAX = 4096

_BIGSEQ = np.int64(1) << 62

_jax = None
_jax_failed = False


def _get_jax() -> Optional[object]:
    """Lazy jax import; remember a failure so we only try once."""
    global _jax, _jax_failed
    if _jax is None and not _jax_failed:
        try:
            import jax  # noqa: F401

            _jax = jax
        except Exception:  # pragma: no cover - depends on environment
            _jax_failed = True
    return _jax


def _bucket(n: int, lo: int = 1) -> int:
    """Round ``n`` up to a power of two (≥ ``lo``) — shape bucketing keeps
    the jit cache small across GA generations with jittering widths."""
    v = max(int(n), lo)
    return 1 << (v - 1).bit_length()


def _advance_factory(jax: object) -> object:
    """Build the jitted lock-step advance once per process."""
    import jax.numpy as jnp
    from jax import lax

    @partial(jax.jit, static_argnums=0)
    def advance(flags, tab):
        (G, P, NP, CAP, any_noise, any_fault, any_strag,
         any_dispatch) = flags
        arrtab = tab["arrtab"]            # (W, G, NR)
        W, _, NR = arrtab.shape
        S = tab["exec_v"].shape[1]
        R = G * NR
        C = G + P + 1
        K = P + 1
        jmax = tab["roots"].shape[2]
        dmax = tab["succ_pad"].shape[2]
        horizon = tab["horizon"]
        nr = tab["nr"]
        proc_of = tab["proc_of"]
        prio_of = tab["prio_of"]
        exec_v = tab["exec_v"]
        quant_v = tab["quant_v"]
        comm_v = tab["comm_v"]
        total_v = tab["total_v"]
        dep_cnt = tab["dep_cnt"]
        succ_pad = tab["succ_pad"]
        succ_cnt = tab["succ_cnt"]
        roots = tab["roots"]
        roots_n = tab["roots_n"]
        overlap = tab["overlap"]
        dispatch_ov = tab["dispatch_ov"]
        dispatch_pid = tab["dispatch_pid"]
        dispatch_known = tab["dispatch_known"]
        noisy = tab["noisy"]
        sigma_pos = tab["sigma_pos"]      # (W, P) bool: sigma > 0
        emult = tab["emult"]              # (W, ZC, P) math.exp multipliers
        faulted = tab["faulted"]
        strag_on = tab["strag_on"]
        strag_tab = tab["strag_tab"]      # (W, FC)
        thr_pid = tab["thr_pid"]          # (W, T)
        thr_t0, thr_t1, thr_fac = tab["thr_t0"], tab["thr_t1"], tab["thr_fac"]
        drop_pid = tab["drop_pid"]        # (W, D)
        drop_t0, drop_t1 = tab["drop_t0"], tab["drop_t1"]
        idle0 = tab["idle0"]              # (P,) bool
        itercap = tab["itercap"]
        ZC = emult.shape[1]
        FC = strag_tab.shape[1]
        T = thr_pid.shape[1]
        D = drop_pid.shape[1]
        WI = jnp.arange(W)
        i64 = jnp.int64
        BIGSEQ = i64(_BIGSEQ)
        INF = jnp.float64(jnp.inf)
        M21 = i64((1 << 21) - 1)

        # --- one-hot masked updates --------------------------------------
        # XLA CPU's scatter lowering pays a per-updated-row cost (~0.1 µs)
        # and this body issues hundreds of single-element updates per
        # iteration — that row overhead, not arithmetic, dominated the
        # first cut of this loop. Every update whose minor axis is small
        # and static (frontier columns C, workers P, ring slots K,
        # requests R) is therefore a fused elementwise select over a
        # one-hot mask; only the FIFO rings (capacity axis) and the pend
        # matrix keep true scatters.
        def oh(m, col, width):
            return m[:, None] & (col[:, None]
                                 == jnp.arange(width)[None, :])

        def oh_set(arr, m, col, val):
            o = oh(m, col, arr.shape[1])
            v = val[:, None] if getattr(val, "ndim", 0) else val
            return jnp.where(o, v, arr)

        def oh2(m, i, j2, d1, d2):
            return (m[:, None, None]
                    & (i[:, None, None] == jnp.arange(d1)[None, :, None])
                    & (j2[:, None, None] == jnp.arange(d2)[None, None, :]))

        # --- masked primitive updates ------------------------------------
        def append_deliver(st, m, pid, g, rr, t):
            st["idle"] = st["idle"] & ~oh(m, pid, P)
            pos = st["del_n"]
            # ring payload (pid, g, rr) packed into one word: one update
            pack = ((pid + 1) << 42) | ((g + 1) << 21) | (rr + 1)
            st["del_pack"] = oh_set(st["del_pack"], m, pos, pack)
            we = m & (pos == 0)
            st["times"] = st["times"].at[:, C - 1].set(
                jnp.where(we, t, st["times"][:, C - 1]))
            st["seqs"] = st["seqs"].at[:, C - 1].set(
                jnp.where(we, st["seq"], st["seqs"][:, C - 1]))
            st["del_n"] = st["del_n"] + m
            st["seq"] = st["seq"] + m
            return st

        def queue_push(st, m, pid, cls, g, rr):
            """Append to the (pid, cls) FIFO ring; O(1), order = push order
            = release_seq order = the numpy tier's packed-key order."""
            pid_c = jnp.clip(pid, 0, P - 1)
            pos = st["ftail"][WI, pid_c, cls]
            head = st["fhead"][WI, pid_c, cls]
            st["overflow"] = st["overflow"] | jnp.any(m & (pos - head >= CAP))
            idx = pos & (CAP - 1)
            pid_s = jnp.where(m, pid, P)
            st["fifo"] = st["fifo"].at[WI, pid_s, cls, idx].set(
                ((g + 1) << 21) | (rr + 1), mode="drop")
            st["ftail"] = st["ftail"] + oh2(m, pid, cls, P, NP)
            return st

        def release(st, m, g, rr, t):
            """Reference ``release()``: dispatch token, then the task.

            Tokens carry no payload and only ever queue on the lane's
            single ``dispatch_pid``, so the token "FIFO" is a per-lane
            counter — no ring storage, no scatter."""
            neg1 = jnp.full((W,), -1, i64)
            if any_dispatch:
                dm = m & dispatch_known
                st["rel_seq"] = st["rel_seq"] + dm
                d_idle = st["idle"][WI, dispatch_pid]
                st = append_deliver(st, dm & d_idle, dispatch_pid,
                                    neg1, neg1, t)
                st["tok"] = st["tok"] + (dm & ~d_idle)
            st["rel_seq"] = st["rel_seq"] + m
            g_c = jnp.clip(g, 0, S - 1)
            pid = proc_of[WI, g_c]
            is_idle = st["idle"][WI, pid]
            st = append_deliver(st, m & is_idle, pid, g, rr, t)
            st = queue_push(st, m & ~is_idle, pid, prio_of[WI, g_c],
                            g, rr)
            return st

        def pull_next(st, m, pid, t):
            """Pop the earliest-keyed entry: queued dispatch tokens first
            (class 0), else the head of the first non-empty priority
            FIFO."""
            pid_c = jnp.clip(pid, 0, P - 1)
            if any_dispatch:
                tok_has = m & (pid == dispatch_pid) & (st["tok"] > 0)
                st["tok"] = st["tok"] - tok_has
            else:
                tok_has = jnp.zeros((W,), bool)
            heads = st["fhead"][WI, pid_c]               # (W, NP)
            tails = st["ftail"][WI, pid_c]
            nonempty = heads < tails
            sel = jnp.argmax(nonempty, axis=1)           # first non-empty
            fifo_has = m & ~tok_has & jnp.any(nonempty, axis=1)
            head_sel = jnp.take_along_axis(heads, sel[:, None], 1)[:, 0]
            idx = head_sel & (CAP - 1)
            v = st["fifo"][WI, pid_c, sel, idx]
            g = jnp.where(tok_has, -1, ((v >> 21) & M21) - 1)
            rr = jnp.where(tok_has, -1, (v & M21) - 1)
            st["fhead"] = st["fhead"] + oh2(fifo_has, pid, sel, P, NP)
            has = tok_has | fifo_has
            st = append_deliver(st, has, pid, g, rr, t)
            st["idle"] = st["idle"] | oh(m & ~has, pid, P)
            return st

        def cond(st):
            tmin = jnp.min(st["times"], axis=1)
            return ((st["it"] < itercap) & ~st["overflow"]
                    & jnp.any(tmin <= horizon))

        def body(st):
            tmin = jnp.min(st["times"], axis=1)
            smask = jnp.where(st["times"] == tmin[:, None], st["seqs"],
                              BIGSEQ)
            ci = jnp.argmin(smask, axis=1)
            act = tmin <= horizon
            now = tmin
            t = now

            # -- request arrivals -------------------------------------
            mA = act & (ci < G)
            gid = jnp.where(mA, ci, 0)
            rid = st["src_rid"][WI, gid]
            a0 = arrtab[WI, gid, 0]
            defer = mA & (rid == 0) & (a0 > t)
            st["times"] = oh_set(st["times"], defer, gid, t + (a0 - t))
            st["seqs"] = oh_set(st["seqs"], defer, gid, st["seq"])
            st["seq"] = st["seq"] + defer
            arr_m = mA & ~defer
            rr = gid * NR + rid
            st["arrival"] = jnp.where(oh(arr_m, rr, R), t[:, None],
                                      st["arrival"])
            st["pend"] = st["pend"].at[
                WI, jnp.where(arr_m, rr, R)].set(dep_cnt, mode="drop")
            for j in range(jmax):
                mj = arr_m & (j < roots_n[WI, gid])
                st = release(st, mj, roots[WI, gid, j], rr, t)
            nrid = rid + 1
            has = arr_m & (nrid < nr)
            arr_next = arrtab[WI, gid, jnp.minimum(nrid, NR - 1)]
            st["times"] = oh_set(
                st["times"], arr_m, gid,
                jnp.where(has, t + (arr_next - t), INF))
            st["seqs"] = oh_set(st["seqs"], arr_m, gid,
                                jnp.where(has, st["seq"], BIGSEQ))
            st["seq"] = st["seq"] + has
            st["src_rid"] = oh_set(st["src_rid"], has, gid, nrid)

            # -- worker completions -----------------------------------
            mC = act & (ci >= G) & (ci < G + P)
            pid = jnp.clip(ci - G, 0, P - 1)
            g = st["end_g"][WI, pid]
            rr = st["end_rr"][WI, pid]
            real = mC & (g >= 0)
            o_r = oh(real, rr, R)
            st["done"] = st["done"] + o_r
            st["last_finish"] = jnp.where(
                o_r, jnp.maximum(st["last_finish"], t[:, None]),
                st["last_finish"])
            g_c = jnp.clip(g, 0, S - 1)
            rr_c = jnp.clip(rr, 0, R - 1)
            for j in range(dmax):
                mj = real & (j < succ_cnt[WI, g_c])
                sj = succ_pad[WI, g_c, j]
                pj = st["pend"][WI, rr_c, sj] - 1
                st["pend"] = st["pend"].at[
                    WI, jnp.where(mj, rr, R), sj].set(pj, mode="drop")
                st = release(st, mj & (pj == 0), sj, rr, t)
            st["times"] = oh_set(st["times"], mC, G + pid, INF)
            st["seqs"] = oh_set(st["seqs"], mC, G + pid, BIGSEQ)
            st["end_g"] = oh_set(st["end_g"], mC, pid, i64(-2))
            st = pull_next(st, mC, pid, t)

            # -- delivery-ring drain ----------------------------------
            # All K slots at once. This is sound because (a) every slot
            # shares the drain's single timestamp t, (b) a pid appears at
            # most once in the ring (append_deliver requires the pid idle
            # and immediately clears idle, so a second delivery for the
            # same pid cannot enter before the drain), hence the per-pid
            # and per-column writes below never collide, and (c) the only
            # slot-order-dependent state — the seq counter and the
            # zpos/fpos RNG cursors — is reproduced with exclusive prefix
            # counts over the slot axis, giving each slot the exact value
            # the scalar left-to-right drain would hand it.
            mD = act & (ci == C - 1)
            kk = jnp.arange(K)[None, :]
            mk = mD[:, None] & (kk < st["del_n"][:, None])       # (W, K)
            v = st["del_pack"]
            pidj = (v >> 42) - 1
            gj = ((v >> 21) & M21) - 1
            rrj = (v & M21) - 1
            pid_c = jnp.clip(pidj, 0, P - 1)
            gj_c = jnp.clip(gj, 0, S - 1)
            disp = mk & (gj < 0)
            realm = mk & (gj >= 0)
            WK = WI[:, None]
            tK = t[:, None]
            seq_at = st["seq"][:, None] + (jnp.cumsum(mk, axis=1) - mk)
            st["seq"] = st["seq"] + jnp.sum(mk, axis=1)
            exec_t = exec_v[WK, gj_c]
            total = total_v[WK, gj_c]
            cm = jnp.where(overlap[:, None], 0.0, comm_v[WK, gj_c])
            if any_noise:
                draw = realm & noisy[:, None] & sigma_pos[WK, pid_c]
                zat = st["zpos"][:, None] + (jnp.cumsum(draw, axis=1) - draw)
                mult = emult[WK, jnp.minimum(zat, ZC - 1), pid_c]
                st["zpos"] = st["zpos"] + jnp.sum(draw, axis=1)
                et = exec_t * mult
                # same order as the scalar loop: exec + quant + (0|comm)
                tt = et + quant_v[WK, gj_c] + cm
                exec_t = jnp.where(draw, et, exec_t)
                total = jnp.where(draw, tt, total)
            if any_fault:
                fm = realm & faulted[:, None]
                ex_f = exec_t
                if any_strag:
                    sd = fm & strag_on[:, None]
                    fat = st["fpos"][:, None] + (jnp.cumsum(sd, axis=1) - sd)
                    sm = strag_tab[WK, jnp.minimum(fat, FC - 1)]
                    st["fpos"] = st["fpos"] + jnp.sum(sd, axis=1)
                    ex_f = jnp.where(sd, ex_f * sm, ex_f)
                for ti in range(T):
                    match = (fm & (thr_pid[:, ti, None] == pidj)
                             & (thr_t0[:, ti, None] <= tK)
                             & (tK < thr_t1[:, ti, None]))
                    ex_f = jnp.where(match, ex_f * thr_fac[:, ti, None],
                                     ex_f)
                stall = jnp.zeros((W, K))
                found = jnp.zeros((W, K), bool)
                for di in range(D):
                    match = (fm & ~found & (drop_pid[:, di, None] == pidj)
                             & (drop_t0[:, di, None] <= tK)
                             & (tK < drop_t1[:, di, None]))
                    stall = jnp.where(match, drop_t1[:, di, None] - tK,
                                      stall)
                    found = found | match
                tt = ex_f + quant_v[WK, gj_c] + cm
                tt = jnp.where(stall > 0.0, stall + tt, tt)
                exec_t = jnp.where(fm, ex_f, exec_t)
                total = jnp.where(fm, tt, total)
            ohr = (realm[:, :, None]
                   & (rrj[:, :, None] == jnp.arange(R)[None, None, :]))
            st["first_start"] = jnp.where(
                jnp.any(ohr, axis=1),
                jnp.minimum(st["first_start"], tK),
                st["first_start"])
            fin = realm & jnp.isfinite(total)
            ohp = ((disp | realm)[:, :, None]
                   & (pid_c[:, :, None] == jnp.arange(P)[None, None, :]))
            badd = jnp.where(disp, dispatch_ov[:, None],
                             jnp.where(fin, total, 0.0))
            st["busy"] = st["busy"] + jnp.sum(
                jnp.where(ohp, badd[:, :, None], 0.0), axis=1)
            ohc = ((disp | realm)[:, :, None]
                   & ((G + pid_c)[:, :, None] == jnp.arange(C)[None, None, :]))
            tval = jnp.where(disp, tK + dispatch_ov[:, None], tK + total)
            hitc = jnp.any(ohc, axis=1)
            st["times"] = jnp.where(
                hitc, jnp.sum(jnp.where(ohc, tval[:, :, None], 0.0), axis=1),
                st["times"])
            st["seqs"] = jnp.where(
                hitc,
                jnp.sum(jnp.where(ohc, seq_at[:, :, None], i64(0)), axis=1),
                st["seqs"])
            hitp = jnp.any(ohp, axis=1)
            egv = jnp.where(disp, i64(-1), gj)
            st["end_g"] = jnp.where(
                hitp, jnp.sum(jnp.where(ohp, egv[:, :, None], i64(0)),
                              axis=1),
                st["end_g"])
            ohpr = (realm[:, :, None]
                    & (pid_c[:, :, None] == jnp.arange(P)[None, None, :]))
            st["end_rr"] = jnp.where(
                jnp.any(ohpr, axis=1),
                jnp.sum(jnp.where(ohpr, rrj[:, :, None], i64(0)), axis=1),
                st["end_rr"])
            st["del_n"] = jnp.where(mD, 0, st["del_n"])
            st["times"] = st["times"].at[:, C - 1].set(
                jnp.where(mD, INF, st["times"][:, C - 1]))
            st["seqs"] = st["seqs"].at[:, C - 1].set(
                jnp.where(mD, BIGSEQ, st["seqs"][:, C - 1]))

            st["it"] = st["it"] + 1
            return st

        times0 = jnp.full((W, C), INF)
        times0 = times0.at[:, :G].set(0.0)
        seqs0 = jnp.full((W, C), BIGSEQ, i64)
        seqs0 = seqs0.at[:, :G].set(jnp.arange(G, dtype=jnp.int64)[None, :])
        st0 = {
            "times": times0,
            "seqs": seqs0,
            "seq": jnp.full((W,), G, i64),
            "rel_seq": jnp.zeros((W,), i64),
            "src_rid": jnp.zeros((W, G), i64),
            "idle": jnp.broadcast_to(idle0, (W, P)),
            "end_g": jnp.full((W, P), -2, i64),
            "end_rr": jnp.full((W, P), -1, i64),
            "arrival": jnp.zeros((W, R)),
            "first_start": jnp.full((W, R), INF),
            "last_finish": jnp.zeros((W, R)),
            "done": jnp.zeros((W, R), i64),
            "pend": jnp.zeros((W, R, S), jnp.int32),
            "busy": jnp.zeros((W, P)),
            "fifo": jnp.zeros((W, P, NP, CAP), i64),
            "fhead": jnp.zeros((W, P, NP), i64),
            "ftail": jnp.zeros((W, P, NP), i64),
            "tok": jnp.zeros((W,), i64),
            "del_pack": jnp.zeros((W, K), i64),
            "del_n": jnp.zeros((W,), i64),
            "zpos": jnp.zeros((W,), i64),
            "fpos": jnp.zeros((W,), i64),
            "overflow": jnp.zeros((), bool),
            "it": jnp.zeros((), i64),
        }
        out = lax.while_loop(cond, body, st0)
        return (out["arrival"], out["first_start"], out["last_finish"],
                out["done"], out["busy"], out["overflow"], out["it"])

    return advance


#: Diagnostics of the most recent :func:`run_batch_compiled` call:
#: ``{"iters", "itercap", "overflow", "fallback"}``. Tests and the
#: simspeed benchmark read this to tell a compiled run from a fallback.
last_stats: dict = {}

_advance_cache = None


def _advance_fn() -> Optional[object]:
    global _advance_cache
    if _advance_cache is None:
        jax = _get_jax()
        if jax is None:
            return None
        _advance_cache = _advance_factory(jax)
    return _advance_cache


def run_batch_compiled(
    lanes: Sequence,
    groups: Sequence[Sequence[int]],
    processors: Sequence[Processor],
) -> Optional[object]:
    """Run a batch through the compiled core; ``None`` requests fallback.

    Inputs (arrival tables, noise multipliers, straggler multipliers) are
    precomputed host-side with the scalar engines' exact expressions; the
    jitted loop then advances the shared frontier to quiescence. Returns a
    :class:`repro.core.batchsim.BatchResult` (``tasks=None``) or ``None``
    when jax is unavailable, a queue overflowed :data:`QUEUE_CAP`, or the
    iteration guard tripped — the caller reruns on the bit-exact numpy
    tier in those cases.
    """
    advance = _advance_fn()
    if advance is None:
        return None
    from .batchsim import BatchResult, BatchSimulator
    from .faults import FaultStream  # noqa: F401  (host-side parity ref)

    sim = BatchSimulator(lanes, groups, processors)
    lanes = sim.lanes
    groups = sim.groups
    pids = sim.pids
    (W, S, P, G, proc_of, prio_of, exec_v, quant_v, comm_v, total_v,
     dep_cnt, net_of, k_of, succ_pad, succ_cnt, dmax, roots, roots_n,
     jmax, group_tasks) = sim._pad_specs()

    nr = np.array([ln.num_requests for ln in lanes], np.int64)
    nr_max = int(nr.max())
    horizon = np.zeros(W)
    arrtab_raw = np.zeros((W, G, max(nr_max, 1)))
    for b, ln in enumerate(lanes):
        tables = draw_arrivals(ln.arrivals, ln.periods, ln.num_requests)
        for gi, tab in enumerate(tables):
            arrtab_raw[b, gi, :len(tab)] = tab
        horizon[b] = arrival_horizon(tables, ln.periods, ln.num_requests)

    dispatch_ov = np.array([ln.dispatch_overhead for ln in lanes])
    dispatch_pid = np.array([ln.dispatch_pid for ln in lanes], np.int64)
    dispatch_known = (dispatch_ov > 0) & np.isin(dispatch_pid, np.array(pids))
    dispatch_pid = np.clip(dispatch_pid, 0, P - 1)
    any_dispatch = bool(dispatch_known.any())
    overlap = np.array([ln.overlap_comm for ln in lanes], bool)

    # noise: z-draws + exp-multiplier tables, scalar-exact host-side
    noisy = np.zeros(W, bool)
    sigma_of = np.zeros((W, P))
    mu_of = np.zeros((W, P))
    draw_bound = np.zeros(W, np.int64)
    for b, ln in enumerate(lanes):
        if ln.noise is not None:
            noisy[b] = True
            for p in processors:
                s = ln.noise.sigma(p.kind)
                sigma_of[b, p.pid] = s
                mu_of[b, p.pid] = -0.5 * s * s
            draw_bound[b] = ln.num_requests * sum(
                ln.spec.counts[n] for nets in groups for n in nets)
    any_noise = bool(noisy.any())
    zcap = _bucket(int(draw_bound.max()) if any_noise else 1)
    emult = np.ones((W, zcap, P))
    for b in np.nonzero(noisy)[0]:
        rng = random.Random(lanes[b].noise.seed)
        bound = int(draw_bound[b])
        zs = [rng.gauss(0.0, 1.0) for _ in range(bound)]
        for p in pids:
            s = sigma_of[b, p]
            if s > 0.0:
                mu = mu_of[b, p]
                # the exact scalar expression: math.exp(mu + z * sigma)
                emult[b, :bound, p] = [math.exp(mu + z * s) for z in zs]

    # faults: straggler multipliers from the one-draw-per-delivery stream;
    # throttle/dropout windows as padded static tables
    faulted = np.zeros(W, bool)
    strag_on = np.zeros(W, bool)
    tmax = 1
    dmax_f = 1
    fb = np.zeros(W, np.int64)
    for b, ln in enumerate(lanes):
        if ln.faults is not None and not ln.faults.empty:
            faulted[b] = True
            tmax = max(tmax, len(ln.faults.throttles))
            dmax_f = max(dmax_f, len(ln.faults.dropouts))
            if ln.faults.straggler_prob > 0.0:
                strag_on[b] = True
                fb[b] = ln.num_requests * sum(
                    ln.spec.counts[n] for nets in groups for n in nets)
    any_fault = bool(faulted.any())
    any_strag = bool(strag_on.any())
    fcap = _bucket(int(fb.max()) if any_strag else 1)
    strag_tab = np.ones((W, fcap))
    thr_pid = np.full((W, tmax), -9, np.int64)
    thr_t0 = np.zeros((W, tmax))
    thr_t1 = np.zeros((W, tmax))
    thr_fac = np.ones((W, tmax))
    drop_pid = np.full((W, dmax_f), -9, np.int64)
    drop_t0 = np.zeros((W, dmax_f))
    drop_t1 = np.zeros((W, dmax_f))
    for b in np.nonzero(faulted)[0]:
        spec = lanes[b].faults
        for ti, (pid, t0, t1, fac) in enumerate(spec.throttles):
            thr_pid[b, ti] = pid
            thr_t0[b, ti], thr_t1[b, ti], thr_fac[b, ti] = t0, t1, fac
        for di, (pid, start, repair) in enumerate(spec.dropouts):
            drop_pid[b, di] = pid
            drop_t0[b, di] = start
            drop_t1[b, di] = (math.inf if repair is None
                              else start + repair)
        if strag_on[b]:
            rng = random.Random(spec.seed)
            prob = spec.straggler_prob
            inv_shape = 1.0 / spec.straggler_shape
            for k in range(int(fb[b])):
                u = rng.random()
                if u < prob:
                    # the exact scalar Pareto expression (FaultStream)
                    v = u / prob
                    if v >= 1.0:
                        v = math.nextafter(1.0, 0.0)
                    strag_tab[b, k] = (1.0 - v) ** (-inv_shape)
                else:
                    strag_tab[b, k] = 1.0

    idle0 = np.zeros(P, bool)
    idle0[pids] = True

    # FIFO classes: one per priority rank (dispatch tokens live in a
    # per-lane counter, not a ring). Ring capacity = exact bound on entries
    # ever pushed per (lane, pid, class): every push is a released task,
    # bounded by the lane's total task count across all requests.
    NP = int(prio_of.max()) + 1
    qbound = int((nr * group_tasks.sum(axis=1)).max())
    CAP = _bucket(qbound + 4)
    if CAP > QUEUE_CAP_MAX:
        last_stats.clear()
        last_stats.update(fallback=True, overflow=False, iters=0,
                          itercap=0, reason="queue-bound")
        return None

    # generous per-lane event bound: arrivals + completions (tasks +
    # dispatch tokens) + ring-head pops, doubled. Hitting it means a bug;
    # the caller falls back to numpy instead of hanging.
    task_max = int(group_tasks.sum(axis=1).max())
    itercap = 64 + 2 * (G * (nr_max + 2) + 4 * nr_max * task_max)

    # shape bucketing: pad W/S/NR (and the z/fault tables, bucketed above)
    # so GA generations with jittering widths reuse one compiled program.
    # Padding lanes carry horizon -1: their frontier (time 0) is never
    # active, so they are inert in every masked update. Width buckets to
    # multiples of 16 (not powers of two): per-iteration cost scales
    # ~linearly with W, so padding 80 GA lanes to 128 would cost ~60%.
    WB = max(16, -(-W // 16) * 16)
    SB = _bucket(S)
    NRB = _bucket(nr_max)
    jB = _bucket(jmax)
    dB = _bucket(dmax)

    def padw(a, fill=0):
        if a.shape[0] == WB:
            return a
        out = np.full((WB,) + a.shape[1:], fill, a.dtype)
        out[:W] = a
        return out

    def pad2(a, n, fill=0):
        if a.shape[1] == n:
            return a
        out = np.full((a.shape[0], n) + a.shape[2:], fill, a.dtype)
        out[:, :a.shape[1]] = a
        return out

    arrtab = np.zeros((W, G, NRB))
    arrtab[:, :, :arrtab_raw.shape[2]] = arrtab_raw
    succ_pad_b = np.zeros((W, SB, dB), np.int64)
    succ_pad_b[:, :S, :dmax] = succ_pad
    roots_b = np.zeros((W, G, jB), np.int64)
    roots_b[:, :, :jmax] = roots

    tab = {
        "arrtab": padw(arrtab),
        "horizon": padw(horizon, -1.0),
        "nr": padw(nr),
        "proc_of": padw(pad2(proc_of, SB)),
        "prio_of": padw(pad2(prio_of, SB)),
        "exec_v": padw(pad2(exec_v, SB)),
        "quant_v": padw(pad2(quant_v, SB)),
        "comm_v": padw(pad2(comm_v, SB)),
        "total_v": padw(pad2(total_v, SB)),
        "dep_cnt": padw(pad2(dep_cnt.astype(np.int32), SB)),
        "succ_pad": padw(succ_pad_b),
        "succ_cnt": padw(pad2(succ_cnt, SB)),
        "roots": padw(roots_b),
        "roots_n": padw(roots_n),
        "overlap": padw(overlap),
        "dispatch_ov": padw(dispatch_ov),
        "dispatch_pid": padw(dispatch_pid),
        "dispatch_known": padw(dispatch_known),
        "noisy": padw(noisy),
        "sigma_pos": padw(sigma_of > 0.0),
        "emult": padw(emult, 1.0),
        "faulted": padw(faulted),
        "strag_on": padw(strag_on),
        "strag_tab": padw(strag_tab, 1.0),
        "thr_pid": padw(thr_pid, -9),
        "thr_t0": padw(thr_t0),
        "thr_t1": padw(thr_t1),
        "thr_fac": padw(thr_fac, 1.0),
        "drop_pid": padw(drop_pid, -9),
        "drop_t0": padw(drop_t0),
        "drop_t1": padw(drop_t1),
        "idle0": idle0,
        "itercap": np.int64(itercap),
    }
    flags = (G, P, NP, CAP, any_noise, any_fault, any_strag, any_dispatch)

    jax = _get_jax()
    from jax.experimental import enable_x64

    with enable_x64():
        jtab = {k: jax.numpy.asarray(v) for k, v in tab.items()}
        (arrival, first_start, last_finish, done, busy, overflow,
         iters) = advance(flags, jtab)
        overflow = bool(overflow)
        iters = int(iters)
        last_stats.clear()
        last_stats.update(iters=iters, itercap=itercap, overflow=overflow,
                          fallback=overflow or iters >= itercap)
        if overflow or iters >= itercap:
            return None
        arrival = np.asarray(arrival)[:W]
        first_start = np.asarray(first_start)[:W]
        last_finish = np.asarray(last_finish)[:W]
        done = np.asarray(done)[:W]
        busy = np.asarray(busy)[:W]

    return BatchResult(
        lanes=lanes, groups=groups, num_requests=nr, arrival=arrival,
        first_start=first_start, last_finish=last_finish, done=done,
        group_tasks=group_tasks, busy=busy, horizon=horizon,
        pids=pids, nr_max=NRB, tasks=None,
    )
