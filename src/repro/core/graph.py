"""Layer-DAG intermediate representation for schedulable networks.

A :class:`ModelGraph` is the unit Puzzle schedules: a DAG of :class:`Layer`
nodes connected by :class:`Edge`\\ s carrying tensors of known byte size.
The partition chromosome cuts edges; connected components of the remaining
graph become :class:`Subgraph`\\ s — the unit of compilation, profiling and
execution (paper §4, Fig. 7).

Subgraphs are content-addressed with a Merkle-tree hash (paper §4.3) so the
device-in-the-loop profiler can cache measurements across GA generations.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple


@dataclass(frozen=True)
class Layer:
    """One schedulable operator/layer.

    ``macs`` / ``param_bytes`` / ``out_bytes`` drive the analytic cost
    backends; ``op_type`` + ``attrs`` drive Merkle hashing and (for the
    executable zoo models) the actual JAX computation.
    """

    index: int
    name: str
    op_type: str
    macs: float = 0.0              # multiply-accumulates of this layer
    param_bytes: int = 0           # weight footprint
    out_bytes: int = 0             # activation output size (comm cost on a cut)
    attrs: Tuple[Tuple[str, Any], ...] = ()

    def attr(self, key: str, default: Any = None) -> Any:
        for k, v in self.attrs:
            if k == key:
                return v
        return default

    def leaf_hash(self) -> bytes:
        h = hashlib.sha256()
        h.update(self.op_type.encode())
        h.update(str(sorted(self.attrs)).encode())
        h.update(str(int(self.macs)).encode())
        h.update(str(self.out_bytes).encode())
        return h.digest()


@dataclass(frozen=True)
class Edge:
    """Directed dependency ``src -> dst`` carrying ``bytes_`` of activation."""

    index: int
    src: int
    dst: int
    bytes_: int


class ModelGraph:
    """A DAG of layers; the schedulable representation of one network."""

    def __init__(self, name: str, layers: Sequence[Layer], edges: Sequence[Edge]):
        self.name = name
        self.layers: List[Layer] = list(layers)
        self.edges: List[Edge] = list(edges)
        n = len(self.layers)
        for i, layer in enumerate(self.layers):
            if layer.index != i:
                raise ValueError(
                    f"layer {layer.name} has index {layer.index}, expected {i}")
        for e in self.edges:
            if not (0 <= e.src < n and 0 <= e.dst < n):
                raise ValueError(f"edge {e} out of range")
            if e.src >= e.dst:
                raise ValueError(f"edge {e} must go forward in topological index order")
        self.out_edges: Dict[int, List[Edge]] = {i: [] for i in range(n)}
        self.in_edges: Dict[int, List[Edge]] = {i: [] for i in range(n)}
        for e in self.edges:
            self.out_edges[e.src].append(e)
            self.in_edges[e.dst].append(e)
        self._partition_cache: Dict[Tuple[int, ...], List["Subgraph"]] = {}

    # -- basic properties ---------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.layers)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    @property
    def total_macs(self) -> float:
        return sum(layer.macs for layer in self.layers)

    @property
    def total_param_bytes(self) -> int:
        return sum(layer.param_bytes for layer in self.layers)

    def sources(self) -> List[int]:
        return [i for i in range(self.num_layers) if not self.in_edges[i]]

    def sinks(self) -> List[int]:
        return [i for i in range(self.num_layers) if not self.out_edges[i]]

    def validate_acyclic(self) -> bool:
        # Edges are constrained src < dst at construction => acyclic by design.
        return True

    # -- partitioning ---------------------------------------------------------
    def partition(self, cut_bits: Sequence[int]) -> List["Subgraph"]:
        """Split into subgraphs given a binary cut vector over edges.

        ``cut_bits[e] == 1`` means edge ``e`` is cut (paper Fig. 7a). The
        connected components of the *undirected* un-cut graph become
        subgraphs. Components are then topologically ordered; a component
        whose internal layers straddle a dependency through another component
        is split further so every subgraph is convex (no dependency cycle
        between subgraphs) — this mirrors compilable subgraphs in Puzzle.
        """
        if len(cut_bits) != self.num_edges:
            raise ValueError(
                f"cut vector has {len(cut_bits)} bits, graph has {self.num_edges} edges"
            )
        cache_key = tuple(cut_bits)
        cached = self._partition_cache.get(cache_key)
        if cached is not None:
            return cached
        n = self.num_layers
        parent = list(range(n))

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        def union(a: int, b: int) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[rb] = ra

        for e in self.edges:
            if not cut_bits[e.index]:
                union(e.src, e.dst)

        comp_of = [find(i) for i in range(n)]
        # Enforce convexity: iterate until no subgraph-level cycle remains.
        # A cycle appears when a cut path leaves a component and re-enters it.
        comp_of = self._make_convex(comp_of)

        groups: Dict[int, List[int]] = {}
        for i, c in enumerate(comp_of):
            groups.setdefault(c, []).append(i)
        # Topological order of subgraphs == order of min layer index (valid
        # since edges only go forward).
        ordered = sorted(groups.values(), key=min)
        result = [Subgraph(self, tuple(g), sg_index=k) for k, g in enumerate(ordered)]
        if len(self._partition_cache) < 4096:
            self._partition_cache[cache_key] = result
        return result

    def _make_convex(self, comp_of: List[int]) -> List[int]:
        """Split components until the subgraph quotient graph is acyclic.

        Uses the forward-index property: within a component, if a layer ``v``
        has a predecessor path exiting and re-entering the component, detach
        ``v`` and its component-successors into a fresh component.
        """
        n = self.num_layers
        changed = True
        next_comp = max(comp_of, default=-1) + 1
        while changed:
            changed = False
            # longest path "external rank" per layer: number of component
            # switches along any path into the layer.
            rank = [0] * n
            for i in range(n):
                for e in self.in_edges[i]:
                    r = rank[e.src] + (1 if comp_of[e.src] != comp_of[e.dst] else 0)
                    if r > rank[i]:
                        rank[i] = r
            # If two layers in one component have different ranks, the lower
            # ones and higher ones cannot be compiled together (an external
            # dependency sits between them) -> split by rank.
            by_comp: Dict[int, Dict[int, List[int]]] = {}
            for i in range(n):
                by_comp.setdefault(comp_of[i], {}).setdefault(rank[i], []).append(i)
            for comp, by_rank in by_comp.items():
                if len(by_rank) > 1:
                    changed = True
                    for r, members in sorted(by_rank.items())[1:]:
                        for m in members:
                            comp_of[m] = next_comp
                        next_comp += 1
        return comp_of

    def __repr__(self) -> str:  # pragma: no cover
        return f"ModelGraph({self.name}, layers={self.num_layers}, edges={self.num_edges})"


@dataclass(frozen=True)
class Subgraph:
    """A convex set of layers compiled and executed as one unit."""

    graph: ModelGraph
    layer_ids: Tuple[int, ...]
    sg_index: int

    @property
    def name(self) -> str:
        return f"{self.graph.name}/sg{self.sg_index}"

    @property
    def macs(self) -> float:
        return sum(self.graph.layers[i].macs for i in self.layer_ids)

    @property
    def param_bytes(self) -> int:
        return sum(self.graph.layers[i].param_bytes for i in self.layer_ids)

    def internal_edges(self) -> List[Edge]:
        s = set(self.layer_ids)
        return [e for e in self.graph.edges if e.src in s and e.dst in s]

    def in_cut_edges(self) -> List[Edge]:
        s = set(self.layer_ids)
        return [e for e in self.graph.edges if e.dst in s and e.src not in s]

    def out_cut_edges(self) -> List[Edge]:
        s = set(self.layer_ids)
        return [e for e in self.graph.edges if e.src in s and e.dst not in s]

    def input_bytes(self) -> int:
        b = sum(e.bytes_ for e in self.in_cut_edges())
        if not b:  # source subgraph: model input size approximated by first layer
            first = self.graph.layers[min(self.layer_ids)]
            b = first.attr("input_bytes", first.out_bytes)
        return int(b)

    def output_bytes(self) -> int:
        b = sum(e.bytes_ for e in self.out_cut_edges())
        if not b:
            last = self.graph.layers[max(self.layer_ids)]
            b = last.out_bytes
        return int(b)

    def merkle_hash(self, extra: Tuple[Any, ...] = ()) -> str:
        """Merkle-tree content hash of this subgraph (paper §4.3).

        Leaves are per-layer hashes in topological order; internal edges are
        folded in pairwise, so equal subgraphs across candidates/generations
        hit the same profile-DB row. ``extra`` lets callers mix in the
        execution configuration (processor, dtype, backend).

        The root digest and per-``extra`` results are memoized on the
        *instance* (content-addressed, so always valid). The search fast
        path shares ``Subgraph`` objects across candidate solutions via its
        partition cache, so repeated profile-key computation becomes a dict
        hit there, while paths that re-decode per simulation (the reference
        oracle, mirroring the original implementation) keep paying full
        cost.
        """
        d = self.__dict__  # frozen dataclass: memoize without __setattr__
        memo = d.get("_merkle_memo")
        if memo is None:
            memo = d["_merkle_memo"] = {}
        else:
            hit = memo.get(extra)
            if hit is not None:
                return hit
        root = d.get("_merkle_root")
        if root is None:
            level = [self.graph.layers[i].leaf_hash() for i in sorted(self.layer_ids)]
            s = set(self.layer_ids)
            edge_sig = ",".join(
                f"{e.src}-{e.dst}" for e in self.graph.edges if e.src in s and e.dst in s
            )
            level.append(hashlib.sha256(edge_sig.encode()).digest())
            while len(level) > 1:
                nxt = []
                for i in range(0, len(level) - 1, 2):
                    nxt.append(hashlib.sha256(level[i] + level[i + 1]).digest())
                if len(level) % 2:
                    nxt.append(level[-1])
                level = nxt
            root = d["_merkle_root"] = level[0]
        if extra:
            out = hashlib.sha256(root + str(extra).encode()).digest().hex()
        else:
            out = root.hex()
        memo[extra] = out
        return out


def partition_quotient(
    graph: ModelGraph, subgraphs: Sequence[Subgraph]
) -> Tuple[Dict[int, int], List[Tuple[int, int]], List[str]]:
    """Contract a partition of ``graph`` to its subgraph quotient graph.

    Returns ``(owner, edges, problems)``: ``owner`` maps each layer id to the
    position of the subgraph owning it in ``subgraphs``; ``edges`` are the
    deduplicated cross-subgraph dependencies ``(src_sg, dst_sg)``; and
    ``problems`` lists structural defects found while contracting — layers
    owned by no subgraph or by more than one, out-of-range layer ids, and
    graph edges dangling out of the owned set. ``partition`` never produces
    these, so a nonempty ``problems`` means the subgraph list was corrupted
    after decode; the static analyzer reports them as SL002.
    """
    owner: Dict[int, int] = {}
    problems: List[str] = []
    for pos, sg in enumerate(subgraphs):
        for lid in sg.layer_ids:
            if not 0 <= lid < graph.num_layers:
                problems.append(f"subgraph {pos} owns out-of-range layer {lid}")
                continue
            if lid in owner:
                problems.append(
                    f"layer {lid} owned by subgraphs {owner[lid]} and {pos}")
                continue
            owner[lid] = pos
    for lid in range(graph.num_layers):
        if lid not in owner:
            problems.append(f"layer {lid} of {graph.name} is owned by no subgraph")
    edges: List[Tuple[int, int]] = []
    seen = set()
    for e in graph.edges:
        su, sv = owner.get(e.src), owner.get(e.dst)
        if su is None or sv is None:
            problems.append(
                f"edge {e.src}->{e.dst} dangles outside the partition")
            continue
        if su != sv and (su, sv) not in seen:
            seen.add((su, sv))
            edges.append((su, sv))
    return owner, edges, problems


def quotient_is_acyclic(num_nodes: int, edges: Sequence[Tuple[int, int]]) -> bool:
    """Kahn's algorithm over a contracted subgraph quotient graph."""
    indeg = [0] * num_nodes
    succs: Dict[int, List[int]] = {}
    for u, v in edges:
        indeg[v] += 1
        succs.setdefault(u, []).append(v)
    ready = [i for i in range(num_nodes) if indeg[i] == 0]
    done = 0
    while ready:
        u = ready.pop()
        done += 1
        for v in succs.get(u, ()):
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    return done == num_nodes


def chain_graph(
    name: str,
    layer_specs: Sequence[Tuple[str, float, int, int]],
) -> ModelGraph:
    """Build a linear-chain graph from ``(op_type, macs, param_bytes, out_bytes)``."""
    layers = [
        Layer(index=i, name=f"{name}.{i}", op_type=op, macs=m, param_bytes=p, out_bytes=o)
        for i, (op, m, p, o) in enumerate(layer_specs)
    ]
    edges = [
        Edge(index=i, src=i, dst=i + 1, bytes_=layers[i].out_bytes)
        for i in range(len(layers) - 1)
    ]
    return ModelGraph(name, layers, edges)


def branching_graph(
    name: str,
    layer_specs: Sequence[Tuple[str, float, int, int]],
    edge_list: Sequence[Tuple[int, int]],
) -> ModelGraph:
    """Build an arbitrary DAG; edge bytes default to the source layer output."""
    layers = [
        Layer(index=i, name=f"{name}.{i}", op_type=op, macs=m, param_bytes=p, out_bytes=o)
        for i, (op, m, p, o) in enumerate(layer_specs)
    ]
    edges = [
        Edge(index=k, src=s, dst=d, bytes_=layers[s].out_bytes)
        for k, (s, d) in enumerate(edge_list)
    ]
    return ModelGraph(name, layers, edges)
