"""Heuristic baselines from the paper's evaluation (§6.1).

* **NPU Only** — every model runs un-partitioned on the NPU (the fastest
  processor for most models) with its best (dtype, backend) configuration.
* **Best Mapping** — search-based heuristic: profile each model on each
  processor, then explore whole-model mappings (no partitioning) with a
  Pareto-archive hillclimb driven by the simulator. This accounts for
  inter-model interaction but cannot split models.

Conventions shared with the rest of :mod:`repro.core`: all times are in
**seconds**; ``best_times`` arguments are the output of
:func:`repro.core.scenarios.best_model_times`; randomness is always drawn
from a locally constructed ``random.Random(seed)``, never from the global
RNG, so every function here is replayable from its arguments alone.
"""
from __future__ import annotations

import random
from typing import Callable, Dict, List, Sequence, Tuple

from .chromosome import Solution
from .graph import ModelGraph
from .nsga import fast_non_dominated_sort

Objective = Tuple[float, ...]


def _whole_model_solution(
    graphs: Sequence[ModelGraph],
    proc_per_net: Sequence[int],
    cfg_per_net: Sequence[Tuple[int, int]],
) -> Solution:
    """Un-partitioned solution: network *n* whole on ``proc_per_net[n]`` with
    ``(dtype_ix, backend_ix) = cfg_per_net[n]``; priority = network index."""
    return Solution(
        partition=[[0] * g.num_edges for g in graphs],
        mapping=[[proc_per_net[n]] * g.num_layers for n, g in enumerate(graphs)],
        priority=list(range(len(graphs))),
        dtype=[c[0] for c in cfg_per_net],
        backend=[c[1] for c in cfg_per_net],
    )


def npu_only_solution(
    graphs: Sequence[ModelGraph],
    npu_pid: int,
    best_times: Sequence[Dict[int, Tuple[float, int, int]]],
) -> Solution:
    """All models un-partitioned on the NPU, best per-model configuration.

    Deterministic (no RNG): the (dtype, backend) choice per model is the
    argmin over profiled times on ``npu_pid`` recorded in ``best_times``.
    """
    cfgs = [(best_times[n][npu_pid][1], best_times[n][npu_pid][2]) for n in range(len(graphs))]
    return _whole_model_solution(graphs, [npu_pid] * len(graphs), cfgs)


def best_mapping_solutions(
    graphs: Sequence[ModelGraph],
    processors: Sequence[int],
    best_times: Sequence[Dict[int, Tuple[float, int, int]]],
    evaluate: Callable[[Solution], Objective],
    max_evals: int = 200,
    seed: int = 0,
) -> List[Solution]:
    """Pareto set over whole-model mappings (no partitioning).

    Starts from the per-model-fastest mapping, then explores single-model
    processor moves, keeping a Pareto archive, until no archive growth or
    the evaluation budget (``max_evals`` distinct mappings) is exhausted.

    ``evaluate`` maps a candidate :class:`Solution` to a minimized objective
    tuple (makespan statistics in seconds, as produced by
    ``StaticAnalyzer.objectives``). ``seed`` only shuffles neighbor visit
    order via a local ``random.Random(seed)``; the same ``(best_times,
    evaluate, max_evals, seed)`` always reproduces the same archive.
    """
    rng = random.Random(seed)
    n = len(graphs)

    def make(proc_per_net: Tuple[int, ...]) -> Solution:
        cfgs = [
            (best_times[m][proc_per_net[m]][1], best_times[m][proc_per_net[m]][2])
            for m in range(n)
        ]
        return _whole_model_solution(graphs, list(proc_per_net), cfgs)

    start = tuple(
        min(best_times[m], key=lambda pid: best_times[m][pid][0]) for m in range(n)
    )
    evaluated: Dict[Tuple[int, ...], Objective] = {}

    def ev(key: Tuple[int, ...]) -> Objective:
        if key not in evaluated:
            evaluated[key] = evaluate(make(key))
        return evaluated[key]

    archive: List[Tuple[Tuple[int, ...], Objective]] = [(start, ev(start))]
    frontier = [start]
    while frontier and len(evaluated) < max_evals:
        base = frontier.pop(0)
        neighbors = []
        for m in range(n):
            for p in processors:
                if p != base[m]:
                    cand = tuple(p if i == m else base[i] for i in range(n))
                    neighbors.append(cand)
        rng.shuffle(neighbors)
        for cand in neighbors:
            if len(evaluated) >= max_evals:
                break
            if cand in evaluated:
                continue
            obj = ev(cand)
            fits = [o for _, o in archive] + [obj]
            fronts = fast_non_dominated_sort(fits)
            if len(archive) in fronts[0]:
                # candidate is non-dominated: rebuild archive from front 0
                items = archive + [(cand, obj)]
                archive = [items[i] for i in fronts[0]]
                # prune keys whose archive entries the candidate just
                # dominated — expanding them would spend the evaluation
                # budget on neighborhoods of dead mappings. cand itself is
                # fresh (it was absent from `evaluated`), so this append
                # cannot duplicate a frontier entry.
                live = {k for k, _ in archive}
                frontier = [k for k in frontier if k in live]
                frontier.append(cand)
    sols = []
    for key, obj in archive:
        s = make(key)
        s.fitness = obj
        sols.append(s)
    return sols
