"""Genetic Algorithm driver (paper §4.3, Fig. 8).

Follows the paper's process: all candidates become parents (no elitist
subset selection), one-point crossover on partition/mapping, UPMX on
priority, mutation, probabilistic local search (merge-neighbors and
reposition-adjacent-layers), fast simulator evaluation during search,
accurate ("brief on-target execution") evaluation before the Pareto
update, NSGA-III replacement, convergence after ``patience`` generations
without average-score improvement.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .chromosome import Solution, SolutionFactory
from .nsga import fast_non_dominated_sort, nsga3_select

Objective = Tuple[float, ...]
EvalFn = Callable[[Solution], Objective]
# batch evaluator: (solutions, accurate) -> objectives, one per solution
BatchEvalFn = Callable[[Sequence[Solution], bool], List[Objective]]
# static pre-screen: worst-rank objective for a *provably* infeasible
# chromosome (simulating it could never beat any feasible candidate),
# or None when the analyzer cannot prove anything — the sound default.
PrescreenFn = Callable[[Solution], Optional[Objective]]


@dataclass
class GAConfig:
    pop_size: int = 24
    max_generations: int = 60
    patience: int = 3            # paper: stop after 3 non-improving generations
    min_generations: int = 12    # don't let a converged seed stop the search cold
    cx_prob: float = 0.9
    p_local: float = 0.5
    p_bit: float = 0.05
    p_map: float = 0.08
    p_prio: float = 0.2
    p_cfg: float = 0.1
    seed: int = 0
    # Every N generations, re-evaluate the population's best candidate through
    # the reference oracle (RuntimeSimulator) and record the drift vs the fast
    # engine. 0 disables the check.
    oracle_interval: int = 0
    # False selects the pure-Python NSGA reference implementations (the seed
    # code path, kept for differential testing and seed-path benchmarking).
    vectorized_nsga: bool = True
    # Route whole-generation evaluations (offspring fast evals + front-0
    # accurate re-evals) through the scheduler's batch evaluator instead of
    # the per-child loop. True selects the numpy lock-step engine: fitness
    # values are identical either way (it is bit-exact; enforced by
    # tests/test_ga_determinism.py); only wall-clock and the evaluation
    # counter's cache interleaving differ. The string "compiled" selects
    # the jitted jax.lax.while_loop core instead — much faster at GA
    # widths (BENCH_simspeed.json -> compiled_speedup) under a documented
    # float tolerance rather than bit-exactness, so search trajectories
    # may diverge from the scalar path after many generations.
    batch_eval: "bool | str" = False
    # Route every chromosome through the static analyzer
    # (repro.analysis.schedlint) before objectives(): proven-infeasible
    # candidates get worst-rank fitness without a single simulated event.
    # Sound-only by contract — the analyzer may only flag chromosomes the
    # simulator could never score feasible (structural corruption, memory
    # capacity violations), so with pruning off the search trajectory is
    # bit-identical whenever nothing would have been pruned (enforced by
    # tests/test_schedlint.py).
    prescreen: bool = False
    # Device-in-the-loop feedback (paper §4.2/§5): every N generations the
    # scheduler hands the current Pareto front to ``measure_device``, which
    # executes candidates on the real runtime, writes measured per-subgraph
    # timings back into the ProfileDB and invalidates the evaluation caches
    # (StaticAnalyzer.apply_measured_costs). When measurements changed any
    # profile entry, the fitness memo is flushed and the whole population is
    # re-evaluated — the search continues on measured costs. 0 disables.
    device_in_loop_interval: int = 0


@dataclass
class GAResult:
    pareto: List[Solution]
    history: List[float]           # average population score per generation
    generations: int
    evaluations: int
    oracle_drift: List[Tuple[int, float]] = field(default_factory=list)
    # (generation, changed-profile-entry count) per device-in-the-loop
    # measurement round that actually updated the ProfileDB
    device_updates: List[Tuple[int, int]] = field(default_factory=list)
    # static pre-screen counters: chromosomes checked, pruned as proven
    # infeasible, and the simulator calls those prunes avoided
    prescreen_stats: Dict[str, int] = field(default_factory=dict)


def _dominates(a: Objective, b: Objective) -> bool:
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


class GeneticScheduler:
    def __init__(
        self,
        factory: SolutionFactory,
        evaluate_fast: EvalFn,
        evaluate_accurate: Optional[EvalFn] = None,
        config: Optional[GAConfig] = None,
        evaluate_oracle: Optional[EvalFn] = None,
        evaluate_batch: Optional[BatchEvalFn] = None,
        measure_device: Optional[Callable[[Sequence[Solution]], int]] = None,
        prescreen: Optional[PrescreenFn] = None,
    ):
        self.factory = factory
        self.evaluate_fast = evaluate_fast
        self.evaluate_accurate = evaluate_accurate or evaluate_fast
        self.evaluate_oracle = evaluate_oracle
        self.evaluate_batch = evaluate_batch
        self.measure_device = measure_device
        self.cfg = config or GAConfig()
        self.prescreen = prescreen if self.cfg.prescreen else None
        self.prescreen_stats: Dict[str, int] = {
            "checked": 0, "pruned": 0, "simulations_avoided": 0}
        self.rng = random.Random(self.cfg.seed)
        self.evaluations = 0
        self._cache: Dict[Tuple, Objective] = {}

    # -- evaluation with memoization ------------------------------------------
    def _prescreen(self, sol: Solution) -> Optional[Objective]:
        """Static verdict for ``sol``: a worst-rank objective when the
        analyzer proves infeasibility, else None (simulate normally).

        Never touches ``self.rng``, so with no prunes the search trajectory
        is bit-identical to a prescreen-off run.
        """
        if self.prescreen is None:
            return None
        self.prescreen_stats["checked"] += 1
        obj = self.prescreen(sol)
        if obj is not None:
            self.prescreen_stats["pruned"] += 1
            self.prescreen_stats["simulations_avoided"] += 1
        return obj

    def _eval(self, sol: Solution, accurate: bool = False) -> Objective:
        key = (sol.key(), accurate)
        if key in self._cache:
            return self._cache[key]
        obj = self._prescreen(sol)
        if obj is None:
            fn = self.evaluate_accurate if accurate else self.evaluate_fast
            obj = fn(sol)
            self.evaluations += 1
        self._cache[key] = obj
        return obj

    def _eval_generation(
        self, sols: Sequence[Solution], accurate: bool = False
    ) -> List[Objective]:
        """Evaluate a whole generation, batched when configured.

        Memoization and the evaluation counter behave like per-child
        :meth:`_eval` calls; the batch evaluator additionally dedups by
        decoded content downstream. Falls back to the per-child loop when no
        batch evaluator is wired or ``cfg.batch_eval`` is off.
        """
        if not (self.cfg.batch_eval and self.evaluate_batch is not None):
            return [self._eval(s, accurate) for s in sols]
        missing: List[Solution] = []
        seen = set()
        for s in sols:
            key = (s.key(), accurate)
            if key not in self._cache and key not in seen:
                seen.add(key)
                pruned = self._prescreen(s)
                if pruned is not None:
                    self._cache[key] = pruned
                else:
                    missing.append(s)
        if missing:
            objs = self.evaluate_batch(missing, accurate)
            for s, obj in zip(missing, objs):
                self._cache[(s.key(), accurate)] = obj
                self.evaluations += 1
        return [self._cache[(s.key(), accurate)] for s in sols]

    # -- local search (paper §4.3) ---------------------------------------------
    def _local_merge(self, sol: Solution) -> Solution:
        """Merge neighboring subgraphs: clear one cut bit; keep if dominating."""
        cuts = [
            (net, i)
            for net in range(len(sol.partition))
            for i, b in enumerate(sol.partition[net])
            if b
        ]
        if not cuts:
            return sol
        net, i = self.rng.choice(cuts)
        cand = sol.copy()
        cand.fitness = None
        cand.partition[net][i] = 0
        base = sol.fitness or self._eval(sol)
        obj = self._eval(cand)
        if _dominates(obj, base) or obj == base:
            cand.fitness = obj
            return cand
        return sol

    def _local_reposition(self, sol: Solution) -> Solution:
        """Reposition adjacent layers: pull one layer onto a neighbor's processor."""
        nets = [n for n in range(len(sol.mapping)) if len(sol.mapping[n]) > 1]
        if not nets:
            return sol
        net = self.rng.choice(nets)
        i = self.rng.randrange(len(sol.mapping[net]) - 1)
        cand = sol.copy()
        cand.fitness = None
        if self.rng.random() < 0.5:
            cand.mapping[net][i + 1] = cand.mapping[net][i]
        else:
            cand.mapping[net][i] = cand.mapping[net][i + 1]
        base = sol.fitness or self._eval(sol)
        obj = self._eval(cand)
        if _dominates(obj, base):
            cand.fitness = obj
            return cand
        return sol

    # -- mating ----------------------------------------------------------------
    def _mate(self, parents: Sequence[Solution]) -> List[Solution]:
        """Pair the (already shuffled) parents and produce offspring.

        Adjacent parents mate pairwise. An odd population leaves one
        shuffled parent over; it mates a uniformly drawn partner from the
        rest (itself when the population is a singleton) instead of
        silently sitting the generation out — ``zip(parents[0::2],
        parents[1::2])`` alone drops the last parent from mating every
        generation. Even populations consume exactly the same RNG stream
        as before the fix (the extra draw happens only on the odd path).
        """
        cfg = self.cfg
        pairs = list(zip(parents[0::2], parents[1::2]))
        if len(parents) % 2:
            leftover = parents[-1]
            partner = (parents[self.rng.randrange(len(parents) - 1)]
                       if len(parents) > 1 else leftover)
            pairs.append((leftover, partner))
        offspring: List[Solution] = []
        for a, b in pairs:
            if self.rng.random() < cfg.cx_prob:
                c1, c2 = self.factory.crossover(a, b)
            else:
                c1, c2 = a.copy(), b.copy()
            c1 = self.factory.mutate(c1, cfg.p_bit, cfg.p_map, cfg.p_prio, cfg.p_cfg)
            c2 = self.factory.mutate(c2, cfg.p_bit, cfg.p_map, cfg.p_prio, cfg.p_cfg)
            offspring.extend([c1, c2])
        return offspring

    # -- main loop ------------------------------------------------------------
    def run(self, seeds: Sequence[Solution] = ()) -> GAResult:
        cfg = self.cfg
        pop: List[Solution] = [s.copy() for s in seeds]
        while len(pop) < cfg.pop_size:
            pop.append(self.factory.random_solution())
        pop = pop[: cfg.pop_size]
        for s, obj in zip(pop, self._eval_generation(pop)):
            s.fitness = obj

        history: List[float] = []
        oracle_drift: List[Tuple[int, float]] = []
        device_updates: List[Tuple[int, int]] = []
        stale = 0
        best_avg = float("inf")
        gen = 0
        for gen in range(1, cfg.max_generations + 1):
            # All candidates are parents (paper: avoid premature convergence).
            parents = pop[:]
            self.rng.shuffle(parents)
            offspring = self._mate(parents)
            # whole-generation fast evaluation (batched when configured),
            # then the probabilistic local search pass per child
            for child, obj in zip(offspring, self._eval_generation(offspring)):
                child.fitness = obj
            for k, child in enumerate(offspring):
                if self.rng.random() < cfg.p_local:
                    child = self._local_merge(child)
                    child = self._local_reposition(child)
                    offspring[k] = child
            # Accurate ("brief on-target") evaluation of the candidates that
            # could enter the Pareto set, before the population update.
            combined = pop + offspring
            fits = [list(s.fitness) for s in combined]
            front0 = fast_non_dominated_sort(fits, vectorized=cfg.vectorized_nsga)[0]
            front0_objs = self._eval_generation(
                [combined[ix] for ix in front0], accurate=True)
            for ix, obj in zip(front0, front0_objs):
                combined[ix].fitness = obj
            fits = [list(s.fitness) for s in combined]
            keep = nsga3_select(fits, cfg.pop_size, rng=self.rng,
                                vectorized=cfg.vectorized_nsga)
            pop = [combined[i] for i in keep]

            if (
                self.measure_device is not None
                and cfg.device_in_loop_interval > 0
                and gen % cfg.device_in_loop_interval == 0
            ):
                # brief on-target execution of the Pareto candidates: feed
                # measured costs back, then re-rank everything on them
                fits = [list(s.fitness) for s in pop]
                front0 = fast_non_dominated_sort(
                    fits, vectorized=cfg.vectorized_nsga)[0]
                changed = self.measure_device([pop[i] for i in front0])
                if changed:
                    device_updates.append((gen, changed))
                    self._cache.clear()
                    for s, obj in zip(pop, self._eval_generation(pop)):
                        s.fitness = obj
            avg = sum(sum(s.fitness) for s in pop) / len(pop)
            history.append(avg)
            if (
                self.evaluate_oracle is not None
                and cfg.oracle_interval > 0
                and gen % cfg.oracle_interval == 0
            ):
                # reference-oracle spot check: the fast engine is exact, so
                # any drift on the best candidate flags a parity regression.
                best = min(pop, key=lambda s: sum(s.fitness))
                ref = self.evaluate_oracle(best)
                fast = self._eval(best)
                drift = max(
                    abs(a - b) for a, b in zip(ref, fast)
                ) if ref and fast else 0.0
                oracle_drift.append((gen, drift))
            if avg < best_avg - 1e-12:
                best_avg = avg
                stale = 0
            else:
                stale += 1
            if stale >= cfg.patience and gen >= cfg.min_generations:
                break

        fits = [list(s.fitness) for s in pop]
        pareto_ix = fast_non_dominated_sort(fits, vectorized=cfg.vectorized_nsga)[0]
        # dedupe identical chromosomes
        seen = set()
        pareto: List[Solution] = []
        for i in pareto_ix:
            k = pop[i].key()
            if k not in seen:
                seen.add(k)
                pareto.append(pop[i])
        return GAResult(
            pareto=pareto, history=history, generations=gen,
            evaluations=self.evaluations, oracle_drift=oracle_drift,
            device_updates=device_updates,
            prescreen_stats=dict(self.prescreen_stats),
        )
