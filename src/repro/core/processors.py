"""Processor descriptors: mobile SoC processors and TPU mesh lanes.

The paper targets a Snapdragon 8 Gen 2 (CPU/GPU/NPU). The TPU adaptation
replaces processor heterogeneity with *lane* heterogeneity: disjoint
sub-meshes of a pod slice with different chip counts (DESIGN.md §2).
Both are described by the same :class:`Processor` record so the scheduler,
simulator and runtime are agnostic to which world they run in.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

# TPU v5e per-chip constants (also used by launch/roofline.py).
TPU_PEAK_FLOPS_BF16 = 197e12      # FLOP/s
TPU_HBM_BW = 819e9                # bytes/s
TPU_ICI_BW = 50e9                 # bytes/s per link


@dataclass(frozen=True)
class Processor:
    """One execution resource the scheduler can map subgraphs onto."""

    pid: int
    name: str
    kind: str                       # 'cpu' | 'gpu' | 'npu' | 'tpu-lane'
    # Analytic-backend parameters -------------------------------------------
    # effective MAC/s by (dtype, backend); missing entries are unsupported
    # and fall back with `fallback_penalty`.
    throughput: Tuple[Tuple[Tuple[str, str], float], ...] = ()
    invocation_overhead: float = 50e-6   # fixed cost per subgraph execution
    layer_overhead: float = 2e-6         # dispatch cost per layer in a subgraph
    # Non-linearity of execution time (§2.1.2): single-layer subgraphs are
    # `fragmentation_ratio` times slower per MAC than the whole fused graph.
    fragmentation_ratio: float = 1.0
    fallback_penalty: float = 30.0       # NNAPI-like worst case (Table 2)
    # Tensor-memory budget in bytes for weights + live activations on this
    # processor (chunk-rounded per runtime/tensorpool.py). 0 = unconstrained;
    # the static analyzer (repro.analysis) rejects schedules whose peak
    # residency lower bound provably exceeds a nonzero budget.
    memory_capacity: int = 0
    # TPU-lane parameters ------------------------------------------------------
    chips: int = 0
    peak_flops: float = 0.0
    hbm_bw: float = 0.0

    def thr(self, dtype: str, backend: str) -> Optional[float]:
        for (dt, be), v in self.throughput:
            if dt == dtype and be == backend:
                return v
        return None


def mobile_processors() -> Tuple[Processor, ...]:
    """CPU/GPU/NPU of the paper's Galaxy S23 Ultra, calibrated so the
    analytic backend reproduces the magnitudes of Tables 2–4.

    Throughputs are effective MAC/s fitted from Table 3 (best-config fp16)
    across the nine models; per-config ratios follow Table 2's structure
    (XNNPACK vs default, NNAPI disaster, fp16 ≈ 2× fp32 where supported).
    """
    cpu = Processor(
        pid=0, name="CPU", kind="cpu",
        throughput=(
            (("fp32", "default"), 18e9),
            (("fp16", "default"), 26e9),
            (("fp32", "xnnpack"), 30e9),
            (("fp16", "xnnpack"), 38e9),
            (("fp32", "nnapi"), 0.9e9),
            (("fp16", "nnapi"), 0.9e9),
            (("int8", "default"), 40e9),
            (("int8", "xnnpack"), 55e9),
        ),
        invocation_overhead=120e-6,
        layer_overhead=4e-6,
        fragmentation_ratio=1.05,   # Table 4: CPU estimated ≈ measured
    )
    gpu = Processor(
        pid=1, name="GPU", kind="gpu",
        throughput=(
            (("fp32", "default"), 90e9),
            (("fp16", "default"), 170e9),
            (("int8", "default"), 200e9),
        ),
        invocation_overhead=400e-6,  # kernel scheduling overheads (Table 4 GPU)
        layer_overhead=12e-6,
        fragmentation_ratio=1.25,
    )
    npu = Processor(
        pid=2, name="NPU", kind="npu",
        throughput=(
            (("fp16", "default"), 1.6e12),
            (("int8", "default"), 2.6e12),
        ),
        invocation_overhead=150e-6,
        layer_overhead=1e-6,
        # Table 4: Σ(layers)/measured on NPU is 1.4×–3.45× -> heavy loss of
        # intra-NPU operator parallelism when fragmented.
        fragmentation_ratio=2.4,
    )
    return (cpu, gpu, npu)


def tpu_lanes(spec: Sequence[int] = (128, 64, 32, 16), pod_chips: int = 256
              ) -> Tuple[Processor, ...]:
    """Partition a pod slice into heterogeneous lanes (DESIGN.md §2).

    Chip counts must sum to <= pod_chips. Effective FLOP/s scales sub-
    linearly with chips for small models (communication), which the lane
    profiler backend accounts for; here we record raw capacity.
    """
    assert sum(spec) <= pod_chips, "lanes exceed pod"
    lanes = []
    for i, chips in enumerate(spec):
        lanes.append(
            Processor(
                pid=i, name=f"lane{i}x{chips}", kind="tpu-lane",
                chips=chips,
                peak_flops=chips * TPU_PEAK_FLOPS_BF16,
                hbm_bw=chips * TPU_HBM_BW,
                invocation_overhead=8e-6,
                layer_overhead=0.5e-6,
                fragmentation_ratio=1.15,
                throughput=((("fp16", "default"), chips * TPU_PEAK_FLOPS_BF16 / 2),
                            (("int8", "default"), chips * TPU_PEAK_FLOPS_BF16),),
            )
        )
    return tuple(lanes)
