"""Communication cost modeling (paper §4.1).

Inter-processor data transfer = RPC overhead (marshalling/unmarshalling,
piecewise-linear in data size with a knee at 1 MiB) + transfer time at the
main-memory bandwidth (≈40 GB/s on the paper's Galaxy S23U; ICI/HBM numbers
for the TPU adaptation).

``PiecewiseLinearCommModel.fit`` performs the paper's piecewise-linear
regression; ``microbenchmark_host`` produces real (size, seconds) samples on
this machine by timing serialize+copy round-trips, which is the
device-in-the-loop way to calibrate the model where no Galaxy S23U exists.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple

import numpy as np

MIB = float(1 << 20)

# Galaxy S23U constants measured in the paper.
PAPER_MEMORY_BW = 40e9  # bytes/s (§4.1, STREAM on Galaxy S23U)

# TPU v5e lane-boundary constants (target hardware; used by the TPU-adapted
# serving experiments).
TPU_ICI_BW = 50e9       # bytes/s per link
TPU_DISPATCH_OVERHEAD = 5e-6


@dataclass(frozen=True)
class PiecewiseLinearCommModel:
    """``cost(n) = a_lo + b_lo*n`` below the knee, ``a_hi + b_hi*n`` above,
    plus ``n / bandwidth`` transfer time."""

    a_lo: float
    b_lo: float
    a_hi: float
    b_hi: float
    knee: float = MIB
    bandwidth: float = PAPER_MEMORY_BW

    def rpc_overhead(self, nbytes: float) -> float:
        if nbytes < self.knee:
            return max(0.0, self.a_lo + self.b_lo * nbytes)
        return max(0.0, self.a_hi + self.b_hi * nbytes)

    def transfer_time(self, nbytes: float) -> float:
        return nbytes / self.bandwidth

    def cost(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.rpc_overhead(nbytes) + self.transfer_time(nbytes)

    @classmethod
    def fit(
        cls,
        samples: Sequence[Tuple[float, float]],
        knee: float = MIB,
        bandwidth: float = PAPER_MEMORY_BW,
    ) -> "PiecewiseLinearCommModel":
        """Least-squares fit of the two linear regions around a fixed knee.

        ``samples`` are (bytes, seconds) of *total* observed cost; the
        transfer component ``bytes/bandwidth`` is subtracted before fitting
        the RPC overhead, matching the paper's decomposition.
        """
        lo = [(n, t - n / bandwidth) for n, t in samples if n < knee]
        hi = [(n, t - n / bandwidth) for n, t in samples if n >= knee]

        def linfit(pts: List[Tuple[float, float]]) -> Tuple[float, float]:
            if not pts:
                return 0.0, 0.0
            if len(pts) == 1:
                return max(0.0, pts[0][1]), 0.0
            xs = np.array([p[0] for p in pts])
            ys = np.array([p[1] for p in pts])
            A = np.stack([np.ones_like(xs), xs], axis=1)
            coef, *_ = np.linalg.lstsq(A, ys, rcond=None)
            return float(coef[0]), float(coef[1])

        a_lo, b_lo = linfit(lo)
        a_hi, b_hi = linfit(hi)
        if not lo:
            a_lo, b_lo = a_hi, b_hi
        if not hi:
            a_hi, b_hi = a_lo, b_lo
        return cls(a_lo=a_lo, b_lo=b_lo, a_hi=a_hi, b_hi=b_hi, knee=knee, bandwidth=bandwidth)


# A model calibrated to the shape of the paper's Fig. 5 measurements on the
# Galaxy S23U: ~60 us fixed RPC dispatch below 1 MiB with a shallow slope,
# then a steeper marshalling slope above the knee.
PAPER_COMM_MODEL = PiecewiseLinearCommModel(
    a_lo=60e-6, b_lo=25e-12, a_hi=90e-6, b_hi=45e-12, knee=MIB, bandwidth=PAPER_MEMORY_BW
)

# TPU lane-boundary model: fixed dispatch + ICI bandwidth. Used by the
# TPU-adapted multi-model serving experiments.
TPU_COMM_MODEL = PiecewiseLinearCommModel(
    a_lo=TPU_DISPATCH_OVERHEAD, b_lo=0.0, a_hi=TPU_DISPATCH_OVERHEAD, b_hi=0.0,
    knee=MIB, bandwidth=TPU_ICI_BW,
)


def quantization_cost(nbytes: float, bandwidth: float = PAPER_MEMORY_BW) -> float:
    """(De)quantization pass cost when producer/consumer dtypes differ (§5.1).

    Modeled as one streaming read+write over the tensor.
    """
    if nbytes <= 0:
        return 0.0
    return 2.0 * nbytes / bandwidth + 10e-6


def microbenchmark_host(
    sizes: Iterable[int] = (1 << 12, 1 << 16, 1 << 20, 1 << 22, 1 << 24),
    repeats: int = 5,
) -> List[Tuple[float, float]]:
    """Measure real serialize+copy round-trip times on this host.

    This is the microbenchmark role from §4.1 — producing (bytes, seconds)
    samples for :meth:`PiecewiseLinearCommModel.fit`.
    """
    samples: List[Tuple[float, float]] = []
    for n in sizes:
        src = np.random.default_rng(0).integers(0, 255, size=n, dtype=np.uint8)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            blob = src.tobytes()               # marshalling
            out = np.frombuffer(blob, dtype=np.uint8).copy()  # unmarshal + copy
            best = min(best, time.perf_counter() - t0)
        assert out.shape == src.shape
        samples.append((float(n), best))
    return samples
