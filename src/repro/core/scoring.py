"""XRBench-style scoring (paper §6.2).

Implements makespan aggregation, QoE score, Realtime score (k = 15),
the combined scenario score, and the *saturation multiplier*
α* = min{α | Score(α, S) = 1.0} used as the headline comparison metric.

Deadline semantics under pluggable arrivals
-------------------------------------------
Request *i* of a group must finish by the **absolute** deadline
``arrival_i + Φ`` where Φ is the group's (α-scaled) period — under
periodic arrivals that degenerates to "finish before the next request",
but the per-request form is what generalizes to jittered / Poisson /
traced sources (:mod:`repro.core.arrivals`). Every function here takes
*makespans*, which the simulators measure **relative to each request's own
arrival** (``Θ_i = last_finish_i − arrival_i``; a task can never start
before its request arrives), so the check ``Θ_i ≤ Φ`` is exactly the
absolute-deadline check for any arrival process. ``deadline`` arguments
throughout are therefore the *relative* deadline Φ, never an absolute
timestamp; :func:`absolute_deadlines` materializes the per-request
absolute form when a caller needs it (reports, trace tooling).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Generator, List, Optional, Sequence, Tuple

RT_K = 15.0  # sigmoid sharpness, same as XRBench

#: The paper's α lattice: 0.2 .. 6.0 in 0.05 steps. Shared by the grid scan,
#: the bisection defaults and the batched population search so they always
#: probe the same points.
ALPHA_GRID = tuple(round(0.2 + 0.05 * i, 4) for i in range(117))


def absolute_deadlines(arrivals: Sequence[float], phi: float) -> List[float]:
    """Per-request absolute deadlines ``arrival_i + Φ``.

    The explicit form of the scoring contract above: request *i* arriving
    at ``arrival_i`` must finish by ``arrival_i + Φ``. Equivalent to
    checking the arrival-relative makespan against Φ, which is what the
    scoring functions do; this helper exists for callers that work with
    absolute trace timestamps instead of makespans.
    """
    return [a + phi for a in arrivals]


def qoe_score(makespans: Sequence[float], deadline: float) -> float:
    """Fraction of requests finishing within the relative deadline Φ
    (equivalently: by their absolute deadline ``arrival_i + Φ``)."""
    if not makespans:
        return 0.0
    ok = sum(1 for m in makespans if m <= deadline)
    return ok / len(makespans)


def rt_score(makespan: float, deadline: float, k: float = RT_K) -> float:
    """Sigmoid realtime score of one request.

    XRBench's sigmoid is deadline-normalized — the argument is the slack
    *ratio* ``Θ/Φ − 1``, not an absolute time difference (otherwise k = 15
    could never saturate at millisecond scales).
    """
    if math.isinf(makespan):
        return 0.0
    if deadline <= 0:
        return 0.0
    x = k * (makespan / deadline - 1.0)
    if x > 60:
        return 0.0
    if x < -60:
        return 1.0
    return 1.0 / (1.0 + math.exp(x))


def group_scores(
    makespans: Sequence[float], deadline: float, k: float = RT_K
) -> Tuple[float, float]:
    """(mean RtScore, QoE) for one model group."""
    if not makespans:
        return 0.0, 0.0
    rt = sum(rt_score(m, deadline, k) for m in makespans) / len(makespans)
    return rt, qoe_score(makespans, deadline)


def scenario_score(
    per_group_makespans: Sequence[Sequence[float]],
    per_group_deadlines: Sequence[float],
    k: float = RT_K,
) -> float:
    """Score(α, S) = (1/N) Σ_G mean-RtScore(G) × QoE(G)."""
    n = len(per_group_makespans)
    if n == 0:
        return 0.0
    total = 0.0
    for ms, dl in zip(per_group_makespans, per_group_deadlines):
        rt, qoe = group_scores(ms, dl, k)
        total += rt * qoe
    return total / n


def deadline_satisfaction(
    per_group_makespans: Sequence[Sequence[float]],
    per_group_deadlines: Sequence[float],
) -> float:
    """Fraction of *all* requests (pooled across groups) meeting their
    group's deadline.

    Unlike :func:`scenario_score` this is a plain hit rate — no sigmoid, no
    per-group averaging — so it is the "satisfying the equivalent level of
    real-time requirements" check of the paper's headline claim. Makespans
    and deadlines are in the same unit (seconds throughout this repo);
    dropped requests (``inf`` makespan) count as misses. Returns 0.0 for an
    empty scenario. Raises ``ValueError`` when the number of makespan groups
    and deadlines disagree (a silently truncating ``zip`` would under-count).
    """
    if len(per_group_makespans) != len(per_group_deadlines):
        raise ValueError(
            f"group count mismatch: {len(per_group_makespans)} makespan "
            f"groups vs {len(per_group_deadlines)} deadlines")
    total = 0
    ok = 0
    for ms, dl in zip(per_group_makespans, per_group_deadlines):
        for m in ms:
            total += 1
            # the isinf guard matters only for an infinite deadline, where
            # `inf <= inf` would count a dropped request as a hit
            if m <= dl and not math.isinf(m):
                ok += 1
    return ok / total if total else 0.0


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]).

    inf-safe: when q lands exactly on a sample, that sample is returned
    directly instead of interpolating (``vals[lo] + 0.0 * inf`` would be
    NaN when the next sample is ``inf``, e.g. an unsaturated α*).
    """
    vals = sorted(values)
    if not vals:
        return float("inf")
    if len(vals) == 1:
        return vals[0]
    pos = (len(vals) - 1) * q / 100.0
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    if frac == 0.0 or vals[lo] == vals[hi]:
        return vals[lo]
    return vals[lo] * (1 - frac) + vals[hi] * frac


@dataclass
class SaturationResult:
    alpha_star: float
    scores: List[Tuple[float, float]]  # (alpha, score) samples


def saturation_multiplier(
    evaluate: Callable[[float], float],
    alphas: Optional[Sequence[float]] = None,
    threshold: float = 0.995,
) -> SaturationResult:
    """α* = min α with Score(α) ≥ threshold (paper treats 1.0 as saturated).

    ``evaluate(alpha)`` must return the scenario score when every group's
    period is ``alpha × base_period``. Scans a grid ascending; scores are
    typically monotone in α but contention noise can wiggle them, so we
    return the first α from which the score stays saturated.
    """
    if alphas is None:
        alphas = ALPHA_GRID
    samples: List[Tuple[float, float]] = []
    sat_from: Optional[float] = None
    for a in alphas:
        s = evaluate(a)
        samples.append((a, s))
        if s >= threshold:
            if sat_from is None:
                sat_from = a
        else:
            sat_from = None
    return SaturationResult(
        alpha_star=sat_from if sat_from is not None else float("inf"),
        scores=samples,
    )


def bisect_alpha_probes(
    lo: float = 0.2,
    hi: float = 6.0,
    step: float = 0.05,
    threshold: float = 0.995,
    confirm: int = 4,
    skip_below: float = 0.0,
) -> Generator[float, float, float]:
    """Generator core of the bracket-then-bisect α*-search.

    Yields the α value to evaluate next; the driver sends back the score.
    Returns (via ``StopIteration.value``) the final
    :class:`SaturationResult`. Factoring the probe *sequence* out of the
    evaluation lets the scalar search and the population-batched search
    (``StaticAnalyzer.population_saturation``) share one algorithm, so they
    probe identical lattice points and return identical results by
    construction.

    ``skip_below`` is the static analyzer's proven infeasibility bound: the
    caller guarantees ``score(α) < threshold`` for every ``α < skip_below``
    (repro.analysis deadline lower bounds). Probes strictly below it are
    answered with score 0.0 without yielding — i.e. without simulating —
    which cannot change α* as long as the guarantee holds (the skipped
    probes appear in ``scores`` as 0.0 samples).
    """
    n = int(round((hi - lo) / step))
    cache: Dict[int, float] = {}

    def ev(i: int) -> Generator[float, float, float]:
        s = cache.get(i)
        if s is None:
            if round(lo + step * i, 4) < skip_below:
                s = 0.0  # proven < threshold by the caller; don't simulate
            else:
                s = yield round(lo + step * i, 4)
            cache[i] = s
        return s

    def result(alpha_star: float) -> SaturationResult:
        samples = sorted((round(lo + step * i, 4), s) for i, s in cache.items())
        return SaturationResult(alpha_star=alpha_star, scores=samples)

    if (yield from ev(n)) < threshold:
        return result(float("inf"))
    floor = -1  # highest lattice index known (or assumed) unsaturated
    while True:
        a, b = floor, n  # invariant: ev(b) >= threshold
        while b - a > 1:
            mid = (a + b) // 2
            if (yield from ev(mid)) >= threshold:
                b = mid
            else:
                a = mid
        dip = None
        for j in range(b + 1, min(b + confirm + 1, n)):
            if (yield from ev(j)) < threshold:
                dip = j
                break
        if dip is None:
            return result(round(lo + step * b, 4))
        floor = dip  # dip strictly above the previous bracket → terminates


def saturation_multiplier_bisect(
    evaluate: Callable[[float], float],
    lo: float = 0.2,
    hi: float = 6.0,
    step: float = 0.05,
    threshold: float = 0.995,
    confirm: int = 4,
    skip_below: float = 0.0,
) -> SaturationResult:
    """Bracket-then-bisect α*-search over the (near-monotone) score curve.

    Evaluates on the same ``lo + step·i`` lattice as the linear scan of
    :func:`saturation_multiplier` so results are directly comparable, but
    needs ~15 ``evaluate`` calls instead of ~117:

    1. If the score at ``hi`` is unsaturated, no α saturates → inf (matches
       the grid semantics, where a dip at the last sample clears ``sat_from``).
    2. Bisect for the smallest lattice point with score ≥ threshold.
    3. Confirmation scan: check the next ``confirm`` lattice points above the
       candidate; contention noise can wiggle the curve, so a dip there
       restarts the bracket above the dip (the paper's "stays saturated"
       semantics). Dips wider than ``confirm`` grid points between the
       candidate and ``hi`` can be missed — that is the accuracy/speed
       trade-off versus the exhaustive scan.

    The probe sequence itself lives in :func:`bisect_alpha_probes`; this
    wrapper drives it with a plain callable. ``skip_below`` forwards the
    analyzer's proven infeasibility bound (see :func:`bisect_alpha_probes`).
    """
    gen = bisect_alpha_probes(lo, hi, step, threshold, confirm, skip_below)
    try:
        alpha = next(gen)
        while True:
            alpha = gen.send(evaluate(alpha))
    except StopIteration as stop:
        return stop.value
