"""The paper's nine mobile networks as schedulable layer DAGs (Table 6).

Two faces per model:

* a **cost graph** (:class:`~repro.core.graph.ModelGraph`) with the paper's
  MAC/parameter totals distributed over a plausible conv-net layer DAG
  (backbone chain + skip/branch merges) — what the Static Analyzer
  schedules when reproducing the paper's experiments with the
  :class:`TableBackend`;
* an **executable reduction** (:class:`ExecutableMobileModel`) — a real JAX
  conv network with the same DAG topology, small enough to run on this
  host's CPU in milliseconds, used by the :class:`JaxExecBackend` for
  literal device-in-the-loop profiling and by the Runtime's engines.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from ..core.graph import Edge, Layer, ModelGraph
from .profiles import MODEL_NAMES, MODEL_SPECS

_DTYPE_BYTES = {"fp32": 4, "fp16": 2, "int8": 1}


def _mac_profile(n: int) -> np.ndarray:
    """Plausible per-layer MAC share: ramps up, peaks mid-network, tails off."""
    x = np.linspace(0.0, 1.0, n)
    w = 0.35 + np.sin(np.pi * x) ** 2 + 0.25 * x
    return w / w.sum()


def _activation_bytes(n: int, input_bytes: int) -> List[int]:
    """Activation sizes: decay from input size as resolution drops."""
    sizes = []
    for i in range(n):
        decay = 0.5 ** (3.0 * i / max(n - 1, 1))  # ~8x total reduction
        sizes.append(max(int(input_bytes * decay), 4096))
    return sizes


def _skip_positions(n: int) -> List[int]:
    """Indices whose layer merges a skip connection (FPN/residual style)."""
    if n < 8:
        return []
    return [i for i in range(4, n - 1, 5)]


def make_cost_graph(name: str) -> ModelGraph:
    """Build the schedulable cost DAG calibrated to Table 6 totals."""
    spec = MODEL_SPECS[name]
    n = int(spec["layers"])
    h, w = spec["input"][1], spec["input"][2]
    input_bytes = int(h * w * 3 * 4)
    mac_share = _mac_profile(n)
    act = _activation_bytes(n, input_bytes)
    skips = set(_skip_positions(n))
    layers: List[Layer] = []
    param_share = mac_share / mac_share.sum()
    for i in range(n):
        op = "add_merge" if i in skips else ("conv" if i % 3 else "dwconv")
        attrs: Tuple[Tuple[str, object], ...] = (("model", name),)
        if i == 0:
            attrs = attrs + (("input_bytes", input_bytes),)
        layers.append(
            Layer(
                index=i,
                name=f"{name}.{i}",
                op_type=op,
                macs=float(spec["macs"] * mac_share[i]),
                param_bytes=int(spec["params"] * 4 * param_share[i]),
                out_bytes=act[i],
                attrs=attrs,
            )
        )
    edges: List[Edge] = []
    k = 0
    for i in range(n - 1):
        edges.append(Edge(index=k, src=i, dst=i + 1, bytes_=act[i]))
        k += 1
    for s in sorted(skips):
        src = s - 3
        if src >= 0:
            edges.append(Edge(index=k, src=src, dst=s, bytes_=act[src]))
            k += 1
    return ModelGraph(name, layers, edges)


def all_cost_graphs() -> Dict[str, ModelGraph]:
    return {name: make_cost_graph(name) for name in MODEL_NAMES}


# ---------------------------------------------------------------------------
# Executable reductions: real JAX conv nets with the same topology.
# ---------------------------------------------------------------------------


class ExecutableMobileModel:
    """A small real conv network matching a cost graph's DAG topology.

    Layers operate on NHWC tensors of fixed spatial size; `add_merge`
    layers consume (chain_input, skip_input). `build_subgraph_fn` returns a
    jit-able function computing the subgraph outputs from its boundary
    inputs — this is what the device-in-the-loop profiler times and what
    the Runtime engines execute.
    """

    def __init__(self, name: str, channels: int = 8, spatial: int = 16, seed: int = 0):
        import jax
        import jax.numpy as jnp

        self.name = name
        self.graph = make_cost_graph(name)
        self.channels = channels
        self.spatial = spatial
        key = jax.random.PRNGKey(seed)
        self._weights: Dict[int, np.ndarray] = {}
        for layer in self.graph.layers:
            key, sub = jax.random.split(key)
            if layer.op_type in ("conv", "dwconv"):
                self._weights[layer.index] = np.asarray(
                    jax.random.normal(sub, (3, 3, channels, channels)) * 0.05,
                    dtype=np.float32,
                )
        self._jnp = jnp
        self._jax = jax

    # -- layer semantics -------------------------------------------------------
    def _apply_layer(self, lid: int, inputs: Sequence, dtype):
        jnp = self._jnp
        import jax

        layer = self.graph.layers[lid]
        x = inputs[0]
        if layer.op_type == "add_merge":
            out = x
            for other in inputs[1:]:
                out = out + other
            return jax.nn.relu(out)
        w = jnp.asarray(self._weights[lid], dtype=dtype)
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return jax.nn.relu(y)

    def _np_dtype(self, dtype: str):
        jnp = self._jnp
        return {"fp32": jnp.float32, "fp16": jnp.bfloat16, "int8": jnp.bfloat16}[dtype]

    def input_shape(self) -> Tuple[int, int, int, int]:
        return (1, self.spatial, self.spatial, self.channels)

    def build_subgraph_fn(
        self, layer_ids: Sequence[int], dtype: str = "fp32"
    ) -> Tuple[Callable, Tuple]:
        """(fn, example_args) computing this subgraph from boundary inputs."""
        jnp = self._jnp
        dt = self._np_dtype(dtype)
        ids = sorted(layer_ids)
        id_set = set(ids)
        # boundary inputs: one per external dependency + model input for sources
        ext_inputs: List[Tuple[int, int]] = []  # (src_layer, dst_layer)
        for lid in ids:
            preds = [e.src for e in self.graph.in_edges[lid]]
            if not preds:
                ext_inputs.append((-1, lid))
            for p in preds:
                if p not in id_set:
                    ext_inputs.append((p, lid))

        def fn(*args):
            env: Dict[int, object] = {}
            ext = {pair: a for pair, a in zip(ext_inputs, args)}
            for lid in ids:
                preds = [e.src for e in self.graph.in_edges[lid]]
                ins = []
                if not preds:
                    ins.append(ext[(-1, lid)])
                for p in preds:
                    ins.append(env[p] if p in id_set else ext[(p, lid)])
                env[lid] = self._apply_layer(lid, ins, dt)
            outs = [env[lid] for lid in ids
                    if all(e.dst not in id_set for e in self.graph.out_edges[lid])
                    or not self.graph.out_edges[lid]]
            return outs[0] if len(outs) == 1 else tuple(outs)

        shape = self.input_shape()
        args = tuple(
            jnp.zeros(shape, dtype=dt) + 0.1 for _ in ext_inputs
        )
        return fn, args


def executable_zoo(
    names: Sequence[str] = MODEL_NAMES, channels: int = 8, spatial: int = 16
) -> Dict[str, ExecutableMobileModel]:
    return {n: ExecutableMobileModel(n, channels=channels, spatial=spatial) for n in names}
