"""Mobile model zoo: the paper's nine networks + measured profile tables."""
from .mobile import ExecutableMobileModel, all_cost_graphs, executable_zoo, make_cost_graph
from .profiles import (
    MODEL_NAMES,
    MODEL_SPECS,
    TABLE4_RATIO,
    best_processor_times_s,
    paper_profile_tables,
)

__all__ = [k for k in dir() if not k.startswith("_")]
