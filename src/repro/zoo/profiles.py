"""The paper's measured profiling data (Tables 2, 3, 4, 6), Galaxy S23 Ultra.

These numbers seed the :class:`~repro.core.profiler.TableBackend` so the
paper-faithful experiments use the paper's own device measurements — the
honest substitute for a Galaxy S23U in this environment (DESIGN.md §2).

Units: seconds. Keys: model name -> (processor kind, dtype, backend) -> s.
"""
from __future__ import annotations

from typing import Dict, Tuple

# Table 6: models with MAC counts and parameter counts.
MODEL_SPECS: Dict[str, Dict[str, float]] = {
    "face_det":    {"macs": 39.2e6,    "params": 0.6e6,  "layers": 12, "input": (1, 128, 128, 3)},
    "selfie_seg":  {"macs": 72.3e6,    "params": 0.1e6,  "layers": 14, "input": (1, 256, 256, 3)},
    "hand_det":    {"macs": 410.8e6,   "params": 2.0e6,  "layers": 18, "input": (1, 192, 192, 3)},
    "pose_det":    {"macs": 444.2e6,   "params": 3.4e6,  "layers": 18, "input": (1, 224, 224, 3)},
    "tcmonodepth": {"macs": 2313.2e6,  "params": 0.2e6,  "layers": 22, "input": (1, 256, 256, 3)},
    "fast_scnn":   {"macs": 2358.9e6,  "params": 1.1e6,  "layers": 20, "input": (1, 512, 512, 3)},
    "yolov8n":     {"macs": 4891.3e6,  "params": 3.2e6,  "layers": 24, "input": (1, 640, 640, 3)},
    "mosaic":      {"macs": 22055.1e6, "params": 1.8e6,  "layers": 28, "input": (1, 512, 512, 3)},
    "fastsam_s":   {"macs": 22325.1e6, "params": 11.8e6, "layers": 28, "input": (1, 640, 640, 3)},
}

MODEL_NAMES = tuple(MODEL_SPECS.keys())

_MS = 1e-3

# Table 2: CPU execution times by (dtype, backend), ms.
_TABLE2_CPU: Dict[str, Dict[Tuple[str, str], float]] = {
    #                 (fp32,default) (fp16,default) (fp32,xnnpack) (fp16,xnnpack) (fp32,nnapi) (fp16,nnapi)
    "face_det":    {("fp32", "default"): 2.6,  ("fp16", "default"): 6.0,  ("fp32", "xnnpack"): 1.6,  ("fp16", "xnnpack"): 5.5,  ("fp32", "nnapi"): 201.0,  ("fp16", "nnapi"): 208.5},
    "selfie_seg":  {("fp32", "default"): 4.3,  ("fp16", "default"): 3.5,  ("fp32", "xnnpack"): 3.1,  ("fp16", "xnnpack"): 3.6,  ("fp32", "nnapi"): 106.8,  ("fp16", "nnapi"): 110.2},
    "hand_det":    {("fp32", "default"): 24.3, ("fp16", "default"): 5.8,  ("fp32", "xnnpack"): 8.5,  ("fp16", "xnnpack"): 7.9,  ("fp32", "nnapi"): 198.5,  ("fp16", "nnapi"): 205.1},
    "pose_det":    {("fp32", "default"): 16.3, ("fp16", "default"): 6.1,  ("fp32", "xnnpack"): 8.7,  ("fp16", "xnnpack"): 8.0,  ("fp32", "nnapi"): 286.0,  ("fp16", "nnapi"): 287.7},
    "tcmonodepth": {("fp32", "default"): 93.8, ("fp16", "default"): 73.2},
    "fast_scnn":   {("fp32", "default"): 73.2, ("fp16", "default"): 37.3},
    "yolov8n":     {("fp32", "default"): 73.0, ("fp16", "default"): 58.6, ("fp32", "xnnpack"): 74.5, ("fp16", "xnnpack"): 61.6, ("fp32", "nnapi"): 638.7,  ("fp16", "nnapi"): 642.9},
    "mosaic":      {("fp32", "default"): 582.5, ("fp16", "default"): 252.6, ("fp32", "xnnpack"): 373.7, ("fp16", "xnnpack"): 213.0, ("fp32", "nnapi"): 1211.7, ("fp16", "nnapi"): 1208.4},
    "fastsam_s":   {("fp32", "default"): 314.6, ("fp16", "default"): 220.3, ("fp32", "xnnpack"): 297.4, ("fp16", "xnnpack"): 192.4, ("fp32", "nnapi"): 1255.8, ("fp16", "nnapi"): 1256.8},
}

# Table 3: best-config times per processor (fp16), ms.
_TABLE3: Dict[str, Dict[str, float]] = {
    #               CPU    GPU    NPU
    "face_det":    {"cpu": 1.6,   "gpu": 1.9,  "npu": 0.3},
    "selfie_seg":  {"cpu": 3.1,   "gpu": 6.5,  "npu": 1.0},
    "hand_det":    {"cpu": 5.8,   "gpu": 4.9,  "npu": 1.2},
    "pose_det":    {"cpu": 6.1,   "gpu": 4.9,  "npu": 1.1},
    "tcmonodepth": {"cpu": 73.2,  "gpu": 31.7, "npu": 32.4},
    "fast_scnn":   {"cpu": 37.3,  "gpu": 12.9, "npu": 22.0},
    "yolov8n":     {"cpu": 58.6,  "gpu": 16.0, "npu": 5.3},
    "mosaic":      {"cpu": 213.0, "gpu": 83.8, "npu": 163.9},
    "fastsam_s":   {"cpu": 192.4, "gpu": 43.4, "npu": 9.1},
}

# Table 4: Estimated/Measured ratios (Σ per-layer vs whole graph) — the
# non-linearity of execution time. Used to validate fragmentation_penalty.
TABLE4_RATIO: Dict[str, Dict[str, float]] = {
    "face_det":    {"cpu": 0.99, "gpu": 0.68, "npu": 1.42},
    "selfie_seg":  {"cpu": 1.05, "gpu": 0.85, "npu": 2.75},
    "hand_det":    {"cpu": 1.01, "gpu": 0.83, "npu": 1.69},
    "pose_det":    {"cpu": 1.00, "gpu": 0.80, "npu": 1.97},
    "tcmonodepth": {"cpu": 0.99, "gpu": 0.92, "npu": 2.13},
    "fast_scnn":   {"cpu": 0.95, "gpu": 0.84, "npu": 2.86},
    "yolov8n":     {"cpu": 1.00, "gpu": 0.88, "npu": 2.40},
    "mosaic":      {"cpu": 0.97, "gpu": 0.93, "npu": 3.45},
    "fastsam_s":   {"cpu": 1.01, "gpu": 0.90, "npu": 1.70},
}


def paper_profile_tables() -> Dict[str, Dict[Tuple[str, str, str], float]]:
    """Flatten Tables 2/3 into the TableBackend schema.

    CPU entries come straight from Table 2. GPU/NPU: Table 3 gives the best
    fp16 configuration; fp32 on GPU is synthesized at 1.9× fp16 (half-rate
    fp32 ALUs), int8 on NPU at 0.65× fp16 (the Hexagon int8 path), int8 on
    CPU at 0.75× of the best CPU fp16 — consistent with the relative orders
    reported in §2.1.1. NNAPI-like catastrophic fallbacks only exist for the
    CPU rows where the paper measured them.
    """
    tables: Dict[str, Dict[Tuple[str, str, str], float]] = {}
    for name in MODEL_NAMES:
        t: Dict[Tuple[str, str, str], float] = {}
        for (dt, be), ms in _TABLE2_CPU[name].items():
            t[("cpu", dt, be)] = ms * _MS
        cpu_fp16_best = min(
            ms for (dt, be), ms in _TABLE2_CPU[name].items() if dt == "fp16"
        )
        t[("cpu", "int8", "default")] = 0.75 * cpu_fp16_best * _MS
        t[("cpu", "int8", "xnnpack")] = 0.70 * cpu_fp16_best * _MS
        gpu = _TABLE3[name]["gpu"]
        npu = _TABLE3[name]["npu"]
        t[("gpu", "fp16", "default")] = gpu * _MS
        t[("gpu", "fp32", "default")] = 1.9 * gpu * _MS
        t[("gpu", "int8", "default")] = 0.9 * gpu * _MS  # little int8 gain on mobile GPUs
        t[("npu", "fp16", "default")] = npu * _MS
        t[("npu", "int8", "default")] = 0.65 * npu * _MS
        tables[name] = t
    return tables


def best_processor_times_s() -> Dict[str, Dict[str, float]]:
    """Table 3 in seconds (best config per processor)."""
    return {
        name: {kind: ms * _MS for kind, ms in row.items()}
        for name, row in _TABLE3.items()
    }
