"""Exact static analysis of post-optimization SPMD HLO.

XLA's ``compiled.cost_analysis()`` does NOT multiply ``while``-loop bodies
by their trip counts, so a scanned 48-layer model reports ~1 layer of
FLOPs. This module re-derives per-device FLOPs / HBM traffic / collective
bytes from the HLO text with a call-graph walk:

* every computation block is parsed into instructions (opcode, result
  type, operands, attributes);
* ``while`` ops carry ``known_trip_count`` in ``backend_config`` — the body
  computation's costs are multiplied by it (nested loops multiply);
* ``fusion`` ops count their *boundary* operands/results as memory traffic
  (fusion internals stay on-chip) but internal ``dot``s still count FLOPs;
* ``dot`` FLOPs = 2 × |result| × contraction size (from operand shapes and
  ``lhs_contracting_dims``);
* collective ops (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute) accumulate operand bytes × multiplier.

The module is per-device (SPMD), so all numbers are per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_type(s: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Return (total bytes, list of (dtype, dims)) for a type string."""
    total = 0
    shapes = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",")] if dims_s else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dims))
    return total, shapes


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    result_bytes: int
    result_shapes: List[Tuple[str, List[int]]]
    operands: List[str]
    raw: str

    def attr(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=\{{([^}}]*)\}}", self.raw)
        return m.group(1) if m else None


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

# opcodes whose top-level appearance implies HBM traffic at their boundary
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        hm = _COMP_HEADER.match(line)
        if hm and ("->" in line):
            cur = Computation(hm.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, rtype, opcode, rest = im.groups()
        # operand segment: up to the first "), " attribute boundary
        op_seg = rest.split("),")[0]
        operands = _OPERAND_RE.findall(op_seg)
        rbytes, rshapes = _parse_type(rtype)
        inst = Instr(
            name=name, opcode=opcode, result_type=rtype, result_bytes=rbytes,
            result_shapes=rshapes, operands=operands, raw=line,
        )
        cur.instrs.append(inst)
        cur.by_name[name] = inst
    return comps, entry


def _dot_flops(inst: Instr, comp: Computation) -> float:
    """2 × |result| × contraction size for a dot instruction."""
    out_elems = 0
    for _, dims in inst.result_shapes:
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.raw)
    contract = 1
    if m and inst.operands:
        lhs = comp.by_name.get(inst.operands[0])
        if lhs and lhs.result_shapes:
            dims = lhs.result_shapes[0][1]
            for ix in m.group(1).split(","):
                if ix and int(ix) < len(dims):
                    contract *= dims[int(ix)]
    return 2.0 * out_elems * max(contract, 1)


_NORM_PAIR = {("bf16", "f32"), ("f32", "bf16"), ("f16", "f32"), ("f32", "f16")}


def _is_float_normalization(inst: Instr, comp: Computation) -> bool:
    """Same-shape bf16<->f32 convert (CPU float-normalization artifact)."""
    if not inst.operands:
        return False
    src = comp.by_name.get(inst.operands[0])
    if src is None or not src.result_shapes or not inst.result_shapes:
        return False
    sdt, sdims = src.result_shapes[0]
    rdt, rdims = inst.result_shapes[0]
    return sdims == rdims and (sdt, rdt) in _NORM_PAIR


def _trip_count(inst: Instr) -> int:
    m = re.search(r'known_trip_count.{0,6}?n.{0,4}?(\d+)', inst.raw)
    return int(m.group(1)) if m else 1


def _called_comps(inst: Instr) -> List[str]:
    out = []
    for key in ("calls", "body", "condition", "to_apply",
                "called_computations"):
        for m in re.finditer(rf"{key}=%?([\w.\-]+)", inst.raw):
            out.append(m.group(1))
    # conditional branches: "branch_computations={%a, %b}"
    m = re.search(r"branch_computations=\{([^}]*)\}", inst.raw)
    if m:
        out.extend(_OPERAND_RE.findall(m.group(1)))
    return out


_SLICE_OPS = {"dynamic-slice", "gather", "slice"}


def _param_index(inst: Instr) -> Optional[int]:
    m = re.search(r"parameter\((\d+)\)", inst.raw)
    return int(m.group(1)) if m else None


def fusion_traffic(inst: Instr, comp: Computation, fused: Computation) -> float:
    """HBM traffic at a fusion boundary.

    Operands consumed only through slicing ops inside the fusion contribute
    their *sliced* bytes (a scan body dynamic-slicing one layer out of the
    (L, ...) stacked weights reads one layer, not L). A root
    dynamic-update-slice writes its update, not the whole aliased buffer.
    """
    params: Dict[int, str] = {}
    for fi in fused.instrs:
        if fi.opcode == "parameter":
            ix = _param_index(fi)
            if ix is not None:
                params[ix] = fi.name

    transparent = {"convert", "bitcast", "copy", "reshape"}

    def resolve(name: str) -> Optional[Instr]:
        """Follow transparent single-operand chains to the producer."""
        seen = 0
        fi = fused.by_name.get(name)
        while fi is not None and fi.opcode in transparent and fi.operands \
                and seen < 8:
            fi = fused.by_name.get(fi.operands[0])
            seen += 1
        return fi

    def effective_consumers(pname: str) -> List[Instr]:
        """Consumers of ``pname``, looking through transparent ops."""
        out: List[Instr] = []
        frontier = [pname]
        seen: set = set()
        while frontier:
            nm = frontier.pop()
            if nm in seen:
                continue
            seen.add(nm)
            for fi in fused.instrs:
                if nm in fi.operands:
                    if fi.opcode in transparent:
                        frontier.append(fi.name)
                    else:
                        out.append((nm, fi))
        return out

    total = 0.0
    for pos, op_name in enumerate(inst.operands):
        full = comp.by_name[op_name].result_bytes if op_name in comp.by_name else 0
        pname = params.get(pos)
        if pname is None:
            total += full
            continue
        consumers = effective_consumers(pname)
        if not consumers:
            total += full
            continue
        # consumer-wise: slices read their result size; DUS destinations are
        # aliased passthrough (0 bytes); any other consumer reads it fully.
        contrib = 0.0
        for via, c in consumers:
            if c.opcode in _SLICE_OPS and c.operands and c.operands[0] == via:
                contrib += c.result_bytes
            elif (c.opcode == "dynamic-update-slice" and c.operands
                  and c.operands[0] == via):
                contrib += 0.0
            else:
                contrib = full
                break
        total += contrib
    # result side: a root that resolves (through converts) to dynamic-
    # update-slices writes only the update slices — XLA aliases the
    # destination buffer (in-place DUS; converts are CPU normalization).
    root = fused.instrs[-1] if fused.instrs else None
    root_names: List[str] = []
    if root is not None:
        root_names = list(root.operands) if root.opcode == "tuple" else [root.name]
    resolved_roots = [resolve(nm) for nm in root_names]
    if root is not None and resolved_roots and all(
            fi is not None and fi.opcode == "dynamic-update-slice"
            for fi in resolved_roots):
        for fi in resolved_roots:
            upd = resolve(fi.operands[1]) if len(fi.operands) > 1 else None
            total += upd.result_bytes if upd is not None else fi.result_bytes
    else:
        total += inst.result_bytes
    return total


@dataclass
class HLOStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)


def analyze(hlo: str) -> HLOStats:
    comps, entry = parse_module(hlo)
    stats = HLOStats()
    if not entry:
        return stats
    visited_stack: List[str] = []

    def operand_bytes(inst: Instr, comp: Computation) -> float:
        total = 0.0
        for op in inst.operands:
            o = comp.by_name.get(op)
            if o is not None:
                total += o.result_bytes
        return total

    def walk(comp_name: str, mult: float, in_fusion: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in visited_stack:
            return
        visited_stack.append(comp_name)
        for inst in comp.instrs:
            op = inst.opcode
            if op == "dot":
                stats.flops += mult * _dot_flops(inst, comp)
                if not in_fusion:
                    stats.traffic_bytes += mult * (
                        inst.result_bytes + operand_bytes(inst, comp)
                    )
            elif op in ("convolution",):
                # rare here (zoo convs run unscanned); approximate via result
                stats.flops += mult * 2.0 * inst.result_bytes
                if not in_fusion:
                    stats.traffic_bytes += mult * (
                        inst.result_bytes + operand_bytes(inst, comp)
                    )
            elif op == "fusion":
                called = _called_comps(inst)
                fused = comps.get(called[0]) if called else None
                if fused is not None:
                    stats.traffic_bytes += mult * fusion_traffic(inst, comp, fused)
                else:
                    stats.traffic_bytes += mult * (
                        inst.result_bytes + operand_bytes(inst, comp)
                    )
                for c in called:
                    walk(c, mult, True)
            elif op == "while":
                n = _trip_count(inst)
                called = _called_comps(inst)
                for c in called:
                    walk(c, mult * n, in_fusion)
            elif any(op.startswith(c) for c in COLLECTIVE_OPS):
                base = next(c for c in COLLECTIVE_OPS if op.startswith(c))
                if op.endswith("-done"):
                    continue  # paired with -start; count once
                nbytes = operand_bytes(inst, comp) or inst.result_bytes
                stats.collective_bytes += mult * nbytes
                stats.collective_by_op[base] = (
                    stats.collective_by_op.get(base, 0.0) + mult * nbytes
                )
                stats.collective_count[base] = (
                    stats.collective_count.get(base, 0) + int(mult)
                )
                stats.traffic_bytes += mult * (
                    inst.result_bytes + (operand_bytes(inst, comp))
                )
            elif op in ("call", "custom-call", "conditional", "reduce",
                        "sort", "scatter", "map", "reduce-window",
                        "select-and-scatter"):
                if op in ("call", "conditional", "custom-call", "map"):
                    for c in _called_comps(inst):
                        walk(c, mult, in_fusion)
                if not in_fusion and op not in ("call", "conditional"):
                    stats.traffic_bytes += mult * (
                        inst.result_bytes + operand_bytes(inst, comp)
                    )
            elif op in _NO_TRAFFIC:
                continue
            elif op == "convert":
                # CPU float normalization wraps bf16 elementwise ops in
                # same-shape bf16<->f32 converts that do not exist on TPU;
                # skip them so the memory term stays TPU-faithful.
                if not in_fusion and not _is_float_normalization(inst, comp):
                    stats.traffic_bytes += mult * (
                        inst.result_bytes + operand_bytes(inst, comp)
                    )
            elif op in _SLICE_OPS:
                if not in_fusion:   # read the slice, write the slice
                    stats.traffic_bytes += mult * 2.0 * inst.result_bytes
            elif op == "dynamic-update-slice":
                # XLA updates in place when the destination is dead/donated
                # (standard in-place-DUS optimization): traffic = the update
                # slice read + written, not the full result buffer.
                if not in_fusion and len(inst.operands) >= 2:
                    upd = comp.by_name.get(inst.operands[1])
                    nb = upd.result_bytes if upd else inst.result_bytes
                    stats.traffic_bytes += mult * 2.0 * nb
            else:
                # plain elementwise / copy at top level: boundary traffic
                if not in_fusion:
                    stats.traffic_bytes += mult * (
                        inst.result_bytes + operand_bytes(inst, comp)
                    )
        visited_stack.pop()

    walk(entry, 1.0, False)
    return stats
