"""The four assigned input shapes and per-(arch × shape) input_specs.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every model
input — weak-type-correct, shardable, no device allocation. Decode shapes
describe ``serve_step``: ONE new token with a KV cache of ``seq_len``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import init_cache


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# sliding window enabled for dense/VLM/audio archs at long context so the
# sub-quadratic requirement is met (DESIGN.md §4)
LONG_CONTEXT_WINDOW = 8_192


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-specific config adjustments (long-context window)."""
    if shape.name == "long_500k" and cfg.uses_attention and not cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def cross_src_shape(cfg: ModelConfig, batch: int) -> Optional[Tuple[int, ...]]:
    """Stub modality embeddings (the allowed frontend carve-out)."""
    if cfg.arch_type == "vlm":
        return (batch, cfg.num_image_tokens, cfg.d_model)
    if cfg.is_encoder_decoder:
        return (batch, cfg.encoder_seq_len, cfg.d_model)
    return None


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStructs for one (arch, shape) step invocation."""
    cfg = config_for_shape(cfg, shape)
    b, s = shape.global_batch, shape.seq_len
    act_dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, b, s, cross_len=_cross_len(cfg))
        )
        out["caches"] = cache_shapes
        out["cache_len"] = jax.ShapeDtypeStruct((), jnp.int32)
    cs = cross_src_shape(cfg, b)
    if cs is not None and shape.kind in ("train", "prefill"):
        out["cross_src"] = jax.ShapeDtypeStruct(cs, act_dt)
    return out


def _cross_len(cfg: ModelConfig) -> int:
    if cfg.arch_type == "vlm":
        return cfg.num_image_tokens
    if cfg.is_encoder_decoder:
        return cfg.encoder_seq_len
    return 0
