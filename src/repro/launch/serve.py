"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Prefill + greedy decode of a reduced model on the host, exercising the
same ``forward_prefill``/``forward_decode`` entry points the production
mesh lowers (launch/steps.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ALIASES, get_smoke_config
from ..models import forward_decode, forward_prefill, init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), default="qwen3-14b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = args.batch
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, args.prompt_len),
                                0, cfg.vocab_size)
    cross = None
    if cfg.arch_type == "vlm":
        cross = jnp.ones((b, cfg.num_image_tokens, cfg.d_model)) * 0.01
    if cfg.is_encoder_decoder:
        cross = jnp.ones((b, cfg.encoder_seq_len, cfg.d_model)) * 0.01

    max_len = args.prompt_len + args.new_tokens + 1
    logits, caches, clen = forward_prefill(params, cfg, tokens, max_len, cross)

    decode = jax.jit(lambda p, t, c, l: forward_decode(p, cfg, t, c, l))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens):
        logits, caches, clen = decode(params, tok, caches, clen)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, 1)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    seq = jnp.concatenate(out, axis=1)
    print(f"[serve] arch={cfg.name} generated {args.new_tokens} tokens × "
          f"batch {b} in {dt:.2f}s ({args.new_tokens * b / dt:.1f} tok/s)")
    print("[serve] sample ids:", seq[0, :16].tolist())


if __name__ == "__main__":
    main()
