"""Mesh construction for the production topology.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and tests/benches must keep seeing one device.
"""
from __future__ import annotations


import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256-chip pod slice, or 2×16×16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1×1 mesh on the host CPU device — smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_lane_mesh(chips_data: int, chips_model: int) -> Mesh:
    """A lane sub-mesh for the multi-model serving adaptation."""
    return jax.make_mesh((chips_data, chips_model), ("data", "model"))


def required_devices(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
