"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

``cost_analysis`` supplies FLOPs/bytes. Collective bytes are NOT in
cost_analysis — we parse the partitioned HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. The SPMD module is per-device, so parsed sizes are
per-device; global = × chips.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9
TPU_ICI_BW = 50e9
HBM_PER_CHIP = 16 * 1024 ** 3  # v5e: 16 GiB

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# e.g. "bf16[16,128,2048]{2,1,0}" or "f32[]"
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result name at line start: "  %name = ..." or "  name = ..."
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of collective ops in a (per-device) HLO module."""
    # symbol table: instruction name -> result byte size
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type is the prefix of rhs up to the opcode token
        sizes[name] = _type_bytes(rhs.split(" ")[0])
    stats = CollectiveStats()
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        for op in COLLECTIVE_OPS:
            # opcode appears right after the result type, e.g.
            # "bf16[...] all-gather(%x), ..." — avoid matching fusion names
            if re.search(rf"\]\S*\s+{op}(-start|-done)?\(", rhs):
                # operand list inside the first parens after the opcode
                om = re.search(rf"{op}(?:-start|-done)?\(([^)]*)\)", rhs)
                nbytes = 0
                if om:
                    for arg in om.group(1).split(","):
                        arg = arg.strip().lstrip("%")
                        nbytes += sizes.get(arg, 0)
                if nbytes == 0:
                    # fall back to the result size (start ops wrap operands)
                    nbytes = _type_bytes(rhs.split(" ")[0])
                stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + nbytes
                stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
                break
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    per_device_flops: float
    per_device_bytes: float
    collective_bytes: float           # per device
    collective_by_op: Dict[str, int]
    model_flops: float                # 6·N·D or 2·N·D (global, useful work)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    useful_ratio: float               # MODEL_FLOPS / (per_device_flops × chips)
    memory_per_device: Optional[float] = None   # from memory_analysis
    fits_hbm: Optional[bool] = None
    notes: str = ""

    def as_dict(self) -> Dict:
        return dict(self.__dict__)


def model_flops(cfg, shape) -> float:
    """Useful-work FLOPs: 6·N_active·tokens (train) / 2·N_active·tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens


def build_report(
    arch: str,
    shape,
    mesh_name: str,
    chips: int,
    stats,                               # hlo_analysis.HLOStats (per device)
    cfg,
    memory_per_device: Optional[float] = None,
) -> RooflineReport:
    flops = float(stats.flops)
    bytes_ = float(stats.traffic_bytes)
    t_comp = flops / TPU_PEAK_FLOPS
    t_mem = bytes_ / TPU_HBM_BW
    t_coll = stats.collective_bytes / TPU_ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        per_device_flops=flops, per_device_bytes=bytes_,
        collective_bytes=float(stats.collective_bytes),
        collective_by_op={k: int(v) for k, v in stats.collective_by_op.items()},
        model_flops=mf,
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck, useful_ratio=useful,
        memory_per_device=memory_per_device,
        fits_hbm=(memory_per_device < HBM_PER_CHIP) if memory_per_device else None,
    )
