"""Jit-compiled distributed steps: train_step / prefill_step / serve_step.

Each ``make_*`` builds the step function for a config plus the full
in/out sharding trees for a mesh — consumed both by the real launcher
(train.py / serve.py) and by the multi-pod dry-run (lower + compile with
ShapeDtypeStructs, no allocation).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..models.transformer import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_params,
    params_spec,
)
from ..sharding.context import activation_sharding
from ..sharding.rules import batch_spec, cache_shardings, tree_shardings
from ..train.optimizer import make_optimizer
from .shapes import InputShape, config_for_shape, input_specs


def param_shapes(cfg: ModelConfig) -> Any:
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def param_shardings(cfg: ModelConfig, mesh: Mesh) -> Any:
    return tree_shardings(params_spec(cfg), param_shapes(cfg), mesh)


def opt_state_shardings(opt_init, params_sds: Any, p_shardings: Any, mesh: Mesh
                        ) -> Any:
    """Optimizer-state shardings: full-size moments inherit the parameter
    sharding; factored (vr/vc) and scalar leaves are replicated."""
    state_sds = jax.eval_shape(opt_init, params_sds)
    shard_by_shape: Dict[Tuple[Tuple[int, ...], str], Any] = {}
    for sds, sh in zip(jax.tree.leaves(params_sds), jax.tree.leaves(p_shardings)):
        shard_by_shape.setdefault(sds.shape, sh)
    repl = NamedSharding(mesh, P())

    def leaf(sds):
        return shard_by_shape.get(sds.shape, repl)

    return jax.tree.map(leaf, state_sds)


def _dp_axes(mesh: Mesh, batch: int):
    spec = batch_spec(mesh, batch)
    return spec[0] if len(spec) else None


def _batch_axes_tuple(mesh: Mesh, batch: int):
    dp = _dp_axes(mesh, batch)
    if dp is None:
        return None
    return tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)


def _vocab_axis(cfg: ModelConfig, mesh: Mesh):
    """'model' when the vocab divides the axis (mamba2's 50280 and
    whisper's 51865 do not divide 16 — replicate those logits)."""
    return "model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0 else None


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Vocab-sharding-friendly CE: one-hot einsum instead of gather so the
    contraction over the (model-sharded) vocab axis stays a partial-sum +
    small all-reduce, never an all-gather of the logits."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits32, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    tgt = jnp.einsum("bsv,bsv->bs", logits32, onehot)
    return jnp.mean(lse - tgt)


def make_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: InputShape,
    optimizer: str = "adamw",
):
    """Returns (jitted step, in_shardings dict, arg ShapeDtypeStructs)."""
    cfg = config_for_shape(cfg, shape)
    opt_init, opt_update = make_optimizer(optimizer)
    dp = _dp_axes(mesh, shape.global_batch)
    has_cross = cfg.arch_type == "vlm" or cfg.is_encoder_decoder

    ba = _batch_axes_tuple(mesh, shape.global_batch)

    def train_step(params, opt_state, tokens, labels, cross_src=None):
        with activation_sharding(ba):
            def loss_fn(p):
                logits = forward_train(p, cfg, tokens, cross_src, remat=True)
                logits = jax.lax.with_sharding_constraint(
                    logits, NamedSharding(mesh, P(dp, None, _vocab_axis(cfg, mesh)))
                )
                return cross_entropy(logits, labels)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new_params, new_opt = opt_update(grads, opt_state, params)
            return new_params, new_opt, loss

    p_sds = param_shapes(cfg)
    p_sh = param_shardings(cfg, mesh)
    o_sh = opt_state_shardings(opt_init, p_sds, p_sh, mesh)
    o_sds = jax.eval_shape(opt_init, p_sds)
    specs = input_specs(cfg, shape)
    tok_sh = NamedSharding(mesh, P(dp, None))
    in_shardings = [p_sh, o_sh, tok_sh, tok_sh]
    args = [p_sds, o_sds, specs["tokens"], specs["labels"]]
    if has_cross:
        cr_sh = NamedSharding(mesh, P(dp, None, None))
        in_shardings.append(cr_sh)
        args.append(specs["cross_src"])
    out_shardings = (p_sh, o_sh, NamedSharding(mesh, P()))
    step = jax.jit(
        train_step,
        in_shardings=tuple(in_shardings),
        out_shardings=out_shardings,
        donate_argnums=(0, 1),
    )
    return step, tuple(args)


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape):
    cfg = config_for_shape(cfg, shape)
    dp = _dp_axes(mesh, shape.global_batch)
    has_cross = cfg.arch_type == "vlm" or cfg.is_encoder_decoder

    ba = _batch_axes_tuple(mesh, shape.global_batch)

    def prefill_step(params, tokens, cross_src=None):
        with activation_sharding(ba):
            return forward_prefill(params, cfg, tokens, shape.seq_len, cross_src)

    p_sds = param_shapes(cfg)
    p_sh = param_shardings(cfg, mesh)
    specs = input_specs(cfg, shape)
    tok_sh = NamedSharding(mesh, P(dp, None))
    in_sh = [p_sh, tok_sh]
    args = [p_sds, specs["tokens"]]
    if has_cross:
        in_sh.append(NamedSharding(mesh, P(dp, None, None)))
        args.append(specs["cross_src"])
    step = jax.jit(prefill_step, in_shardings=tuple(in_sh))
    return step, tuple(args)


def make_decode_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape):
    """serve_step: ONE token against a seq_len KV cache."""
    cfg = config_for_shape(cfg, shape)
    dp = _dp_axes(mesh, shape.global_batch)

    ba = _batch_axes_tuple(mesh, shape.global_batch)

    def decode_step(params, token, caches, cache_len):
        with activation_sharding(ba):
            return forward_decode(params, cfg, token, caches, cache_len)

    p_sds = param_shapes(cfg)
    p_sh = param_shardings(cfg, mesh)
    specs = input_specs(cfg, shape)
    cache_sh = cache_shardings(cfg, mesh, specs["caches"])
    tok_sh = NamedSharding(mesh, P(dp, None))
    repl = NamedSharding(mesh, P())
    step = jax.jit(
        decode_step,
        in_shardings=(p_sh, tok_sh, cache_sh, repl),
        out_shardings=(
            NamedSharding(mesh, P(dp, None, _vocab_axis(cfg, mesh))),
            cache_sh,
            repl,
        ),
        donate_argnums=(2,),
    )
    args = (p_sds, specs["token"], specs["caches"], specs["cache_len"])
    return step, args


def make_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
              optimizer: str = "adamw"):
    """Dispatch by shape kind; returns (jitted fn, ShapeDtypeStruct args)."""
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, optimizer)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape)
    return make_decode_step(cfg, mesh, shape)
