"""Launch: production meshes, distributed steps, dry-run, roofline."""
from .mesh import make_host_mesh, make_lane_mesh, make_production_mesh
from .shapes import INPUT_SHAPES, InputShape, config_for_shape, input_specs

__all__ = [k for k in dir() if not k.startswith("_")]
