"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On this CPU host, trains the reduced smoke variant of the chosen
architecture on the synthetic Markov stream. On a real TPU slice the same
entry point builds the production mesh and the pjit train step from
``launch.steps`` (``--mesh single|multi``).
"""
from __future__ import annotations

import argparse

from ..configs import ALIASES, get_config, get_smoke_config
from ..train import TrainConfig, train
from ..train.optimizer import optimizer_for_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), default="phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    import jax
    n_dev = len(jax.devices())
    cfg = get_smoke_config(args.arch) if n_dev == 1 else get_config(args.arch)
    opt = optimizer_for_config(cfg)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"optimizer={opt} devices={n_dev}")

    cross_fn = None
    if cfg.arch_type == "vlm":
        import jax.numpy as jnp

        def cross_fn(b):
            return jnp.ones((b, cfg.num_image_tokens, cfg.d_model)) * 0.01
    if cfg.is_encoder_decoder:
        import jax.numpy as jnp

        def cross_fn(b):
            return jnp.ones((b, cfg.encoder_seq_len, cfg.d_model)) * 0.01

    res = train(cfg, TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        lr=args.lr, optimizer=opt, log_every=max(args.steps // 10, 1),
        checkpoint_path=args.checkpoint,
    ), cross_src_fn=cross_fn)
    print(f"[train] loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"(floor {res.loss_floor:.3f}); {res.tokens_per_s:,.0f} tok/s")


if __name__ == "__main__":
    main()
