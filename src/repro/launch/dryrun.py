import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder host devices. This is
the ONLY module that sets the flag; tests and benchmarks see one device.

For each combination we record:
* ``compiled.memory_analysis()`` — per-device bytes (proves it fits),
* ``compiled.cost_analysis()`` — FLOPs / bytes for the roofline,
* collective bytes parsed from the partitioned HLO,
* the three roofline terms + dominant bottleneck.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Results: results/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import json
import time
import traceback
from typing import Dict

import jax

from ..configs import ALIASES, get_config
from ..train.optimizer import optimizer_for_config
from .mesh import make_production_mesh
from .hlo_analysis import analyze
from .roofline import build_report
from .shapes import INPUT_SHAPES, config_for_shape
from .steps import make_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def run_one(arch: str, shape_name: str, mesh_name: str,
            save: bool = True, verbose: bool = True) -> Dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    record: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": int(chips), "ok": False,
    }
    t0 = time.time()
    try:
        opt = optimizer_for_config(cfg)
        step, args = make_step(cfg, mesh, shape, optimizer=opt)
        with mesh:
            lowered = step.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        cost = None
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            cost = {k: float(v) for k, v in ca.items()
                    if isinstance(v, (int, float)) and "{" not in k}
        except Exception:
            cost = None
        mem_per_device = None
        mem_info = {}
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                             "temp_size_in_bytes", "alias_size_in_bytes",
                             "generated_code_size_in_bytes"):
                    v = getattr(ma, attr, None)
                    if v is not None:
                        mem_info[attr] = int(v)
                mem_per_device = float(
                    mem_info.get("argument_size_in_bytes", 0)
                    - mem_info.get("alias_size_in_bytes", 0)
                    + mem_info.get("temp_size_in_bytes", 0)
                    + mem_info.get("output_size_in_bytes", 0)
                )
        except Exception:
            pass
        if mem_per_device is None:
            # fallback: per-device bytes of the (sharded) inputs
            mem_per_device = _arg_bytes_per_device(args, chips)
        hlo = compiled.as_text()
        stats = analyze(hlo)
        rep = build_report(
            arch, shape, mesh_name, chips, stats,
            config_for_shape(cfg, shape), mem_per_device,
        )
        record.update(rep.as_dict())
        record.update({
            "ok": True,
            "optimizer": opt,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_info": mem_info,
            "xla_cost_analysis": cost,   # raw (trip-count-unaware) reference
            "collective_count": dict(stats.collective_count),
            "hlo_bytes": len(hlo),
        })
        if verbose:
            mem_gib = (mem_per_device or 0) / 2**30
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
                  f"compile={t_compile:.1f}s mem/dev={mem_gib:.2f}GiB "
                  f"bottleneck={rep.bottleneck} "
                  f"terms=({rep.t_compute:.4f},{rep.t_memory:.4f},"
                  f"{rep.t_collective:.4f})s useful={rep.useful_ratio:.2f}")
    except Exception as e:
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: FAIL {record['error']}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1, default=str)
    return record


def _arg_bytes_per_device(args, chips: int) -> float:
    total = 0
    for leaf in jax.tree.leaves(args):
        total += leaf.size * leaf.dtype.itemsize
    return total / chips


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = sorted(ALIASES) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    n_ok = n_fail = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_one(arch, shape_name, mesh_name)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
