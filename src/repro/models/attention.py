"""Attention: GQA with RoPE / qk-norm / QKV-bias / sliding window / cross-attn.

The training/prefill path uses a blockwise flash-style computation in pure
jnp (outer map over query blocks, inner scan over KV blocks with an online
softmax) so the lowered HLO never materializes an (S, S) score matrix —
memory-safe at 32k and the pure-jnp oracle for the Pallas kernel.

The decode path attends one query against a KV cache; with a sliding
window it slices the last W cache entries (keeps long-context decode
sub-quadratic for dense models).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, rms_norm

Params = Dict[str, Any]

NEG_INF = -1e30


def project_qkv(
    params: Params,
    x: jnp.ndarray,                      # (B, S, D)
    positions: jnp.ndarray,              # (B, S)
    rope_theta: float = 10_000.0,
    qk_norm: bool = False,
    use_rope: bool = True,
    norm_eps: float = 1e-5,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _expand_kv(k: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """(B, S, Kv, hd) -> (B, S, H, hd) by repeating each KV head G times."""
    b, s, kv, hd = k.shape
    reps = num_heads // kv
    return jnp.repeat(k, reps, axis=2)


def blockwise_attention(
    q: jnp.ndarray,                      # (B, Sq, H, hd)
    k: jnp.ndarray,                      # (B, Sk, Kv, hd)
    v: jnp.ndarray,                      # (B, Sk, Kv, hd)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    q_block: int = 256,
    kv_block: int = 256,
) -> jnp.ndarray:
    """Flash-style attention; returns (B, Sq, H, hd).

    ``q_offset`` is the absolute position of q[0] relative to k[0]
    (prefill continuation). ``window``: attend only to keys within
    ``window`` positions behind the query.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    scale = hd ** -0.5

    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    nq = -(-sq // q_block)
    nk = -(-sk // kv_block)
    pad_q = nq * q_block - sq
    pad_k = nk * kv_block - sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # grouped layouts: q (B, Kv, G, nq, qb, hd); kv (B, Kv, nk, kb, hd).
    # GQA stays grouped end-to-end — expanding KV to H heads (jnp.repeat)
    # costs ~G× the cache in HBM traffic (§Perf 1).
    qp = qp.reshape(b, nq, q_block, kv, g, hd).transpose(0, 3, 4, 1, 2, 5)
    kp = kp.reshape(b, nk, kv_block, kv, hd).transpose(0, 3, 1, 2, 4)
    vp = vp.reshape(b, nk, kv_block, kv, hd).transpose(0, 3, 1, 2, 4)

    q_pos = q_offset + jnp.arange(nq * q_block)
    k_pos = jnp.arange(nk * kv_block)

    def q_step(qi):
        qb = qp[:, :, :, qi]                           # (B, Kv, G, qb, hd)
        qpos = jax.lax.dynamic_slice_in_dim(q_pos, qi * q_block, q_block)

        def kv_step(carry, ki):
            m, denom, acc = carry
            kb = kp[:, :, ki]                          # (B, Kv, kb, hd)
            vb = vp[:, :, ki]
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qb.astype(jnp.float32),
                kb.astype(jnp.float32)
            ) * scale
            kpos = jax.lax.dynamic_slice_in_dim(k_pos, ki * kv_block, kv_block)
            mask = jnp.ones((q_block, kv_block), dtype=bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask &= kpos[None, :] < sk                # padding
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            denom_new = denom * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32)
            )
            return (m_new, denom_new, acc_new), None

        init = (
            jnp.full((b, kv, g, q_block), NEG_INF, dtype=jnp.float32),
            jnp.zeros((b, kv, g, q_block), dtype=jnp.float32),
            jnp.zeros((b, kv, g, q_block, hd), dtype=jnp.float32),
        )
        (m, denom, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(denom, 1e-30)[..., None]
        return out.astype(q.dtype)                     # (B, Kv, G, qb, hd)

    blocks = jax.lax.map(q_step, jnp.arange(nq))       # (nq, B, Kv, G, qb, hd)
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(b, kv * g, nq * q_block, hd)
    out = out[:, :, :sq].transpose(0, 2, 1, 3)         # (B, Sq, H, hd)
    return out


def attention_output(params: Params, attn: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", attn, params["wo"])


def decode_attention(
    q: jnp.ndarray,                       # (B, 1, H, hd)
    cache_k: jnp.ndarray,                 # (B, S, Kv, hd)
    cache_v: jnp.ndarray,
    cache_len: jnp.ndarray,               # scalar/per-batch current length
    window: Optional[int] = None,
) -> jnp.ndarray:
    """One-token attention over the KV cache.

    GQA is computed *grouped* — q reshaped to (B, Kv, G, hd) and contracted
    against the cache directly. Materializing the head-expanded cache
    (jnp.repeat) was measured at ~2× the whole KV cache in extra HBM
    traffic per decode step at kimi-k2/decode_32k scale (§Perf 1).

    With a window, only the last ``window`` cache slots are read (the cache
    is maintained as a ring buffer by the caller), keeping the FLOPs and
    bytes of long-context decode O(window) instead of O(S).
    """
    b, sq, h, hd = q.shape
    kv = cache_k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    if window is not None and cache_k.shape[1] > window:
        # ring-buffer view: slice the window ending at cache_len
        start = jnp.maximum(cache_len - window, 0)
        cache_k = jax.lax.dynamic_slice_in_dim(cache_k, start, window, axis=1)
        cache_v = jax.lax.dynamic_slice_in_dim(cache_v, start, window, axis=1)
        valid = jnp.arange(window) < jnp.minimum(cache_len, window)
    else:
        valid = jnp.arange(cache_k.shape[1]) < cache_len
    qg = q.reshape(b, sq, kv, g, hd)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
        cache_k.astype(jnp.float32),
    ) * scale
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, cache_v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def cross_attention(
    params: Params,
    x: jnp.ndarray,                       # (B, S, D)
    kv_src: jnp.ndarray,                  # (B, T, D) encoder/image embeddings
    norm_eps: float = 1e-5,
    qk_norm: bool = False,
) -> jnp.ndarray:
    """Cross-attention (no RoPE on keys from another modality)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_src, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, params["wv"])
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    out = blockwise_attention(q, k, v, causal=False)
    y = attention_output(params, out)
    if "attn_gate" in params:
        y = jnp.tanh(params["attn_gate"]) * y
    return y
