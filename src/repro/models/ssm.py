"""Mamba2 mixer via the SSD (state-space duality) chunked algorithm
[arXiv:2405.21060].

The sequence is split into chunks of length Q. Within a chunk the SSD
computes an attention-like quadratic form (MXU-friendly on TPU); across
chunks a low-rank recurrent state (B, H, P, N) is carried by a scan —
O(S·Q) compute and O(1)-in-S decode state, which is what makes `long_500k`
native for SSM/hybrid architectures.

This module is also the pure-jnp oracle for the Pallas SSD kernel in
``repro.kernels.ssd_scan``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm

Params = Dict[str, Any]


def init_mamba2(
    key: jax.Array,
    d_model: int,
    d_inner: int,
    ssm_state: int,
    ssm_heads: int,
    ssm_groups: int = 1,
    conv_width: int = 4,
    dtype=jnp.float32,
) -> Params:
    ks = jax.random.split(key, 6)
    gn = ssm_groups * ssm_state
    # in_proj packs [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)]
    proj_out = 2 * d_inner + 2 * gn + ssm_heads
    return {
        "in_proj": dense_init(ks[0], (d_model, proj_out), dtype=dtype),
        "conv_w": dense_init(ks[1], (conv_width, d_inner + 2 * gn), scale=0.5,
                             dtype=dtype),
        "conv_b": jnp.zeros((d_inner + 2 * gn,), dtype=dtype),
        "A_log": jnp.zeros((ssm_heads,), jnp.float32) + jnp.log(
            jnp.linspace(1.0, 16.0, ssm_heads)
        ),
        "dt_bias": jnp.zeros((ssm_heads,), jnp.float32),
        "D": jnp.ones((ssm_heads,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype=dtype),
        "out_proj": dense_init(ks[2], (d_inner, d_model), dtype=dtype),
    }


def mamba2_spec() -> Params:
    return {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


def _split_proj(proj, d_inner, gn, heads):
    z = proj[..., :d_inner]
    x = proj[..., d_inner : 2 * d_inner]
    b = proj[..., 2 * d_inner : 2 * d_inner + gn]
    c = proj[..., 2 * d_inner + gn : 2 * d_inner + 2 * gn]
    dt = proj[..., 2 * d_inner + 2 * gn :]
    return z, x, b, c, dt


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over (B, S, C); returns (y, new_state).

    ``state`` is the trailing (width-1) inputs from the previous call
    (used at decode time); None means zero history.
    """
    width = w.shape[0]
    bsz, s, c = x.shape
    if state is None:
        state = jnp.zeros((bsz, width - 1, c), x.dtype)
    xin = jnp.concatenate([state, x], axis=1)           # (B, S+w-1, C)
    y = jnp.zeros((bsz, s, c), x.dtype)
    for i in range(width):
        y = y + xin[:, i : i + s, :] * w[i]
    y = y + b
    new_state = xin[:, -(width - 1):, :] if width > 1 else state
    return jax.nn.silu(y), new_state


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular cumulative sums: out[..., i, j] = sum x[j+1..i]."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,          # (B, S, H, P) inputs per head
    dt: jnp.ndarray,         # (B, S, H) softplus-ed step sizes
    A: jnp.ndarray,          # (H,) negative decay rates (A = -exp(A_log))
    Bm: jnp.ndarray,         # (B, S, G, N)
    Cm: jnp.ndarray,         # (B, S, G, N)
    chunk: int = 128,
    initial_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD chunked scan. Returns (y (B,S,H,P), final_state (B,H,P,N)).

    One sequential ``lax.scan`` over chunks carrying the (B,H,P,N) state;
    each step computes the intra-chunk quadratic term, the carried-state
    contribution, and the state update. Peak temporaries are O(B·H·Q²) for
    a single chunk — never the all-chunks (B,nc,H,Q,Q) tensor (which at
    train_4k scale is hundreds of GB and was the memory bottleneck of the
    phase-separated formulation).
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    nc = s // chunk
    reps = h // g
    Bh = jnp.repeat(Bm, reps, axis=2)                  # (B, S, H, N)
    Ch = jnp.repeat(Cm, reps, axis=2)
    # scan inputs: leading chunk axis
    xc = x.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3).astype(jnp.float32)
    Bc = Bh.reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    Cc = Ch.reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)

    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        xq, dtq, Bq, Cq = inp                          # (B,Q,H,P) (B,Q,H) ...
        dA = dtq * A[None, None, :]                    # (B, Q, H), negative
        dA_cum = jnp.cumsum(dA, axis=1)
        total = dA_cum[:, -1]                          # (B, H)
        # intra-chunk quadratic term
        L = jnp.exp(segsum(dA.transpose(0, 2, 1)))     # (B, H, Q, Q)
        scores = jnp.einsum("bqhn,bkhn->bhqk", Cq, Bq)
        y_intra = jnp.einsum(
            "bhqk,bhqk,bkh,bkhp->bqhp",
            scores, L, dtq, xq.astype(jnp.float32),
        )
        # carried-state contribution
        y_inter = jnp.einsum(
            "bqhn,bhpn,bqh->bqhp", Cq, state, jnp.exp(dA_cum)
        )
        # state update
        decay_to_end = jnp.exp(total[:, None, :] - dA_cum)   # (B, Q, H)
        chunk_state = jnp.einsum(
            "bqhn,bqh,bqh,bqhp->bhpn",
            Bq, decay_to_end, dtq, xq.astype(jnp.float32),
        )
        new_state = chunk_state + jnp.exp(total)[:, :, None, None] * state
        return new_state, (y_intra + y_inter).astype(x.dtype)

    final_state, ys = jax.lax.scan(step, initial_state, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, final_state


def mamba2_mixer(
    params: Params,
    xin: jnp.ndarray,                     # (B, S, D)
    cfg,
    conv_state: Optional[jnp.ndarray] = None,
    ssm_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
):
    """Full Mamba2 block body (pre-norm residual handled by caller)."""
    d_inner = cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    heads = cfg.ssm_heads
    proj = jnp.einsum("bsd,dp->bsp", xin, params["in_proj"])
    z, x, bm, cm, dt = _split_proj(proj, d_inner, gn, heads)
    xbc = jnp.concatenate([x, bm, cm], axis=-1)
    xbc, new_conv_state = causal_conv1d(xbc, params["conv_w"], params["conv_b"],
                                        conv_state)
    x = xbc[..., :d_inner]
    bm = xbc[..., d_inner : d_inner + gn]
    cm = xbc[..., d_inner + gn :]
    b_, s_, _ = x.shape
    xh = x.reshape(b_, s_, heads, cfg.ssm_head_dim)
    bmh = bm.reshape(b_, s_, cfg.ssm_groups, cfg.ssm_state)
    cmh = cm.reshape(b_, s_, cfg.ssm_groups, cfg.ssm_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, new_ssm_state = ssd_chunked(
        xh, dt, A, bmh, cmh, chunk=min(cfg.ssm_chunk, s_),
        initial_state=ssm_state,
    )
    y = y + xh * params["D"][None, None, :, None]      # skip connection
    y = y.reshape(b_, s_, d_inner).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps).astype(xin.dtype)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    if return_state:
        return out, (new_conv_state, new_ssm_state)
    return out


def mamba2_decode_step(
    params: Params,
    xin: jnp.ndarray,                     # (B, 1, D)
    cfg,
    conv_state: jnp.ndarray,              # (B, width-1, d_inner+2GN)
    ssm_state: jnp.ndarray,               # (B, H, P, N) fp32
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """O(1) single-token recurrent update (the SSM's decode advantage)."""
    d_inner = cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    heads = cfg.ssm_heads
    proj = jnp.einsum("bsd,dp->bsp", xin, params["in_proj"])
    z, x, bm, cm, dt = _split_proj(proj, d_inner, gn, heads)
    xbc = jnp.concatenate([x, bm, cm], axis=-1)
    xbc, new_conv_state = causal_conv1d(xbc, params["conv_w"], params["conv_b"],
                                        conv_state)
    x = xbc[..., :d_inner]
    bm = xbc[..., d_inner : d_inner + gn]
    cm = xbc[..., d_inner + gn :]
    b_ = x.shape[0]
    xh = x.reshape(b_, heads, cfg.ssm_head_dim)        # S=1 squeezed
    bmh = jnp.repeat(
        bm.reshape(b_, cfg.ssm_groups, cfg.ssm_state), heads // cfg.ssm_groups, axis=1
    )
    cmh = jnp.repeat(
        cm.reshape(b_, cfg.ssm_groups, cfg.ssm_state), heads // cfg.ssm_groups, axis=1
    )
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt1 * A[None, :])                  # (B, H)
    # h' = decay * h + dt * B ⊗ x
    outer = jnp.einsum(
        "bh,bhn,bhp->bhpn", dt1, bmh.astype(jnp.float32), xh.astype(jnp.float32)
    )
    new_state = decay[:, :, None, None] * ssm_state + outer
    y = jnp.einsum("bhn,bhpn->bhp", cmh.astype(jnp.float32), new_state)
    y = y + xh.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(b_, 1, d_inner).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps).astype(xin.dtype)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    return out, (new_conv_state, new_state)
