"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is *sort-based* (argsort by expert id → gather into an
``(E, C, D)`` buffer → batched expert SwiGLU → scatter-combine), not the
one-hot-matmul formulation: the einsum dispatch would add
``T·E·C·D`` FLOPs — more than the expert compute itself at kimi-k2 scale —
and would corrupt the roofline analysis. Gathers/scatters are memory ops.

Under pjit, the expert dimension is sharded over the "model" mesh axis
(expert parallelism); the token→expert permutation then lowers to an
all-to-all, which the roofline accounts as collective bytes.

``moe_ffn_dense`` is the small-scale oracle (computes every expert for
every token and masks) used to property-test the dispatch path.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

Params = Dict[str, Any]


def init_moe(key: jax.Array, d_model: int, num_experts: int, moe_d_ff: int,
             dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d_model, num_experts), dtype=jnp.float32),
        "w_gate": dense_init(k2, (num_experts, d_model, moe_d_ff), dtype=dtype),
        "w_up": dense_init(k3, (num_experts, d_model, moe_d_ff), dtype=dtype),
        "w_down": dense_init(k4, (num_experts, moe_d_ff, d_model), dtype=dtype),
    }


def moe_spec() -> Params:
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ffn"),
        "w_up": ("experts", "embed", "ffn"),
        "w_down": ("experts", "ffn", "embed"),
    }


def router_topk(
    x2d: jnp.ndarray, router_w: jnp.ndarray, k: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (gates (T,k) normalized, expert_idx (T,k), full probs (T,E))."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(axis=-1, keepdims=True), 1e-9)
    return gates, idx, probs


def load_balance_loss(probs: jnp.ndarray, idx: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * Σ_e f_e · P_e."""
    counts = jnp.zeros((num_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(idx.size, 1)
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


def moe_ffn(
    params: Params,
    x: jnp.ndarray,                        # (B, S, D)
    num_experts: int,
    k: int,
    capacity_factor: float = 1.25,
    return_aux: bool = False,
):
    """Sort-based capacity-limited top-k MoE (FLOP count = active experts)."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    gates, idx, probs = router_topk(x2d, params["router"], k)

    capacity = int(max(1, round(t * k / num_experts * capacity_factor)))
    # flatten (token, slot_k) assignments
    flat_expert = idx.reshape(-1)                        # (t*k,)
    flat_gate = gates.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)
    # stable sort by expert id groups assignments per expert
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # rank within expert group = position - first position of that expert
    # (`.at[].min` with a +inf-like init gives each expert's first position)
    positions = jnp.arange(t * k)
    seg_start = (
        jnp.full((num_experts,), t * k, jnp.int32)
        .at[sorted_expert]
        .min(positions.astype(jnp.int32))
    )
    rank = positions - seg_start[sorted_expert]
    keep = rank < capacity                                # capacity drop
    slot = jnp.where(keep, rank, capacity)                # overflow -> slot C

    # gather tokens into (E, C+1, D); slot C is a waste bucket. Keep the
    # buffer in the WEIGHT dtype: einsum promotion to f32 was measured
    # materializing full f32 copies of the expert weights every step
    # (§Perf 1).
    wdt = params["w_gate"].dtype
    buf = jnp.zeros((num_experts, capacity + 1, d), wdt)
    buf = buf.at[sorted_expert, slot].set(x2d.astype(wdt)[sorted_token])
    buf = buf[:, :capacity]                               # (E, C, D)

    # expert computation: batched SwiGLU over the expert dimension
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # (E, C, D)

    # combine: scatter back with gate weights. The whole path stays in the
    # activation dtype — f32 here doubled the (T·k, D) dispatch collectives
    # that GSPMD emits for the cross-shard scatter (§Perf 3).
    ypad = jnp.concatenate([y, jnp.zeros((num_experts, 1, d), y.dtype)], axis=1)
    contrib = ypad[sorted_expert, slot] * sorted_gate[:, None].astype(y.dtype)
    contrib = jnp.where(keep[:, None], contrib, jnp.zeros((), y.dtype))
    out2d = jnp.zeros((t, d), y.dtype).at[sorted_token].add(contrib)
    out = out2d.reshape(b, s, d).astype(x.dtype)
    if return_aux:
        aux = load_balance_loss(probs, idx, num_experts)
        return out, aux
    return out


def moe_ffn_dense(
    params: Params,
    x: jnp.ndarray,
    num_experts: int,
    k: int,
) -> jnp.ndarray:
    """Oracle: compute all experts for all tokens, mask by routing.

    Exponentially more FLOPs — for tests only (no capacity drops).
    """
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, idx, _ = router_topk(x2d, params["router"], k)
    g = jnp.einsum("td,edf->tef", x2d, params["w_gate"])
    u = jnp.einsum("td,edf->tef", x2d, params["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, params["w_down"])   # (T, E, D)
    weight = jnp.zeros((b * s, num_experts), y.dtype)
    weight = weight.at[jnp.arange(b * s)[:, None], idx].set(gates.astype(y.dtype))
    out = jnp.einsum("ted,te->td", y, weight)
    return out.reshape(b, s, d)
