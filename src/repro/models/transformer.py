"""Model assembly: pattern-scanned decoder stacks for every assigned family.

The layer stack is a repeating *pattern* (``cfg.layout_pattern``); parameters
are stacked over pattern repetitions and the forward pass is a
``jax.lax.scan`` over repetitions, applying each pattern position inline.
This keeps the lowered HLO size O(|pattern|) regardless of depth — essential
for dry-running 61-72 layer models.

Three entry points:
* :func:`forward_train` — full-sequence logits (training / loss);
* :func:`forward_prefill` — logits + populated caches;
* :func:`forward_decode` — one token against caches (serve_step).

Caches are pytrees mirroring the block structure:
attention blocks carry (k, v); SSM blocks carry (conv_state, ssm_state) —
O(1) in sequence length; cross-attention blocks carry precomputed (ck, cv)
from the stub modality embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    attention_output,
    blockwise_attention,
    cross_attention,
    decode_attention,
    project_qkv,
)
from .config import ATTN, ATTN_MOE, CROSS, SSM_MLP, ModelConfig
from .layers import (
    attention_spec,
    dense_init,
    init_attention,
    init_mlp,
    mlp_spec,
    rms_norm,
    swiglu,
)
from .moe import init_moe, moe_ffn, moe_spec
from .ssm import init_mamba2, mamba2_decode_step, mamba2_mixer, mamba2_spec
from ..sharding.context import constrain_batch

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _kind_has_self_attn(kind: str) -> bool:
    return kind in (ATTN, ATTN_MOE)


def _kind_has_ssm(kind: str) -> bool:
    return kind.startswith("ssm")


def _kind_ffn(kind: str, cfg: ModelConfig) -> str:
    """'moe' | 'dense' | 'none' for the FFN half of the block."""
    if kind.endswith("moe"):
        return "moe"
    if kind in (ATTN, CROSS, SSM_MLP):
        return "dense" if cfg.d_ff else "none"
    return "none"


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def init_block(key: jax.Array, kind: str, cfg: ModelConfig,
               with_cross: bool = False) -> Params:
    dt = _dtype(cfg)
    ks = iter(jax.random.split(key, 8))
    p: Params = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if _kind_has_self_attn(kind):
        p["attn"] = init_attention(
            next(ks), cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
            dtype=dt,
        )
    if kind == CROSS:
        p["xattn"] = init_attention(
            next(ks), cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, qk_norm=cfg.qk_norm, gated=True, dtype=dt,
        )
    if _kind_has_ssm(kind):
        p["ssm"] = init_mamba2(
            next(ks), cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
            cfg.ssm_groups, cfg.ssm_conv_width, dtype=dt,
        )
    if with_cross and _kind_has_self_attn(kind):
        # encoder-decoder: every decoder block cross-attends to the encoder
        p["ln_cross"] = jnp.ones((cfg.d_model,), dt)
        p["cross"] = init_attention(
            next(ks), cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, dtype=dt,
        )
    ffn = _kind_ffn(kind, cfg)
    if ffn != "none":
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
    if ffn == "dense":
        p["mlp"] = init_mlp(next(ks), cfg.d_model, cfg.d_ff, dtype=dt)
    elif ffn == "moe":
        p["moe"] = init_moe(next(ks), cfg.d_model, cfg.num_experts,
                            cfg.moe_d_ff, dtype=dt)
    return p


def block_spec(kind: str, cfg: ModelConfig, with_cross: bool = False) -> Params:
    p: Params = {"ln1": ("embed",)}
    if _kind_has_self_attn(kind):
        p["attn"] = attention_spec(cfg.qkv_bias, cfg.qk_norm)
    if kind == CROSS:
        p["xattn"] = attention_spec(False, cfg.qk_norm, gated=True)
    if _kind_has_ssm(kind):
        p["ssm"] = mamba2_spec()
    if with_cross and _kind_has_self_attn(kind):
        p["ln_cross"] = ("embed",)
        p["cross"] = attention_spec()
    ffn = _kind_ffn(kind, cfg)
    if ffn != "none":
        p["ln2"] = ("embed",)
    if ffn == "dense":
        p["mlp"] = mlp_spec()
    elif ffn == "moe":
        p["moe"] = moe_spec()
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    cfg.validate()
    keys = jax.random.split(key, 8)
    reps = cfg.pattern_repeats
    params: Params = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model), scale=0.02,
                            dtype=dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                    dtype=dt)
    blocks = []
    for j, kind in enumerate(cfg.layout_pattern):
        bkeys = jax.random.split(jax.random.fold_in(keys[2], j), reps)
        stacked = jax.vmap(
            lambda k: init_block(k, kind, cfg, with_cross=cfg.is_encoder_decoder)
        )(bkeys)
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    if cfg.is_encoder_decoder:
        ekeys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: init_block(k, ATTN, cfg))(ekeys),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
    return params


def params_spec(cfg: ModelConfig) -> Params:
    spec: Params = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
    }
    if not cfg.tie_embeddings:
        spec["head"] = ("embed", "vocab")

    def stack(tree):
        return jax.tree.map(lambda axes: ("layers",) + tuple(axes), tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    spec["blocks"] = tuple(
        stack(block_spec(kind, cfg, with_cross=cfg.is_encoder_decoder))
        for kind in cfg.layout_pattern
    )
    if cfg.is_encoder_decoder:
        spec["encoder"] = {
            "blocks": stack(block_spec(ATTN, cfg)),
            "final_norm": ("embed",),
        }
    return spec


# ---------------------------------------------------------------------------
# block forward
# ---------------------------------------------------------------------------

def block_forward_full(
    p: Params,
    kind: str,
    cfg: ModelConfig,
    x: jnp.ndarray,                     # (B, S, D)
    positions: jnp.ndarray,             # (B, S)
    cross_src: Optional[jnp.ndarray],   # (B, T, D) image/encoder embeddings
    causal: bool = True,
    want_cache: bool = False,
):
    """Full-sequence pass (train/prefill). Returns (x, cache | None)."""
    cache: Dict[str, jnp.ndarray] = {}
    window = cfg.sliding_window
    if _kind_has_self_attn(kind):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(
            p["attn"], h, positions, cfg.rope_theta, cfg.qk_norm,
            use_rope=True, norm_eps=cfg.norm_eps,
        )
        attn = blockwise_attention(q, k, v, causal=causal, window=window)
        x = x + attention_output(p["attn"], attn)
        if want_cache:
            cache["k"], cache["v"] = k, v
        if "cross" in p and cross_src is not None:
            hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            x = x + cross_attention(p["cross"], hc, cross_src, cfg.norm_eps)
            if want_cache:
                ck = jnp.einsum("btd,dhk->bthk", cross_src, p["cross"]["wk"])
                cv = jnp.einsum("btd,dhk->bthk", cross_src, p["cross"]["wv"])
                cache["ck"], cache["cv"] = ck, cv
    elif kind == CROSS:
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + cross_attention(p["xattn"], h, cross_src, cfg.norm_eps,
                                qk_norm=cfg.qk_norm)
        if want_cache:
            ck = jnp.einsum("btd,dhk->bthk", cross_src, p["xattn"]["wk"])
            cv = jnp.einsum("btd,dhk->bthk", cross_src, p["xattn"]["wv"])
            cache["ck"], cache["cv"] = ck, cv
    elif _kind_has_ssm(kind):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if want_cache:
            y, (conv_st, ssm_st) = mamba2_mixer(p["ssm"], h, cfg,
                                                return_state=True)
            cache["conv"], cache["state"] = conv_st, ssm_st
        else:
            y = mamba2_mixer(p["ssm"], h, cfg)
        x = x + y

    ffn = _kind_ffn(kind, cfg)
    if ffn == "dense":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    elif ffn == "moe":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + moe_ffn(p["moe"], h, cfg.num_experts, cfg.experts_per_token,
                        cfg.capacity_factor)
    return x, (cache if want_cache else None)


def block_forward_decode(
    p: Params,
    kind: str,
    cfg: ModelConfig,
    x: jnp.ndarray,                     # (B, 1, D)
    position: jnp.ndarray,              # (B, 1) absolute position
    cache: Dict[str, jnp.ndarray],
    cache_len: jnp.ndarray,             # scalar int32
):
    """One-token decode. Returns (x, new_cache)."""
    new_cache = dict(cache)
    window = cfg.sliding_window
    if _kind_has_self_attn(kind):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(
            p["attn"], h, position, cfg.rope_theta, cfg.qk_norm,
            use_rope=True, norm_eps=cfg.norm_eps,
        )
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
        new_cache["k"], new_cache["v"] = ck, cv
        attn = decode_attention(q, ck, cv, cache_len + 1, window=window)
        x = x + attention_output(p["attn"], attn)
        if "cross" in p:
            hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            qc = jnp.einsum("bsd,dhk->bshk", hc, p["cross"]["wq"])
            a = decode_attention(qc, cache["ck"], cache["cv"],
                                 jnp.int32(cache["ck"].shape[1]))
            x = x + attention_output(p["cross"], a)
    elif kind == CROSS:
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dhk->bshk", h, p["xattn"]["wq"])
        if cfg.qk_norm:
            qc = rms_norm(qc, p["xattn"]["q_norm"], cfg.norm_eps)
        a = decode_attention(qc, cache["ck"], cache["cv"],
                             jnp.int32(cache["ck"].shape[1]))
        y = attention_output(p["xattn"], a)
        if "attn_gate" in p["xattn"]:
            y = jnp.tanh(p["xattn"]["attn_gate"]) * y
        x = x + y
    elif _kind_has_ssm(kind):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, (conv_st, ssm_st) = mamba2_decode_step(
            p["ssm"], h, cfg, cache["conv"], cache["state"]
        )
        new_cache["conv"], new_cache["state"] = conv_st, ssm_st
        x = x + y

    ffn = _kind_ffn(kind, cfg)
    if ffn == "dense":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    elif ffn == "moe":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + moe_ffn(p["moe"], h, cfg.num_experts, cfg.experts_per_token,
                        cfg.capacity_factor)
    return x, new_cache


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _scan_stack(
    cfg: ModelConfig,
    blocks: Tuple[Params, ...],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cross_src: Optional[jnp.ndarray],
    causal: bool = True,
    want_cache: bool = False,
    remat: bool = False,
):
    """Scan over pattern repetitions; returns (x, caches per position)."""

    def body(carry, rep_params):
        h = constrain_batch(carry)
        caches = []
        for j, kind in enumerate(cfg.layout_pattern):
            h, c = block_forward_full(
                rep_params[j], kind, cfg, h, positions, cross_src,
                causal=causal, want_cache=want_cache,
            )
            h = constrain_batch(h)
            caches.append(c if want_cache else 0)
        return h, tuple(caches)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, blocks)
    return x, caches


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Encoder stack over stub frame embeddings (whisper)."""
    enc = params["encoder"]
    b, t, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))

    def body(carry, blk):
        h, _ = block_forward_full(blk, ATTN, cfg, carry, pos, None, causal=False)
        return h, 0

    x, _ = jax.lax.scan(body, frames, enc["blocks"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward_train(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,                  # (B, S) int32
    cross_src: Optional[jnp.ndarray] = None,  # stub modality embeddings
    remat: bool = True,
) -> jnp.ndarray:
    b, s = tokens.shape
    x = constrain_batch(params["embed"][tokens])
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    if cfg.is_encoder_decoder and cross_src is not None:
        cross_src = encode(params, cfg, cross_src)
    x, _ = _scan_stack(cfg, params["blocks"], x, pos, cross_src, remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


def forward_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    max_cache_len: int,
    cross_src: Optional[jnp.ndarray] = None,
):
    """Returns (last-token logits, caches, cache_len)."""
    b, s = tokens.shape
    x = constrain_batch(params["embed"][tokens])
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    if cfg.is_encoder_decoder and cross_src is not None:
        cross_src = encode(params, cfg, cross_src)
    x, caches = _scan_stack(cfg, params["blocks"], x, pos, cross_src,
                            want_cache=True)
    caches = _pad_caches(cfg, caches, max_cache_len)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, caches, jnp.int32(s)


def _pad_caches(cfg: ModelConfig, caches, max_cache_len: int):
    """Grow k/v caches to the serving capacity."""
    out = []
    for j, kind in enumerate(cfg.layout_pattern):
        c = caches[j]
        if isinstance(c, dict) and "k" in c:
            pad = max_cache_len - c["k"].shape[2]   # (R, B, S, Kv, hd)
            if pad > 0:
                c = dict(c)
                c["k"] = jnp.pad(c["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                c["v"] = jnp.pad(c["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        out.append(c)
    return tuple(out)


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_cache_len: int,
    cross_len: int = 0,
    dtype=None,
):
    """Empty serving caches for ``forward_decode`` (decode-only dry-run)."""
    dt = dtype or _dtype(cfg)
    reps = cfg.pattern_repeats
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    caches = []
    for kind in cfg.layout_pattern:
        c: Dict[str, jnp.ndarray] = {}
        if _kind_has_self_attn(kind):
            # sliding-window models only retain the window in the cache
            s = min(max_cache_len, cfg.sliding_window) if cfg.sliding_window else max_cache_len
            c["k"] = jnp.zeros((reps, batch, s, kvh, hd), dt)
            c["v"] = jnp.zeros((reps, batch, s, kvh, hd), dt)
            if cfg.is_encoder_decoder:
                c["ck"] = jnp.zeros((reps, batch, cross_len, kvh, hd), dt)
                c["cv"] = jnp.zeros((reps, batch, cross_len, kvh, hd), dt)
        if kind == CROSS:
            c["ck"] = jnp.zeros((reps, batch, cross_len, kvh, hd), dt)
            c["cv"] = jnp.zeros((reps, batch, cross_len, kvh, hd), dt)
        if _kind_has_ssm(kind):
            c["conv"] = jnp.zeros(
                (reps, batch, cfg.ssm_conv_width - 1,
                 cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state), dt)
            c["state"] = jnp.zeros(
                (reps, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32)
        caches.append(c)
    return tuple(caches)


def forward_decode(
    params: Params,
    cfg: ModelConfig,
    token: jnp.ndarray,                    # (B, 1) int32
    caches,
    cache_len: jnp.ndarray,                # scalar int32: tokens already cached
    unroll: bool = True,
):
    """serve_step: one new token, updated caches.

    The layer loop is UNROLLED by default (serving-framework practice):
    scanning layers stacks cache updates through the scan's ys
    dynamic-update-slice, and nesting the (dynamic) sequence-position DUS
    inside it defeats XLA's in-place aliasing — measured as a full rewrite
    of the 61-layer KV cache per decoded token at kimi-k2 scale (§Perf 1).
    Unrolled, the per-layer cache index is static and aliasing holds; HLO
    size is O(layers) but decode graphs are small.
    """
    b = token.shape[0]
    x = params["embed"][token]
    pos = jnp.broadcast_to(cache_len[None, None], (b, 1))

    def one_block(h, rep_params_j, cache_j, kind):
        if cfg.sliding_window and _kind_has_self_attn(kind):
            write_pos = cache_len % cache_j["k"].shape[1]
        else:
            write_pos = cache_len
        return block_forward_decode(rep_params_j, kind, cfg, h, pos,
                                    cache_j, write_pos)

    if unroll:
        reps = cfg.pattern_repeats
        cur = [dict(caches[j]) for j in range(len(cfg.layout_pattern))]
        h = constrain_batch(x)
        for r in range(reps):
            for j, kind in enumerate(cfg.layout_pattern):
                rep_params_j = jax.tree.map(lambda a: a[r], params["blocks"][j])
                cache_j = {k: v[r] for k, v in cur[j].items()}
                h, c = one_block(h, rep_params_j, cache_j, kind)
                h = constrain_batch(h)
                for k, v in c.items():
                    # static layer index -> aliasable in-place update
                    cur[j][k] = cur[j][k].at[r].set(v)
        x = h
        new_caches = tuple(cur)
    else:
        def body(carry, rep):
            rep_params, rep_cache = rep
            h = constrain_batch(carry)
            new = []
            for j, kind in enumerate(cfg.layout_pattern):
                h, c = one_block(h, rep_params[j], rep_cache[j], kind)
                new.append(c)
            return h, tuple(new)

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, new_caches, cache_len + 1
