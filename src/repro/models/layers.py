"""Basic building blocks: norms, RoPE, SwiGLU, parameter initialization.

Parameters are plain pytrees (nested dicts of jnp arrays). Every initializer
has a twin ``*_spec`` returning the same structure with *logical axis*
tuples per leaf; ``repro.sharding.rules`` maps logical axes to mesh axes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x: jnp.ndarray, w_up: jnp.ndarray, b_up: jnp.ndarray,
             w_down: jnp.ndarray, b_down: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_up) + b_up)
    return jnp.einsum("...f,fd->...d", h, w_down) + b_down


# -- RoPE -----------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- initializers -------------------------------------------------------------

def dense_init(key: jax.Array, shape: Tuple[int, ...], scale: Optional[float] = None,
               dtype=jnp.float32) -> jnp.ndarray:
    fan_in = shape[0]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * s).astype(dtype)


def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp_spec() -> Params:
    return {
        "w_gate": ("embed", "ffn"),
        "w_up": ("embed", "ffn"),
        "w_down": ("ffn", "embed"),
    }


def init_attention(key: jax.Array, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False, qk_norm: bool = False,
                   gated: bool = False, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d_model, num_heads, head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, num_kv_heads, head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, num_kv_heads, head_dim), dtype=dtype),
        "wo": dense_init(ks[3], (num_heads, head_dim, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads, head_dim), dtype=dtype)
        p["bk"] = jnp.zeros((num_kv_heads, head_dim), dtype=dtype)
        p["bv"] = jnp.zeros((num_kv_heads, head_dim), dtype=dtype)
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype=dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype=dtype)
    if gated:  # llama-3.2-vision cross-attn gates
        p["attn_gate"] = jnp.zeros((1,), dtype=dtype)
    return p


def attention_spec(qkv_bias: bool = False, qk_norm: bool = False,
                   gated: bool = False) -> Params:
    p: Params = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if qkv_bias:
        p["bq"] = ("heads", "head_dim")
        p["bk"] = ("kv_heads", "head_dim")
        p["bv"] = ("kv_heads", "head_dim")
    if qk_norm:
        p["q_norm"] = ("head_dim",)
        p["k_norm"] = ("head_dim",)
    if gated:
        p["attn_gate"] = (None,)
    return p
