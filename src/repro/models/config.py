"""Model configuration schema covering all assigned architecture families.

One :class:`ModelConfig` describes any of: dense decoder (GQA/RoPE/SwiGLU,
optional qk-norm/QKV-bias/sliding-window), MoE, Mamba2 SSD, hybrid
(attention/SSM interleave with optional MoE FFN), encoder-decoder (audio),
and VLM (interleaved cross-attention layers consuming stub image
embeddings).

Layer stacking uses a repeating *pattern*: ``layout_pattern`` lists the
block kinds of one period; the model is ``num_layers / len(pattern)``
repetitions. The launcher scans over repetitions so the lowered HLO stays
O(pattern), not O(num_layers).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

# block kinds
ATTN = "attn"            # self-attention + dense FFN
ATTN_MOE = "attn_moe"    # self-attention + MoE FFN
SSM = "ssm"              # Mamba2 mixer (no separate FFN)
SSM_MOE = "ssm_moe"      # Mamba2 mixer + MoE FFN (Jamba style)
SSM_MLP = "ssm_mlp"      # Mamba2 mixer + dense FFN (Jamba style)
CROSS = "cross"          # self-attn is replaced by gated cross-attention + FFN

VALID_KINDS = (ATTN, ATTN_MOE, SSM, SSM_MOE, SSM_MLP, CROSS)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    layout_pattern: Tuple[str, ...] = (ATTN,)
    head_dim: Optional[int] = None
    # attention options -----------------------------------------------------
    rope_theta: float = 10_000.0
    qk_norm: bool = False             # qwen3
    qkv_bias: bool = False            # qwen2.5
    sliding_window: Optional[int] = None  # enables sub-quadratic long context
    # MoE ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                 # per-expert hidden size
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD) ------------------------------------------------------
    ssm_state: int = 0                # N
    ssm_head_dim: int = 64            # P
    ssm_expand: int = 2
    ssm_chunk: int = 128              # SSD chunk length Q
    ssm_conv_width: int = 4
    ssm_groups: int = 1               # G (B/C groups)
    # encoder-decoder (audio) ---------------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500       # whisper: 30 s of audio at 50 Hz
    # VLM -----------------------------------------------------------------
    num_image_tokens: int = 0         # cross-attn KV length (stub embeddings)
    # misc -------------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # citation of the public source for this config
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:        # attention-free (pure SSM)
            return 0
        return self.d_model // self.num_heads

    @property
    def pattern_repeats(self) -> int:
        if self.num_layers % len(self.layout_pattern):
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.layout_pattern)}"
            )
        return self.num_layers // len(self.layout_pattern)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def uses_ssm(self) -> bool:
        return any(k.startswith("ssm") for k in self.layout_pattern)

    @property
    def uses_moe(self) -> bool:
        return any(k.endswith("moe") for k in self.layout_pattern)

    @property
    def uses_attention(self) -> bool:
        return any(k in (ATTN, ATTN_MOE, CROSS) for k in self.layout_pattern)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid natively; dense via sliding window."""
        if not self.uses_attention:
            return True
        return self.sliding_window is not None or self.uses_ssm

    def validate(self) -> "ModelConfig":
        for k in self.layout_pattern:
            if k not in VALID_KINDS:
                raise ValueError(f"unknown block kind {k}")
        _ = self.pattern_repeats
        if self.uses_attention and self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must be divisible by num_kv_heads")
        if self.uses_moe and not (0 < self.experts_per_token <= self.num_experts):
            raise ValueError("bad MoE top-k")
        if self.uses_ssm and self.d_inner % self.ssm_head_dim:
            raise ValueError("d_inner must be divisible by ssm_head_dim")
        return self

    # -- parameter counting (for roofline MODEL_FLOPS) -----------------------
    def param_count(self) -> int:
        D, V = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        n = 0
        n += V * D                                   # embed
        if not self.tie_embeddings:
            n += D * V                               # head
        per_kind = {}
        for kind in set(self.layout_pattern):
            p = 2 * D           # two norms
            if kind in (ATTN, ATTN_MOE, CROSS):
                q = D * self.num_heads * hd
                kv = 2 * D * self.num_kv_heads * hd
                o = self.num_heads * hd * D
                p += q + kv + o
                if kind == CROSS:
                    p += D  # attention gate
            if kind in (SSM, SSM_MOE, SSM_MLP):
                di, N, G, H = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
                in_proj = D * (2 * di + 2 * G * N + H)
                conv = (di + 2 * G * N) * self.ssm_conv_width
                out = di * D
                p += in_proj + conv + out + 2 * H + di
            if kind in (ATTN, SSM_MLP) and self.d_ff:
                p += 3 * D * self.d_ff               # SwiGLU
            if kind.endswith("moe"):
                p += D * self.num_experts            # router
                p += self.num_experts * 3 * D * self.moe_d_ff
            per_kind[kind] = p
        for kind in self.layout_pattern:
            n += per_kind[kind] * self.pattern_repeats
        if self.is_encoder_decoder:
            # encoder: attn + dense FFN per layer + cross-attn params in decoder
            enc = self.encoder_layers * (
                2 * D + 2 * D * self.num_heads * hd + 2 * D * self.num_kv_heads * hd
                + 3 * D * self.d_ff
            )
            dec_cross = self.num_layers * (
                D + D * self.num_heads * hd + 2 * D * self.num_kv_heads * hd
                + self.num_heads * hd * D
            )
            n += enc + dec_cross
        return int(n)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.uses_moe:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for k in self.layout_pattern if k.endswith("moe"))
        moe_layers *= self.pattern_repeats
        all_experts = moe_layers * self.num_experts * 3 * self.d_model * self.moe_d_ff
        active = moe_layers * self.experts_per_token * 3 * self.d_model * self.moe_d_ff
        return int(full - all_experts + active)


def uniform_layout(kind: str) -> Tuple[str, ...]:
    return (kind,)
