"""JAX model stack: all assigned architecture families."""
from .attention import blockwise_attention, cross_attention, decode_attention, project_qkv
from .config import (
    ATTN,
    ATTN_MOE,
    CROSS,
    SSM,
    SSM_MLP,
    SSM_MOE,
    ModelConfig,
)
from .layers import apply_rope, rms_norm, swiglu
from .moe import load_balance_loss, moe_ffn, moe_ffn_dense, router_topk
from .ssm import mamba2_decode_step, mamba2_mixer, ssd_chunked
from .transformer import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_cache,
    init_params,
    params_spec,
)

__all__ = [k for k in dir() if not k.startswith("_")]
