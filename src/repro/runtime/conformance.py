"""Runtime↔simulator conformance: trace extraction, diffing, reporting.

The paper's accuracy claim is *device-in-the-loop* evaluation — predicted
schedules are validated by actually executing them (§4.2/§5). This module
closes that loop for the repo's engine stack: it runs a schedule on
:class:`~repro.runtime.PuzzleRuntime`, extracts a task trace in the exact
schema of the committed golden traces (``tests/golden/``), and diffs it
against a simulator run of the same schedule.

Two conformance regimes:

* **virtual** — the runtime replays :class:`~repro.core.fastsim.FastSimSpec`
  costs on a virtual clock; the comparison is at **zero tolerance** (every
  release/start/finish timestamp, every makespan, the busy times and the
  task ordering must match the simulator bit for bit).
* **real** — the runtime genuinely executes the models with wall-clock
  timing; thread scheduling makes exact ordering unreproducible, so the
  comparison is **bounded relative error** on per-request makespans.

Entry point for users: ``StaticAnalyzer.validate_on_runtime``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.arrivals import ArrivalSpec
from ..core.chromosome import Solution
from ..core.fastsim import FastSimSpec
from ..core.faults import FaultSpec
from ..core.graph import ModelGraph
from ..core.processors import Processor
from ..core.simulator import NoiseModel, RequestRecord, SimResult, TaskRecord
from .runtime import PuzzleRuntime, RuntimeConfig


def serialize_result(res: SimResult) -> Dict[str, object]:
    """Golden-trace schema (``tests/golden/*.json``) of a SimResult.

    Single source of truth for the schema: the golden-trace tests, the
    conformance reports and the CI artifacts all serialize through here.
    """
    return {
        "horizon": res.horizon,
        "busy_time": {str(pid): t for pid, t in sorted(res.busy_time.items())},
        "requests": [
            [r.group, r.request, r.arrival, r.first_start, r.last_finish,
             r.done_tasks, r.total_tasks]
            for r in res.requests
        ],
        "makespans": [
            None if math.isinf(r.makespan) else r.makespan
            for r in res.requests
        ],
        "tasks": [
            [t.group, t.request, t.network, t.sg_index, t.processor,
             t.released, t.started, t.finished,
             t.comm_time, t.quant_time, t.exec_time]
            for t in res.tasks
        ],
    }


def runtime_result(
    runtime: PuzzleRuntime,
    states: Sequence[Sequence[object]],
    periods: Sequence[float],
    num_requests: int,
    rebase: bool = False,
    arrivals: Optional[ArrivalSpec] = None,
) -> SimResult:
    """Build a simulator-comparable :class:`SimResult` from a runtime run.

    ``states`` is ``run_periodic``'s return value (request states per
    group). With ``rebase`` (real-exec mode) all wall-clock timestamps are
    shifted so the earliest submission is t=0, making them comparable to
    simulated time.
    """
    t0 = 0.0
    if rebase:
        submits = [st.submitted for glist in states for st in glist]
        t0 = min(submits) if submits else 0.0

    requests: List[RequestRecord] = []
    for gid, glist in enumerate(states):
        for rid, st in enumerate(glist):
            requests.append(RequestRecord(
                group=gid, request=rid, arrival=st.submitted - t0,
                first_start=(float("inf") if st.first_start is None
                             else st.first_start - t0),
                last_finish=(st.last_finish - t0 if st.last_finish else 0.0),
                done_tasks=st.done_tasks, total_tasks=st.total_tasks,
            ))
    tasks: List[TaskRecord] = []
    for rec in runtime.coordinator.trace:
        if rebase:
            rec = TaskRecord(
                group=rec.group, request=rec.request, network=rec.network,
                sg_index=rec.sg_index, processor=rec.processor,
                released=rec.released - t0,
                started=rec.started - t0 if rec.started else 0.0,
                finished=rec.finished - t0 if rec.finished else 0.0,
                comm_time=rec.comm_time, exec_time=rec.exec_time,
                quant_time=rec.quant_time,
            )
        tasks.append(rec)
    return SimResult(
        requests=sorted(requests, key=lambda r: (r.group, r.request)),
        tasks=tasks,
        busy_time={pid: w.busy_time for pid, w in runtime.workers.items()},
        horizon=PuzzleRuntime.sim_horizon(periods, num_requests,
                                          arrivals=arrivals),
    )


def run_virtual_schedule(
    graphs: Sequence[ModelGraph],
    solution: Solution,
    processors: Sequence[Processor],
    spec: FastSimSpec,
    groups: Sequence[Sequence[int]],
    periods: Sequence[float],
    num_requests: int,
    noise: Optional[NoiseModel] = None,
    dispatch_overhead: float = 0.0,
    dispatch_pid: int = 0,
    arrivals: Optional[ArrivalSpec] = None,
    faults: Optional[FaultSpec] = None,
) -> SimResult:
    """Execute a schedule on the virtual-clock runtime; return its trace.

    This is the fourth engine tier: the *actual* Coordinator/Worker
    dispatch code, replaying the spec's costs deterministically. The result
    is bit-comparable to ``FastSimulator(spec, ...).run(collect_tasks=True)``
    with the same parameters (including the ``arrivals`` process and the
    ``faults`` ensemble — injected raw, with no recovery policy, which is
    the parity-oracle setting).
    """
    rt = PuzzleRuntime(
        graphs, solution, processors,
        config=RuntimeConfig(
            virtual=True, noise=noise,
            dispatch_overhead=dispatch_overhead, dispatch_pid=dispatch_pid,
            faults=faults,
        ),
        spec=spec,
    )
    with rt:
        states = rt.run_periodic(groups, periods, num_requests=num_requests,
                                 arrivals=arrivals)
        return runtime_result(rt, states, periods, num_requests,
                              arrivals=arrivals)


@dataclass
class ConformanceReport:
    """Outcome of one runtime↔simulator conformance run."""

    mode: str                          # "virtual" | "real"
    rel_tol: float
    runtime_tasks: int
    sim_tasks: int
    ordering_match: bool               # identical task release sequences
    max_release_diff: float
    max_start_diff: float
    max_finish_diff: float
    max_makespan_diff: float           # abs; inf when only one side dropped
    max_makespan_rel_err: float
    max_busy_diff: float
    passed: bool
    runtime_trace: Dict[str, object]   # golden-trace schema
    sim_trace: Dict[str, object]

    def summary(self) -> Dict[str, float]:
        """JSON-safe scalar summary (for sweep results / CI artifacts)."""
        def _f(v: float) -> Optional[float]:
            return None if math.isinf(v) else v
        return {
            "mode": self.mode,
            "runtime_tasks": self.runtime_tasks,
            "sim_tasks": self.sim_tasks,
            "ordering_match": bool(self.ordering_match),
            "max_release_diff": _f(self.max_release_diff),
            "max_start_diff": _f(self.max_start_diff),
            "max_finish_diff": _f(self.max_finish_diff),
            "max_makespan_diff": _f(self.max_makespan_diff),
            "max_makespan_rel_err": _f(self.max_makespan_rel_err),
            "max_busy_diff": _f(self.max_busy_diff),
            "passed": bool(self.passed),
        }

    def to_json(self, include_traces: bool = True) -> Dict[str, object]:
        doc: Dict[str, object] = dict(self.summary())
        if include_traces:
            doc["runtime_trace"] = self.runtime_trace
            doc["sim_trace"] = self.sim_trace
        return doc


def _task_key(t: TaskRecord) -> Tuple[int, int, int, int]:
    return (t.group, t.request, t.network, t.sg_index)


def build_report(
    mode: str,
    runtime_res: SimResult,
    sim_res: SimResult,
    rel_tol: float = 0.0,
) -> ConformanceReport:
    """Diff a runtime trace against a simulator trace.

    Virtual mode (``rel_tol = 0``) passes only on an exact match: same
    release ordering, zero max-abs diff on every release/start/finish
    timestamp, identical makespans (dropped requests must be dropped on
    both sides) and identical busy times. Real mode passes when per-request
    makespans agree within ``rel_tol`` relative error and both sides
    release the same task set (ordering is reported but not enforced —
    thread scheduling is not reproducible).
    """
    order_rt = [(t.group, t.request, t.network, t.sg_index, t.processor)
                for t in runtime_res.tasks]
    order_sim = [(t.group, t.request, t.network, t.sg_index, t.processor)
                 for t in sim_res.tasks]
    ordering_match = order_rt == order_sim

    by_key_rt = {_task_key(t): t for t in runtime_res.tasks}
    by_key_sim = {_task_key(t): t for t in sim_res.tasks}
    same_tasks = set(by_key_rt) == set(by_key_sim)
    rel_diff = 0.0
    start_diff = 0.0
    finish_diff = 0.0
    for key in set(by_key_rt) & set(by_key_sim):
        a, b = by_key_rt[key], by_key_sim[key]
        rel_diff = max(rel_diff, abs(a.released - b.released))
        start_diff = max(start_diff, abs(a.started - b.started))
        finish_diff = max(finish_diff, abs(a.finished - b.finished))

    ms_diff = 0.0
    ms_rel = 0.0
    req_rt = {(r.group, r.request): r for r in runtime_res.requests}
    req_sim = {(r.group, r.request): r for r in sim_res.requests}
    for key in set(req_rt) | set(req_sim):
        a, b = req_rt.get(key), req_sim.get(key)
        if a is None or b is None:
            ms_diff = ms_rel = float("inf")
            continue
        ma, mb = a.makespan, b.makespan
        if math.isinf(ma) and math.isinf(mb):
            continue
        if math.isinf(ma) or math.isinf(mb):
            ms_diff = ms_rel = float("inf")
            continue
        ms_diff = max(ms_diff, abs(ma - mb))
        if mb > 0:
            ms_rel = max(ms_rel, abs(ma - mb) / mb)

    busy_diff = 0.0
    for pid in set(runtime_res.busy_time) | set(sim_res.busy_time):
        busy_diff = max(busy_diff, abs(
            runtime_res.busy_time.get(pid, 0.0)
            - sim_res.busy_time.get(pid, 0.0)))

    if mode == "virtual":
        passed = (
            ordering_match and same_tasks
            and rel_diff == 0.0 and start_diff == 0.0 and finish_diff == 0.0
            and ms_diff == 0.0 and busy_diff == 0.0
        )
    else:
        passed = same_tasks and ms_rel <= rel_tol

    return ConformanceReport(
        mode=mode,
        rel_tol=rel_tol,
        runtime_tasks=len(runtime_res.tasks),
        sim_tasks=len(sim_res.tasks),
        ordering_match=ordering_match,
        max_release_diff=rel_diff,
        max_start_diff=start_diff,
        max_finish_diff=finish_diff,
        max_makespan_diff=ms_diff,
        max_makespan_rel_err=ms_rel,
        max_busy_diff=busy_diff,
        passed=passed,
        runtime_trace=serialize_result(runtime_res),
        sim_trace=serialize_result(sim_res),
    )
