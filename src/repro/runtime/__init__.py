"""Puzzle Runtime: Coordinator / Workers / Engines + memory optimizations,
plus the virtual-clock conformance tier and measured-cost extraction."""
from .clock import SimCostSource, VirtualClock, WallClock
from .conformance import (
    ConformanceReport,
    build_report,
    run_virtual_schedule,
    runtime_result,
    serialize_result,
)
from .coordinator import Coordinator, RequestState
from .engine import ENGINE_REGISTRY, EagerEngine, Engine, FastMathJitEngine, JitEngine, make_engine
from .recovery import RecoveryEvent, RecoveryPolicy, greedy_remap
from .runtime import PuzzleRuntime, RuntimeConfig
from .tensorpool import CHUNK, SharedBufferTransport, TensorPool
from .worker import DISPATCH_TOKEN, Worker, WorkerExecutionError

__all__ = [k for k in dir() if not k.startswith("_")]
