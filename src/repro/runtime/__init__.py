"""Puzzle Runtime: Coordinator / Workers / Engines + memory optimizations."""
from .coordinator import Coordinator, RequestState
from .engine import ENGINE_REGISTRY, EagerEngine, Engine, FastMathJitEngine, JitEngine, make_engine
from .runtime import PuzzleRuntime, RuntimeConfig
from .tensorpool import CHUNK, SharedBufferTransport, TensorPool
from .worker import Worker

__all__ = [k for k in dir() if not k.startswith("_")]
