"""Tensor Pool and Zero-Copy Shared Buffer (paper §5.3).

``TensorPool`` pre-allocates and recycles memory buffers in 2048-byte
chunks (the paper's chunk size) so repeated inferences reuse the same
physical pages — the paper measured −76.8% malloc time, −99.4% free time
and −65.9% memcpy time from this. ``acquire`` returns a numpy view sized
to the request, rounded up to chunk multiples so one buffer serves many
tensor shapes.

``SharedBufferTransport`` is the host analogue of the ION/DMA-BUF shared
buffer: producers hand consumers a reference to the same backing store
(zero-copy) instead of serializing through a staging copy.
"""
from __future__ import annotations

import threading
import weakref
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

# The chunk math lives in repro.core.memlayout (no jax dependency) so the
# static analyzer can bound residency with exactly the pool's accounting
# without importing the runtime package; re-exported here for compat.
from ..core.memlayout import CHUNK, rounded_chunk_bytes

__all__ = [
    "CHUNK", "rounded_chunk_bytes", "TensorPoolOOM", "PoolStats",
    "TensorPool", "SharedBufferTransport",
]


class TensorPoolOOM(MemoryError):
    """Raised by :meth:`TensorPool.acquire` when a capacity-bounded pool
    would exceed its budget even after recycling every free buffer."""


@dataclass
class PoolStats:
    mallocs: int = 0
    reuses: int = 0
    frees: int = 0
    #: double-releases and foreign (never-acquired) buffers, ignored rather
    #: than pooled — each one would otherwise alias or pollute the free list
    rejected_frees: int = 0
    bytes_allocated: int = 0
    memcpy_bytes: int = 0
    memcpy_calls: int = 0
    #: high-water mark of bytes held by live (unreleased) acquisitions
    peak_bytes_in_use: int = 0
    #: acquisitions refused because they would exceed ``capacity_bytes``
    oom_rejections: int = 0


class TensorPool:
    """Chunk-granular buffer pool with free-list reuse.

    Outstanding buffers are tracked by backing-store identity: a release is
    only honored for a base buffer this pool handed out and that is not
    already back in the free list. That closes two corruption paths the
    naive free list had — releasing the same buffer twice used to enqueue
    it twice, so two later ``acquire`` calls returned views over **one**
    backing store (silent data corruption); and releasing a foreign
    non-chunk-rounded array created a free-list bucket keyed by its
    unrounded ``nbytes`` that ``acquire`` (which only looks up rounded
    sizes) could never serve, growing without bound. Both cases are now
    ignored and counted in ``stats.rejected_frees``; honored releases
    increment ``stats.frees`` on the pooled path too, so the §5.3 free-time
    accounting adds up (``frees + rejected_frees`` = release calls).

    Known limit: views carry no acquisition token, so a *stale* release of
    a view whose backing store was already recycled to a new owner (release
    → re-acquire → release the old view again) is indistinguishable from
    the new owner's release — that is caller use-after-free, which no
    free-list can detect without an ownership handle; the tracking here
    defends against double-release and foreign buffers, not against a
    caller that keeps using a view it already released.
    """

    def __init__(self, enabled: bool = True,
                 capacity_bytes: Optional[int] = None):
        if capacity_bytes is not None and capacity_bytes <= 0:
            raise ValueError("capacity_bytes must be positive (or None)")
        self.enabled = enabled
        self._capacity = capacity_bytes
        self._free: Dict[int, List[np.ndarray]] = {}
        self._lock = threading.Lock()
        # id(base) -> base for buffers handed out and not yet released.
        # Weak values: a caller that drops its view without releasing must
        # not pin the backing store (and a recycled id can then never match
        # a stale entry — dead entries vanish with their array).
        self._outstanding: "weakref.WeakValueDictionary[int, np.ndarray]" = (
            weakref.WeakValueDictionary())
        self.stats = PoolStats()

    def capacity(self) -> Optional[int]:
        """Byte budget this pool enforces, or ``None`` when unbounded."""
        return self._capacity

    def bytes_in_use(self) -> int:
        """Chunk-rounded bytes currently held by unreleased acquisitions.

        Derived from the outstanding-buffer registry (weak values), so views
        dropped without an explicit ``release`` stop counting once collected
        — the figure cannot drift. Only meaningful when ``enabled``; a
        disabled pool tracks nothing and reports 0.
        """
        with self._lock:
            return self._in_use_locked()

    def _in_use_locked(self) -> int:
        return sum(buf.nbytes for buf in self._outstanding.values())

    def _rounded(self, nbytes: int) -> int:
        return rounded_chunk_bytes(nbytes)

    def _reserve(self, size: int) -> None:
        # called under self._lock; capacity counts live acquisitions only
        # (free-list buffers are recyclable, not occupied)
        in_use = self._in_use_locked()
        if self._capacity is not None and in_use + size > self._capacity:
            self.stats.oom_rejections += 1
            raise TensorPoolOOM(
                f"acquire of {size} B exceeds pool capacity "
                f"{self._capacity} B ({in_use} B in use)")
        if in_use + size > self.stats.peak_bytes_in_use:
            self.stats.peak_bytes_in_use = in_use + size

    def acquire(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        size = self._rounded(nbytes)
        if self.enabled:
            with self._lock:
                bucket = self._free.get(size)
                if bucket:
                    self._reserve(size)
                    buf = bucket.pop()
                    self.stats.reuses += 1
                    self._outstanding[id(buf)] = buf
                    return buf[:nbytes].view(dtype).reshape(shape)
                self._reserve(size)
        self.stats.mallocs += 1
        self.stats.bytes_allocated += size
        buf = np.empty(size, dtype=np.uint8)
        if self.enabled:
            with self._lock:
                self._outstanding[id(buf)] = buf
        return buf[:nbytes].view(dtype).reshape(shape)

    def release(self, arr: np.ndarray) -> None:
        base = arr
        while base.base is not None:
            base = base.base
        if not self.enabled:
            self.stats.frees += 1
            return
        with self._lock:
            tracked = self._outstanding.pop(id(base), None)
            if tracked is not base:
                # double release (already back in the free list) or a
                # foreign buffer this pool never handed out: pooling it
                # would alias future acquisitions or leak unservable
                # buckets, so ignore it.
                self.stats.rejected_frees += 1
                return
            self.stats.frees += 1
            self._free.setdefault(base.nbytes, []).append(base)

    def stage(self, src: np.ndarray) -> np.ndarray:
        """Copy ``src`` into a pooled buffer (the marshalling path)."""
        dst = self.acquire(src.shape, src.dtype)
        np.copyto(dst, src)
        self.stats.memcpy_calls += 1
        self.stats.memcpy_bytes += src.nbytes
        return dst


@dataclass
class TransportStats:
    zero_copies: int = 0
    staged_copies: int = 0
    staged_bytes: int = 0


class SharedBufferTransport:
    """Inter-worker tensor hand-off: zero-copy when enabled, staged copy
    through the pool otherwise (the paper's pre-DMA-BUF baseline)."""

    def __init__(self, pool: TensorPool, zero_copy: bool = True):
        self.pool = pool
        self.zero_copy = zero_copy
        self.stats = TransportStats()

    def transfer(self, tensor) -> object:
        if self.zero_copy:
            self.stats.zero_copies += 1
            return tensor            # same backing store crosses the boundary
        arr = np.asarray(tensor)
        out = self.pool.stage(arr)
        self.stats.staged_copies += 1
        self.stats.staged_bytes += arr.nbytes
        return out
