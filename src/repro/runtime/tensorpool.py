"""Tensor Pool and Zero-Copy Shared Buffer (paper §5.3).

``TensorPool`` pre-allocates and recycles memory buffers in 2048-byte
chunks (the paper's chunk size) so repeated inferences reuse the same
physical pages — the paper measured −76.8% malloc time, −99.4% free time
and −65.9% memcpy time from this. ``acquire`` returns a numpy view sized
to the request, rounded up to chunk multiples so one buffer serves many
tensor shapes.

``SharedBufferTransport`` is the host analogue of the ION/DMA-BUF shared
buffer: producers hand consumers a reference to the same backing store
(zero-copy) instead of serializing through a staging copy.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

CHUNK = 2048  # bytes, paper §5.3


@dataclass
class PoolStats:
    mallocs: int = 0
    reuses: int = 0
    frees: int = 0
    bytes_allocated: int = 0
    memcpy_bytes: int = 0
    memcpy_calls: int = 0


class TensorPool:
    """Chunk-granular buffer pool with free-list reuse."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._free: Dict[int, List[np.ndarray]] = {}
        self._lock = threading.Lock()
        self.stats = PoolStats()

    def _rounded(self, nbytes: int) -> int:
        return max(CHUNK, ((nbytes + CHUNK - 1) // CHUNK) * CHUNK)

    def acquire(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        size = self._rounded(nbytes)
        if self.enabled:
            with self._lock:
                bucket = self._free.get(size)
                if bucket:
                    buf = bucket.pop()
                    self.stats.reuses += 1
                    return buf[:nbytes].view(dtype).reshape(shape)
        self.stats.mallocs += 1
        self.stats.bytes_allocated += size
        buf = np.empty(size, dtype=np.uint8)
        return buf[:nbytes].view(dtype).reshape(shape)

    def release(self, arr: np.ndarray) -> None:
        base = arr
        while base.base is not None:
            base = base.base
        if not isinstance(base, np.ndarray) or base.dtype != np.uint8:
            self.stats.frees += 1
            return
        if self.enabled:
            with self._lock:
                self._free.setdefault(base.nbytes, []).append(base)
        else:
            self.stats.frees += 1

    def stage(self, src: np.ndarray) -> np.ndarray:
        """Copy ``src`` into a pooled buffer (the marshalling path)."""
        dst = self.acquire(src.shape, src.dtype)
        np.copyto(dst, src)
        self.stats.memcpy_calls += 1
        self.stats.memcpy_bytes += src.nbytes
        return dst


@dataclass
class TransportStats:
    zero_copies: int = 0
    staged_copies: int = 0
    staged_bytes: int = 0


class SharedBufferTransport:
    """Inter-worker tensor hand-off: zero-copy when enabled, staged copy
    through the pool otherwise (the paper's pre-DMA-BUF baseline)."""

    def __init__(self, pool: TensorPool, zero_copy: bool = True):
        self.pool = pool
        self.zero_copy = zero_copy
        self.stats = TransportStats()

    def transfer(self, tensor) -> object:
        if self.zero_copy:
            self.stats.zero_copies += 1
            return tensor            # same backing store crosses the boundary
        arr = np.asarray(tensor)
        out = self.pool.stage(arr)
        self.stats.staged_copies += 1
        self.stats.staged_bytes += arr.nbytes
        return out
