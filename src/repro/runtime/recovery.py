"""Recovery policies: graceful degradation under injected faults.

The fault layer (:mod:`repro.core.faults`) is deliberately recovery-free —
the four engine tiers realize faults identically so the parity oracle
stays bit-exact. This module is the *policy* layer on top: what the
runtime does about a fault once it happens.

Two mechanisms, both bounded and deterministic under the virtual clock:

* **timeout + retry-and-backoff** — a delivered task whose (faulted)
  service time exceeds ``timeout_factor ×`` its clean estimate is aborted
  at the timeout and re-delivered after ``backoff`` seconds, up to
  ``max_retries`` times; a retry re-samples the noise and fault streams,
  so a straggler draw usually clears. Exhausted retries run the task to
  completion rather than failing the request — recovery degrades
  gracefully, it never drops work the fault itself would not have dropped.
  Stall time from a dropout is *excluded* from the timeout check: retrying
  into a dead processor cannot help, the remap below can.
* **dropout → fallback remap** — at a *permanent* dropout the runtime
  re-routes every subgraph placed on the dead processor to a backup
  placement (precomputed via
  ``StaticAnalyzer.backup_mapping`` — the next-best placement excluding
  that processor — or the greedy least-loaded fallback here), drains the
  dead worker's queue into the new placement, and re-issues any task that
  was stalled in flight. In-flight requests survive: their already-running
  tasks complete (the model is non-preemptive) and their remaining tasks
  follow the new placement.

Recovery runs are *not* bit-comparable to the simulator tiers (they
consume extra stream draws and change placements mid-run); parity-oracle
runs always use ``recovery=None``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple


@dataclass(frozen=True)
class RecoveryPolicy:
    """Knobs for the runtime's fault-recovery behaviour.

    ``timeout_factor`` scales each subgraph's *clean* service time
    (exec + quant + comm from the cost source) into its per-task execution
    timeout, floored at ``min_timeout`` so tiny subgraphs are not retried
    on scheduling jitter. ``backoff`` is the delay before each re-delivery,
    multiplied by the attempt number (linear backoff). ``remap`` gates the
    dropout → backup-mapping re-route.
    """

    max_retries: int = 2
    backoff: float = 0.0005
    timeout_factor: float = 8.0
    min_timeout: float = 0.002
    remap: bool = True

    def timeout_for(self, clean_total: float) -> float:
        """Per-task execution timeout for a clean service-time estimate."""
        t = self.timeout_factor * clean_total
        return t if t > self.min_timeout else self.min_timeout


def greedy_remap(
    placed: Sequence[Sequence[object]],
    dead_pid: int,
    survivor_pids: Sequence[int],
    load: Optional[Dict[int, float]] = None,
) -> Dict[Tuple[int, int], int]:
    """Fallback backup mapping: move each dead-processor subgraph to the
    least-loaded survivor (deterministic: ties break on pid).

    ``load`` seeds the per-survivor load estimate (e.g. current busy
    times); each assignment adds the subgraph's weight so consecutive
    moves spread. Returns ``(net, k) -> new_pid`` for exactly the
    subgraphs owned by ``dead_pid``. Prefer
    ``StaticAnalyzer.backup_mapping`` when a profiler is available — it
    picks per-subgraph fastest survivors instead of balancing blindly.
    """
    if not survivor_pids:
        raise ValueError("no surviving processors to remap onto")
    est: Dict[int, float] = {pid: 0.0 for pid in survivor_pids}
    if load:
        for pid, v in load.items():
            if pid in est:
                est[pid] = float(v)
    remap: Dict[Tuple[int, int], int] = {}
    for net, plist in enumerate(placed):
        for k, p in enumerate(plist):
            if p.processor != dead_pid:
                continue
            target = min(est, key=lambda pid: (est[pid], pid))
            remap[(net, k)] = target
            # weight by layer count: a cheap, profiler-free size proxy
            est[target] += float(len(p.subgraph.layer_ids))
    return remap


@dataclass
class RecoveryEvent:
    """One recovery action taken by the runtime (for reports/benchmarks)."""

    kind: str            # "remap" | "retry"
    time: float
    pid: int             # dead pid (remap) / executing pid (retry)
    detail: Dict[str, object]

    def to_json(self) -> Dict[str, object]:
        return {"kind": self.kind, "time": self.time, "pid": self.pid,
                **self.detail}
