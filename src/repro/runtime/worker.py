"""Worker: one per processor, non-preemptive execution (paper §5.1).

Each Worker owns a priority task queue and two threads: a (de)quantization
thread and an execution thread, connected by an internal queue — so
dequantization of the next task overlaps execution of the current one,
exactly the two-thread design in Fig. 9.
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .engine import Engine, make_engine
from .tensorpool import SharedBufferTransport, TensorPool


@dataclass(order=True)
class WorkerTask:
    priority: Tuple
    payload: Any = field(compare=False)


_DTYPE_NP = {"fp32": np.float32, "fp16": np.float32, "int8": np.float32}


class Worker:
    """Dedicated executor for one processor id."""

    def __init__(
        self,
        pid: int,
        name: str,
        engines: Dict[str, Engine],
        pool: TensorPool,
        transport: SharedBufferTransport,
        on_done: Callable[[Any, Any, float, float], None],
    ):
        self.pid = pid
        self.name = name
        self.engines = engines
        self.pool = pool
        self.transport = transport
        self.on_done = on_done
        self._queue: "queue.PriorityQueue[Optional[WorkerTask]]" = queue.PriorityQueue()
        self._exec_queue: "queue.Queue[Optional[Tuple]]" = queue.Queue(maxsize=4)
        self._quant_thread = threading.Thread(target=self._quant_loop, daemon=True)
        self._exec_thread = threading.Thread(target=self._exec_loop, daemon=True)
        self.busy_time = 0.0
        self.tasks_done = 0
        self._stop = False

    def start(self) -> None:
        self._quant_thread.start()
        self._exec_thread.start()

    def submit(self, priority: Tuple, payload: Any) -> None:
        self._queue.put(WorkerTask(priority, payload))

    def stop(self) -> None:
        self._stop = True
        self._queue.put(None)

    # -- dequant/staging thread ---------------------------------------------
    def _quant_loop(self) -> None:
        while True:
            task = self._queue.get()
            if task is None:
                self._exec_queue.put(None)
                return
            payload = task.payload
            t0 = time.perf_counter()
            inputs = payload.get("inputs")
            prepared = []
            if inputs is not None:
                for tensor, src_dtype in inputs:
                    # dtype boundary: (de)quantize = convert through a pooled
                    # staging buffer (mirrors the Worker dequant path)
                    want = payload["dtype"]
                    if src_dtype != want:
                        arr = np.asarray(tensor, dtype=_DTYPE_NP[want])
                        arr = self.pool.stage(arr)
                        prepared.append(arr)
                    else:
                        prepared.append(self.transport.transfer(tensor))
            quant_t = time.perf_counter() - t0
            self._exec_queue.put((payload, prepared, quant_t))

    # -- execution thread -----------------------------------------------------
    def _exec_loop(self) -> None:
        while True:
            item = self._exec_queue.get()
            if item is None:
                return
            payload, prepared, quant_t = item
            engine: Engine = self.engines[payload["backend"]]
            t0 = time.perf_counter()
            try:
                out = engine.execute(payload["engine_key"],
                                     prepared if prepared else None)
                err = None
            except Exception as e:  # surface, don't kill the worker
                out, err = None, e
            exec_t = time.perf_counter() - t0
            # staged input buffers are consumed by the engine call — return
            # them to the pool (the Tensor Pool recycling path, §5.3)
            for arr in prepared:
                if isinstance(arr, np.ndarray):
                    self.pool.release(arr)
            self.busy_time += exec_t + quant_t
            self.tasks_done += 1
            self.on_done(payload, out if err is None else err, quant_t, exec_t)
