"""Worker: one per processor, non-preemptive execution (paper §5.1).

Each Worker owns a priority task queue. In real-execution mode it runs two
threads: a (de)quantization thread and an execution thread, connected by an
internal queue — so dequantization of the next task overlaps execution of
the current one, exactly the two-thread design in Fig. 9.

In **virtual-clock mode** (``cost_source`` given) the Worker spawns no
threads at all: it keeps a priority heap of waiting items and cooperates
with a :class:`~repro.runtime.clock.VirtualClock` — a submitted task is
*delivered* (costs charged, noise drawn) and *ended* (dependents resolved)
through scheduled events, reproducing the simulator's
deliver/end event structure one-to-one. This makes a runtime execution a
deterministic, instant replay whose task trace is bit-comparable to
:class:`~repro.core.fastsim.FastSimulator`.
"""
from __future__ import annotations

import heapq
import math
import queue
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .clock import SimCostSource, WallClock
from .engine import Engine
from .recovery import RecoveryPolicy
from .tensorpool import SharedBufferTransport, TensorPool


class WorkerExecutionError(RuntimeError):
    """A task failed inside a Worker thread (staging or execution).

    Carries enough context — subgraph, processor, backend, original
    exception — for the client to tell *which placement* broke. Raised into
    the owning request's future only; the worker threads keep serving."""


@dataclass(order=True)
class WorkerTask:
    priority: Tuple
    payload: Any = field(compare=False)


_DTYPE_NP = {"fp32": np.float32, "fp16": np.float32, "int8": np.float32}

#: Stop sentinel. Its priority ``(-2,)`` sorts below every real key — task
#: keys are ``(0, prio, seq)`` and dispatch tokens ``(-1, 0, seq)`` — so a
#: stop request jumps the queue even when tasks are still pending (the
#: abandoned-mid-request case). Putting a bare ``None`` into the
#: PriorityQueue, as the old code did, raised ``TypeError`` as soon as the
#: queue was non-empty (``None`` is unorderable against ``WorkerTask``),
#: leaking both threads forever.
_STOP = object()

#: Virtual-mode dispatch token: the Coordinator's per-release dispatch work
#: occupying the dispatch processor (paper §6.3), mirroring the simulators'
#: sentinel store item.
DISPATCH_TOKEN = ("dispatch",)


class Worker:
    """Dedicated executor for one processor id."""

    def __init__(
        self,
        pid: int,
        name: str,
        engines: Dict[str, Engine],
        pool: TensorPool,
        transport: SharedBufferTransport,
        on_done: Callable[[Any, Any, float, float], None],
        clock=None,
        cost_source: Optional[SimCostSource] = None,
        on_start: Optional[Callable[[Any], None]] = None,
        recovery: Optional[RecoveryPolicy] = None,
        on_stalled: Optional[Callable[[int, Any], None]] = None,
        on_recovery: Optional[Callable[[str, int, Dict], None]] = None,
    ):
        self.pid = pid
        self.name = name
        self.engines = engines
        self.pool = pool
        self.transport = transport
        self.on_done = on_done
        self.on_start = on_start
        # virtual-mode recovery: policy knobs + runtime hooks (None = serve
        # faults raw, the parity-oracle setting)
        self.recovery = recovery
        self.on_stalled = on_stalled
        self.on_recovery = on_recovery
        self.clock = clock if clock is not None else WallClock()
        self.cost_source = cost_source
        self.virtual = cost_source is not None
        self._queue: "queue.PriorityQueue[WorkerTask]" = queue.PriorityQueue()
        self._exec_queue: "queue.Queue[Optional[Tuple]]" = queue.Queue(maxsize=4)
        self._quant_thread = threading.Thread(target=self._quant_loop, daemon=True)
        self._exec_thread = threading.Thread(target=self._exec_loop, daemon=True)
        self.busy_time = 0.0
        self.tasks_done = 0
        self._stop = False
        # virtual-mode state: waiting-item heap + idle flag, exactly the
        # simulator's per-processor store
        self._vstore: List[Tuple[Tuple, Any]] = []
        self._vidle = True

    def start(self) -> None:
        if self.virtual:
            return  # no threads: the VirtualClock drives everything
        self._quant_thread.start()
        self._exec_thread.start()

    def submit(self, priority: Tuple, payload: Any) -> None:
        if self.virtual:
            if self._vidle:
                self._vidle = False
                self.clock.schedule(0.0, lambda: self._vdeliver(payload))
            else:
                heapq.heappush(self._vstore, (priority, payload))
            return
        self._queue.put(WorkerTask(priority, payload))

    def stop(self, join: bool = True, timeout: float = 10.0) -> None:
        """Stop the worker; with ``join`` (default) wait for both threads.

        Safe to call with tasks still queued (the stop sentinel outranks
        them) and idempotent. After a joined stop no worker thread is alive
        and both queues are drained.
        """
        if self.virtual:
            self._stop = True
            self._vstore.clear()  # drop waiting items: the clock is done
            return
        if not self._stop:
            self._stop = True
            self._queue.put(WorkerTask((-2,), _STOP))
        if join:
            for t in (self._quant_thread, self._exec_thread):
                if t.ident is not None:
                    t.join(timeout)
            self._drain()

    def threads_alive(self) -> bool:
        return self._quant_thread.is_alive() or self._exec_thread.is_alive()

    def _drain(self) -> None:
        for q in (self._queue, self._exec_queue):
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break

    # -- virtual-clock execution ----------------------------------------------
    def _vdeliver(self, payload: Any) -> None:
        """Task delivery event: charge costs, draw noise, schedule the end.

        Mirrors the simulator's DELIVER event byte for byte: the noise draw
        happens here (global delivery order), ``busy_time`` accrues the full
        service time up front, and the end event fires at ``now + total``
        with ``total = exec + quant + comm`` in that association.
        """
        src = self.cost_source
        if payload is DISPATCH_TOKEN:
            ov = src.dispatch_overhead
            self.busy_time += ov
            self.clock.schedule(ov, self._vpull)
            return
        comm, quant, exec_t = src.costs(payload["net"], payload["sg"])
        clean_total = exec_t + quant + comm  # pre-noise, pre-fault estimate
        exec_t = src.noisy_exec(self.pid, exec_t)
        stall = 0.0
        if src.fault_stream is not None:
            exec_t, stall = src.fault_stream.service(
                self.pid, self.clock.now(), exec_t)
        pol = self.recovery
        if pol is not None and math.isinf(stall) and self.on_stalled is not None:
            # delivered onto a permanently-dead processor with recovery on:
            # hand the task back for re-routing instead of stalling forever,
            # then keep draining the queue (the reroute cannot come back —
            # the runtime rewires the placement before redispatching)
            self.on_stalled(self.pid, payload)
            self._vpull()
            return
        payload["started"] = self.clock.now()
        payload["comm_s"] = comm
        payload["quant_s"] = quant
        payload["exec_s"] = exec_t
        if self.on_start is not None:
            self.on_start(payload)
        total = exec_t + quant + comm
        if stall > 0.0:
            # delivered to a dropped processor: stall until the repair (an
            # end event at t=inf never fires — same drop semantics as the
            # simulator tiers)
            payload["stall_s"] = stall
            total = stall + total
        if pol is not None and stall == 0.0:
            # straggler watchdog — stall time is excluded: retrying into a
            # dead/throttled-window processor cannot help, the remap can
            timeout_s = pol.timeout_for(clean_total)
            attempts = payload.get("attempts", 0)
            if total > timeout_s and attempts < pol.max_retries:
                # abandon the attempt at the timeout, re-deliver after a
                # linear backoff; the retry re-draws the noise and fault
                # streams (recovery runs are not parity-compared)
                payload["attempts"] = attempts + 1
                self.busy_time += timeout_s
                if self.on_recovery is not None:
                    self.on_recovery("retry", self.pid, {
                        "net": payload["net"], "sg": payload["sg"],
                        "request": payload["request"],
                        "attempt": attempts + 1,
                        "timeout_s": timeout_s, "total_s": total,
                    })
                self.clock.schedule(timeout_s + pol.backoff * (attempts + 1),
                                    lambda: self._vdeliver(payload))
                return
        if not math.isinf(total):
            self.busy_time += total
        self.clock.schedule(total, lambda: self._vend(payload))

    def _vend(self, payload: Any) -> None:
        """Task end event: resolve dependents, then pull the next item."""
        self.tasks_done += 1
        # the Coordinator releases ready successors *before* this worker
        # pulls its next item — same order as the simulator's END event
        self.on_done(payload, None, payload["quant_s"], payload["exec_s"])
        self._vpull()

    def _vpull(self) -> None:
        if self._vstore:
            _, payload = heapq.heappop(self._vstore)
            self.clock.schedule(0.0, lambda: self._vdeliver(payload))
        else:
            self._vidle = True

    def _wrap_error(self, payload: Any, stage: str,
                    e: Exception) -> WorkerExecutionError:
        return WorkerExecutionError(
            f"{stage} failed for subgraph (net={payload.get('net')}, "
            f"sg={payload.get('sg')}) on processor {self.pid} ({self.name}), "
            f"backend={payload.get('backend')!r}: {type(e).__name__}: {e}")

    # -- dequant/staging thread ---------------------------------------------
    def _quant_loop(self) -> None:
        while True:
            task = self._queue.get()
            if task.payload is _STOP:
                self._exec_queue.put(None)
                return
            payload = task.payload
            t0 = self.clock.now()
            inputs = payload.get("inputs")
            prepared: List = []
            err: Optional[Exception] = None
            try:
                if inputs is not None:
                    for tensor, src_dtype in inputs:
                        # dtype boundary: (de)quantize = convert through a
                        # pooled staging buffer (the Worker dequant path)
                        want = payload["dtype"]
                        if src_dtype != want:
                            arr = np.asarray(tensor, dtype=_DTYPE_NP[want])
                            arr = self.pool.stage(arr)
                            prepared.append(arr)
                        else:
                            prepared.append(self.transport.transfer(tensor))
            except Exception as e:  # fail the request, not the thread
                err = self._wrap_error(payload, "input staging", e)
            quant_t = self.clock.now() - t0
            self._exec_queue.put((payload, prepared, quant_t, err))

    # -- execution thread -----------------------------------------------------
    def _exec_loop(self) -> None:
        while True:
            item = self._exec_queue.get()
            if item is None:
                return
            payload, prepared, quant_t, err = item
            t0 = self.clock.now()
            payload["started"] = t0
            if self.on_start is not None:
                self.on_start(payload)
            out = None
            if err is None:
                try:
                    # the engine lookup lives *inside* the try: an unknown
                    # backend key must fail the request, not kill this
                    # thread and strand the coordinator
                    engine: Engine = self.engines[payload["backend"]]
                    out = engine.execute(payload["engine_key"],
                                         prepared if prepared else None)
                except Exception as e:  # surface, don't kill the worker
                    err = self._wrap_error(payload, "execution", e)
            exec_t = self.clock.now() - t0
            # staged input buffers are consumed by the engine call — return
            # them to the pool (the Tensor Pool recycling path, §5.3)
            for arr in prepared:
                if isinstance(arr, np.ndarray):
                    self.pool.release(arr)
            self.busy_time += exec_t + quant_t
            self.tasks_done += 1
            payload["quant_s"] = quant_t
            payload["exec_s"] = exec_t
            self.on_done(payload, out if err is None else err, quant_t, exec_t)
