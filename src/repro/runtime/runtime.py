"""PuzzleRuntime: user-facing assembly of Coordinator + Workers + Engines
(paper §5), with the Tensor Pool and Zero-Copy Shared Buffer optimizations
toggleable for the §5.3 ablation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

from ..core.chromosome import PlacedSubgraph, Solution, decode_solution
from ..core.graph import ModelGraph
from ..core.processors import Processor
from .coordinator import Coordinator, RequestState
from .engine import ENGINE_REGISTRY, make_engine
from .tensorpool import SharedBufferTransport, TensorPool
from .worker import Worker


@dataclass
class RuntimeConfig:
    tensor_pool: bool = True
    shared_buffer: bool = True


class PuzzleRuntime:
    """Executes a Static Analyzer solution against real (reduced) models."""

    def __init__(
        self,
        graphs: Sequence[ModelGraph],
        solution: Solution,
        processors: Sequence[Processor],
        executables: Dict[str, Any],
        config: Optional[RuntimeConfig] = None,
    ):
        self.cfg = config or RuntimeConfig()
        self.placed = decode_solution(solution, graphs)
        self.pool = TensorPool(enabled=self.cfg.tensor_pool)
        self.transport = SharedBufferTransport(
            self.pool, zero_copy=self.cfg.shared_buffer
        )
        self.workers: Dict[int, Worker] = {}
        self._coordinator: Optional[Coordinator] = None

        def on_done(payload, result, quant_t, exec_t):
            assert self._coordinator is not None
            self._coordinator.on_task_done(payload, result, quant_t, exec_t)

        for proc in processors:
            engines = {name: make_engine(name) for name in ENGINE_REGISTRY}
            self.workers[proc.pid] = Worker(
                proc.pid, proc.name, engines, self.pool, self.transport, on_done
            )
        self._coordinator = Coordinator(self.placed, self.workers, executables)
        for w in self.workers.values():
            w.start()

    # -- serving ------------------------------------------------------------
    def infer(self, networks: Sequence[int], group: int = 0) -> RequestState:
        return self._coordinator.submit(networks, group)

    def infer_sync(self, networks: Sequence[int], timeout: float = 60.0
                   ) -> RequestState:
        st = self.infer(networks)
        return st.future.result(timeout=timeout)

    def run_periodic(
        self,
        groups: Sequence[Sequence[int]],
        periods: Sequence[float],
        num_requests: int = 10,
        timeout: float = 120.0,
    ) -> List[List[RequestState]]:
        """Drive periodic requests per model group; returns states per group."""
        states: List[List[RequestState]] = [[] for _ in groups]
        t0 = time.perf_counter()
        issued = [0] * len(groups)
        total = num_requests * len(groups)
        while sum(issued) < total:
            now = time.perf_counter() - t0
            soonest = None
            for g, period in enumerate(periods):
                if issued[g] >= num_requests:
                    continue
                due = issued[g] * period
                if due <= now:
                    states[g].append(self.infer(groups[g], group=g))
                    issued[g] += 1
                else:
                    soonest = min(soonest, due) if soonest is not None else due
            if soonest is not None:
                sleep = soonest - (time.perf_counter() - t0)
                if sleep > 0:
                    time.sleep(min(sleep, 0.01))
        deadline = time.perf_counter() + timeout
        for glist in states:
            for st in glist:
                st.future.result(timeout=max(0.1, deadline - time.perf_counter()))
        return states

    def stats(self) -> Dict[str, Any]:
        return {
            "pool": self.pool.stats.__dict__,
            "transport": self.transport.stats.__dict__,
            "workers": {
                pid: {"busy_s": w.busy_time, "tasks": w.tasks_done}
                for pid, w in self.workers.items()
            },
        }

    def close(self) -> None:
        for w in self.workers.values():
            w.stop()
