"""PuzzleRuntime: user-facing assembly of Coordinator + Workers + Engines
(paper §5), with the Tensor Pool and Zero-Copy Shared Buffer optimizations
toggleable for the §5.3 ablation.

Two execution modes:

* **real** (default) — threads + genuine JAX execution of the executable
  zoo models, wall-clock timestamps. Engines record per-Merkle-key
  execution times; :meth:`PuzzleRuntime.measured_costs` aggregates them
  into device-in-the-loop measurements for the ProfileDB feedback loop.
* **virtual** (``RuntimeConfig(virtual=True)`` + a ``FastSimSpec``) — no
  threads, no execution: a :class:`~repro.runtime.clock.VirtualClock`
  drives the very same Coordinator/Worker dispatch logic over the spec's
  cost arrays, so a run is a deterministic, instant replay whose task
  trace is bit-comparable to :class:`~repro.core.fastsim.FastSimulator`
  (the runtime↔simulator conformance tier).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from ..core.arrivals import ArrivalSpec, arrival_horizon, draw_arrivals
from ..core.chromosome import Solution, decode_solution
from ..core.fastsim import FastSimSpec
from ..core.faults import FaultSpec
from ..core.graph import ModelGraph
from ..core.processors import Processor
from ..core.simulator import NoiseModel
from .clock import SimCostSource, VirtualClock, WallClock
from .coordinator import Coordinator, RequestState
from .engine import ENGINE_REGISTRY, make_engine
from .recovery import RecoveryEvent, greedy_remap
from .tensorpool import SharedBufferTransport, TensorPool
from .worker import DISPATCH_TOKEN, Worker


@dataclass
class RuntimeConfig:
    tensor_pool: bool = True
    shared_buffer: bool = True
    # virtual-clock (conformance) mode: replay FastSimSpec costs on an event
    # clock instead of sleeping/executing. The noise/dispatch knobs mirror
    # the simulators' measured-evaluation parameters.
    virtual: bool = False
    noise: Optional[NoiseModel] = None
    dispatch_overhead: float = 0.0
    dispatch_pid: int = 0
    # fault ensemble injected at task delivery (virtual mode), realized by
    # the same shared FaultStream as the three simulator tiers
    faults: Optional[FaultSpec] = None
    # recovery policy: None = serve faults raw (the parity-oracle setting);
    # a RecoveryPolicy enables timeout/retry and the dropout → backup remap
    recovery: Optional["RecoveryPolicy"] = None


class PuzzleRuntime:
    """Executes a Static Analyzer solution against real (reduced) models."""

    def __init__(
        self,
        graphs: Sequence[ModelGraph],
        solution: Solution,
        processors: Sequence[Processor],
        executables: Optional[Dict[str, Any]] = None,
        config: Optional[RuntimeConfig] = None,
        spec: Optional[FastSimSpec] = None,
    ):
        self.cfg = config or RuntimeConfig()
        if self.cfg.virtual and spec is None:
            raise ValueError("virtual-clock mode needs a FastSimSpec "
                             "(the cost source)")
        self.placed = decode_solution(solution, graphs)
        self.spec = spec
        self.clock = VirtualClock() if self.cfg.virtual else WallClock()
        self.pool = TensorPool(enabled=self.cfg.tensor_pool)
        self.transport = SharedBufferTransport(
            self.pool, zero_copy=self.cfg.shared_buffer
        )
        self.workers: Dict[int, Worker] = {}
        self._coordinator: Optional[Coordinator] = None
        self._closed = False
        # recovery bookkeeping (virtual mode): actions taken, dead pids,
        # optional precomputed backups per dead pid
        self.recovery_events: List[RecoveryEvent] = []
        self.measured_cost_skips = 0
        self._dead: Set[int] = set()
        self._backups: Dict[int, Tuple[Dict[Tuple[int, int], int],
                                       Optional[FastSimSpec]]] = {}

        cost_source = None
        if self.cfg.virtual:
            cost_source = SimCostSource(
                spec, processors, noise=self.cfg.noise,
                dispatch_overhead=self.cfg.dispatch_overhead,
                faults=self.cfg.faults,
            )
        self._cost_source = cost_source
        recovering = (self.cfg.virtual and self.cfg.recovery is not None)
        remapping = (recovering and self.cfg.recovery.remap
                     and cost_source.faults is not None)

        def on_done(payload, result, quant_t, exec_t):
            assert self._coordinator is not None
            self._coordinator.on_task_done(payload, result, quant_t, exec_t)

        def on_start(payload):
            assert self._coordinator is not None
            self._coordinator.on_task_start(payload)

        for proc in processors:
            engines = {name: make_engine(name) for name in ENGINE_REGISTRY}
            self.workers[proc.pid] = Worker(
                proc.pid, proc.name, engines, self.pool, self.transport,
                on_done, clock=self.clock, cost_source=cost_source,
                on_start=on_start,
                recovery=self.cfg.recovery if recovering else None,
                on_stalled=self._on_stalled if remapping else None,
                on_recovery=self._record_recovery if recovering else None,
            )
        self._coordinator = Coordinator(
            self.placed, self.workers, executables or {},
            clock=self.clock, virtual=self.cfg.virtual,
            dispatch_overhead=self.cfg.dispatch_overhead,
            dispatch_pid=self.cfg.dispatch_pid,
        )
        if remapping:
            # scheduled at init ⇒ smallest heap sequence numbers: at the
            # dropout instant the remap fires *before* any same-time
            # delivery, so no task is handed to the dead worker afterwards
            for pid, start, end in cost_source.faults.dropouts:
                if end is None and pid in self.workers:
                    self.clock.schedule(start,
                                        lambda p=pid: self._on_dropout(p))
        for w in self.workers.values():
            w.start()

    @property
    def coordinator(self) -> Coordinator:
        return self._coordinator

    # -- serving ------------------------------------------------------------
    def infer(self, networks: Sequence[int], group: int = 0) -> RequestState:
        if self._closed:
            raise RuntimeError("PuzzleRuntime is closed")
        return self._coordinator.submit(networks, group)

    def infer_sync(self, networks: Sequence[int], timeout: float = 60.0
                   ) -> RequestState:
        st = self.infer(networks)
        if self.cfg.virtual:
            self.clock.run()  # drain the event heap; completes synchronously
            return st.future.result(timeout=0)
        return st.future.result(timeout=timeout)

    def run_periodic(
        self,
        groups: Sequence[Sequence[int]],
        periods: Sequence[float],
        num_requests: int = 10,
        timeout: float = 120.0,
        arrivals: Optional[ArrivalSpec] = None,
    ) -> List[List[RequestState]]:
        """Drive the request sources per model group; returns states per group.

        ``arrivals`` selects the arrival process (``None`` = periodic, the
        paper's sources); all processes draw their timestamps from the
        shared :func:`~repro.core.arrivals.draw_arrivals` generator.
        Virtual mode reproduces the simulators' request sources exactly —
        group sources fire at the drawn arrival times on the event clock
        and the run stops at the same quiescence horizon, so overloaded
        schedules drop the same requests the simulator drops (``makespan
        is None``).
        """
        if self.cfg.virtual:
            return self._run_sources_virtual(
                groups, periods, num_requests, arrivals)
        tables = draw_arrivals(arrivals, periods, num_requests)
        states: List[List[RequestState]] = [[] for _ in groups]
        t0 = time.perf_counter()
        issued = [0] * len(groups)
        total = num_requests * len(groups)
        while sum(issued) < total:
            now = time.perf_counter() - t0
            soonest = None
            for g in range(len(groups)):
                if issued[g] >= num_requests:
                    continue
                due = tables[g][issued[g]]
                if due <= now:
                    states[g].append(self.infer(groups[g], group=g))
                    issued[g] += 1
                else:
                    soonest = min(soonest, due) if soonest is not None else due
            if soonest is not None:
                sleep = soonest - (time.perf_counter() - t0)
                if sleep > 0:
                    time.sleep(min(sleep, 0.01))
        deadline = time.perf_counter() + timeout
        for glist in states:
            for st in glist:
                st.future.result(timeout=max(0.1, deadline - time.perf_counter()))
        return states

    def _run_sources_virtual(
        self,
        groups: Sequence[Sequence[int]],
        periods: Sequence[float],
        num_requests: int,
        arrivals: Optional[ArrivalSpec] = None,
    ) -> List[List[RequestState]]:
        states: List[List[RequestState]] = [[] for _ in groups]
        clock = self.clock
        tables = draw_arrivals(arrivals, periods, num_requests)

        def make_source(gid: int, rid: int):
            def fire() -> None:
                states[gid].append(self.infer(groups[gid], group=gid))
                if rid + 1 < num_requests:
                    arrival = tables[gid][rid + 1]
                    # same float expression as the simulators' timeout
                    # (`now + (arrival - now)`), keeping tie-breaks identical
                    clock.schedule(arrival - clock.now(),
                                   make_source(gid, rid + 1))
            return fire

        def make_init(gid: int):
            # fires at t=0 like the simulators' source inits; a non-zero
            # first arrival schedules a timeout (same heap-sequence order),
            # a zero one issues synchronously
            def init() -> None:
                first = tables[gid][0]
                if first > clock.now():
                    clock.schedule(first - clock.now(), make_source(gid, 0))
                else:
                    make_source(gid, 0)()
            return init

        for gid in range(len(groups)):
            clock.schedule(0.0, make_init(gid))
        horizon = arrival_horizon(tables, periods, num_requests)
        clock.run(until=horizon)
        return states

    @staticmethod
    def sim_horizon(
        periods: Sequence[float],
        num_requests: int,
        arrivals: Optional[ArrivalSpec] = None,
    ) -> float:
        """The simulators' quiescence horizon, verbatim (arrival-aware)."""
        return arrival_horizon(
            draw_arrivals(arrivals, periods, num_requests),
            periods, num_requests)

    # -- fault recovery (virtual mode) --------------------------------------
    def set_backup(
        self,
        dead_pid: int,
        remap: Dict[Tuple[int, int], int],
        spec: Optional[FastSimSpec] = None,
    ) -> None:
        """Register a precomputed fallback for ``dead_pid``'s dropout.

        ``remap`` maps each ``(net, k)`` placed on ``dead_pid`` to its
        backup processor (``StaticAnalyzer.backup_mapping`` output — the
        next-best placement excluding that processor). ``spec``, when
        given, must be the backup solution's FastSimSpec: it shares the
        partition, so its rows override the primary costs for exactly the
        remapped subgraphs. Without a registered backup the runtime falls
        back to :func:`~repro.runtime.recovery.greedy_remap`.
        """
        bad = [pid for pid in remap.values() if pid == dead_pid]
        if bad:
            raise ValueError(f"backup remap routes back onto dead pid "
                             f"{dead_pid}")
        self._backups[dead_pid] = (dict(remap), spec)

    def _record_recovery(self, kind: str, pid: int, detail: Dict) -> None:
        self.recovery_events.append(RecoveryEvent(
            kind=kind, time=self.clock.now(), pid=pid, detail=detail))

    def _on_dropout(self, pid: int) -> None:
        """Permanent-dropout handler: rewire placement, drain the dead queue.

        Idempotent. Re-places every subgraph owned by ``pid`` onto its
        backup processor (registered via :meth:`set_backup`, else greedy
        least-loaded), installs backup cost overrides when available, and
        redispatches the dead worker's waiting tasks through the new
        placement — in-flight requests keep running, nothing is dropped.
        A task already *executing* on ``pid`` completes (non-preemptive
        model); only queued and future work moves.
        """
        if pid in self._dead:
            return
        self._dead.add(pid)
        survivors = [q for q in self.workers if q != pid
                     and q not in self._dead]
        if not survivors:
            return  # nothing to remap onto; pid's requests will drop
        backup = self._backups.get(pid)
        if backup is not None:
            remap, bspec = backup
        else:
            load = {q: self.workers[q].busy_time for q in survivors}
            remap = greedy_remap(self.placed, pid, survivors, load=load)
            bspec = None
        for (net, k), new_pid in remap.items():
            p = self.placed[net][k]
            self.placed[net][k] = dataclasses.replace(p, processor=new_pid)
        if bspec is not None and self._cost_source is not None:
            for (net, k) in remap:
                g = bspec.offsets[net] + k
                self._cost_source.override[g] = (
                    bspec.comm[g], bspec.quant[g], bspec.exec_[g])
        moved = 0
        dead_w = self.workers[pid]
        while dead_w._vstore:
            _, payload = heapq.heappop(dead_w._vstore)
            if payload is DISPATCH_TOKEN:
                continue  # coordinator work, not tied to the dead processor
            self._coordinator.redispatch(payload)
            moved += 1
        self._record_recovery("remap", pid, {
            "subgraphs": len(remap), "requeued": moved,
            "backup": "registered" if backup is not None else "greedy",
        })

    def _on_stalled(self, pid: int, payload: Dict) -> None:
        """Worker hook: a task was delivered onto a permanently-dead pid.

        Belt-and-braces behind :meth:`_on_dropout` (which normally fires
        first and leaves nothing to stall): make sure the placement is
        rewired, then re-route the task. If no survivor exists the task is
        abandoned — the request drops exactly as the raw fault tiers drop
        it, instead of looping on the dead worker.
        """
        self._on_dropout(pid)
        if self.placed[payload["net"]][payload["sg"]].processor == pid:
            return
        self._coordinator.redispatch(payload)

    # -- measurement --------------------------------------------------------
    def measured_costs(self) -> Dict[str, float]:
        """Measured execution time per Merkle profile key.

        Aggregated over every engine execution this runtime performed (all
        workers, all requests) — the device-in-the-loop measurements that
        feed back into the :class:`~repro.core.profiler.ProfileDB`. Per key
        the slowest sample is discarded when three or more exist (the first
        execution can pay a JIT recompilation for the staged input
        signature) and the lower median of the rest is taken — the paper's
        brief on-target execution medians repeats the same way. Empty in
        virtual mode (nothing is actually executed).

        Robust to partial measurement sets: keys whose sample lists are
        empty or carry only unusable values (non-finite or non-positive —
        a worker that died mid-run, or a request dropped by an injected
        fault, leaves such holes) are skipped instead of raising;
        ``self.measured_cost_skips`` counts them for conformance reports.
        """
        per_key: Dict[str, List[float]] = {}
        for w in self.workers.values():
            for eng in w.engines.values():
                for key, ts in eng.exec_times.items():
                    per_key.setdefault(key, []).extend(ts)
        out: Dict[str, float] = {}
        self.measured_cost_skips = 0
        for key, ts in per_key.items():
            ts = sorted(t for t in ts
                        if t is not None and math.isfinite(t) and t > 0.0)
            if not ts:
                self.measured_cost_skips += 1
                continue
            if len(ts) > 2:
                ts = ts[:-1]
            out[key] = ts[(len(ts) - 1) // 2]
        return out

    def stats(self) -> Dict[str, Any]:
        return {
            "pool": self.pool.stats.__dict__,
            "transport": self.transport.stats.__dict__,
            "workers": {
                pid: {"busy_s": w.busy_time, "tasks": w.tasks_done}
                for pid, w in self.workers.items()
            },
        }

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Stop and join worker threads, drain queues, fail pending futures.

        Idempotent; safe mid-request (the stop sentinel outranks queued
        tasks). After close no worker thread is alive and every unfinished
        request's future carries a ``RuntimeError``.
        """
        if self._closed:
            return
        self._closed = True
        for w in self.workers.values():
            w.stop(join=True)
        if self._coordinator is not None:
            reason = "PuzzleRuntime closed"
            faults = self.cfg.faults
            if faults is not None and not faults.empty and faults.dropouts:
                # name the injected fault so a pending future's error says
                # *why* the request never finished, not just that it didn't
                descr = ", ".join(
                    f"processor {pid} dropped at t={start:g}"
                    + ("" if end is None else f" (repaired at t={end:g})")
                    for pid, start, end in faults.dropouts)
                reason += f" with injected faults: {descr}"
            self._coordinator.cancel_pending(reason)

    def __enter__(self) -> "PuzzleRuntime":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
