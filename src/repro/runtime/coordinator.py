"""Coordinator: the Runtime's external interface (paper §5.2, Fig. 9).

Workflow: ① client request enters the queue → ② the coordinator finds
subgraphs with resolved dependencies → ③ tasks go to Worker queues →
④ Workers (de)quantize + execute → ⑤ results update request state →
⑥ the final result returns to the client (a Future).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.chromosome import PlacedSubgraph
from .worker import Worker


@dataclass
class RequestState:
    request_id: int
    group: int
    networks: List[int]
    submitted: float
    future: Future = field(default_factory=Future)
    remaining: int = 0
    outputs: Dict[Tuple[int, int], Any] = field(default_factory=dict)
    pending_deps: Dict[Tuple[int, int], int] = field(default_factory=dict)
    first_start: Optional[float] = None
    finish: Optional[float] = None
    task_records: List[Dict] = field(default_factory=list)

    @property
    def makespan(self) -> Optional[float]:
        if self.finish is None:
            return None
        return self.finish - self.submitted


class Coordinator:
    """Dependency-resolving dispatcher over per-processor Workers."""

    def __init__(
        self,
        placed: Sequence[Sequence[PlacedSubgraph]],
        workers: Dict[int, Worker],
        executables: Dict[str, Any],
    ):
        self.placed = placed
        self.workers = workers
        self.executables = executables
        self._lock = threading.Lock()
        self._requests: Dict[int, RequestState] = {}
        self._next_id = 0
        self._seq = 0
        # static dependency structure + engine pre-loading (Initialization)
        self._deps: List[List[List[int]]] = []
        self._succs: List[List[List[int]]] = []
        self._owner: List[Dict[int, int]] = []
        for plist in placed:
            owner: Dict[int, int] = {}
            for k, p in enumerate(plist):
                for lid in p.subgraph.layer_ids:
                    owner[lid] = k
            deps = [sorted({owner[e.src] for e in p.subgraph.in_cut_edges()})
                    for p in plist]
            succs: List[List[int]] = [[] for _ in plist]
            for k, d in enumerate(deps):
                for pr in d:
                    succs[pr].append(k)
            self._deps.append(deps)
            self._succs.append(succs)
            self._owner.append(owner)
        for plist in placed:
            for p in plist:
                w = workers[p.processor]
                eng = w.engines[p.backend]
                eng.load(p, executables)

    # -- client API ------------------------------------------------------------
    def submit(self, networks: Sequence[int], group: int = 0) -> RequestState:
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            st = RequestState(
                request_id=rid, group=group, networks=list(networks),
                submitted=time.perf_counter(),
            )
            st.remaining = sum(len(self.placed[n]) for n in networks)
            for n in networks:
                for k, d in enumerate(self._deps[n]):
                    st.pending_deps[(n, k)] = len(d)
            self._requests[rid] = st
        for n in networks:
            for k, d in enumerate(self._deps[n]):
                if not d:
                    self._dispatch(st, n, k)
        return st

    # -- internal -----------------------------------------------------------
    def _dispatch(self, st: RequestState, net: int, k: int) -> None:
        p = self.placed[net][k]
        inputs = None
        if self._deps[net][k]:
            inputs = []
            for pk in self._deps[net][k]:
                prod = self.placed[net][pk]
                out = st.outputs[(net, pk)]
                first = out[0] if isinstance(out, tuple) else out
                inputs.append((first, prod.dtype))
            # boundary inputs must match the subgraph arity; replicate the
            # producer output for multi-input boundaries
            model = self.executables[p.subgraph.graph.name]
            _, example = model.build_subgraph_fn(p.subgraph.layer_ids, p.dtype)
            while len(inputs) < len(example):
                inputs.append(inputs[-1])
            inputs = inputs[: len(example)]
        with self._lock:
            self._seq += 1
            seq = self._seq
        payload = {
            "request": st.request_id,
            "net": net,
            "sg": k,
            "dtype": p.dtype,
            "backend": p.backend,
            "engine_key": p.profile_key(),
            "inputs": inputs,
            "released": time.perf_counter(),
        }
        self.workers[p.processor].submit((p.priority, seq), payload)

    def on_task_done(self, payload: Dict, result: Any, quant_t: float,
                     exec_t: float) -> None:
        rid, net, k = payload["request"], payload["net"], payload["sg"]
        ready: List[Tuple[RequestState, int, int]] = []
        with self._lock:
            st = self._requests[rid]
            if isinstance(result, Exception):
                if not st.future.done():
                    st.future.set_exception(result)
                return
            now = time.perf_counter()
            if st.first_start is None:
                st.first_start = payload["released"]
            st.outputs[(net, k)] = result
            st.remaining -= 1
            st.task_records.append({
                "net": net, "sg": k, "quant_s": quant_t, "exec_s": exec_t,
                "wait_s": now - payload["released"] - exec_t - quant_t,
            })
            for s in self._succs[net][k]:
                st.pending_deps[(net, s)] -= 1
                if st.pending_deps[(net, s)] == 0:
                    ready.append((st, net, s))
            done = st.remaining == 0
            if done:
                st.finish = now
        for st2, n2, k2 in ready:
            self._dispatch(st2, n2, k2)
        if done and not st.future.done():
            st.future.set_result(st)
