"""Coordinator: the Runtime's external interface (paper §5.2, Fig. 9).

Workflow: ① client request enters the queue → ② the coordinator finds
subgraphs with resolved dependencies → ③ tasks go to Worker queues →
④ Workers (de)quantize + execute → ⑤ results update request state →
⑥ the final result returns to the client (a Future).

All timestamps come from an injectable clock (wall time by default, a
:class:`~repro.runtime.clock.VirtualClock` in conformance mode), and every
released task gets a :class:`~repro.core.simulator.TaskRecord` appended to
``self.trace`` in release order — the same schema and ordering the
simulators produce, so a runtime execution diffs directly against a
simulated one. In virtual mode the Coordinator also mirrors the
simulators' queueing keys exactly: tasks enter Worker stores with priority
``(0, network-priority, release-seq)`` and, when dispatch overhead is
modeled, a ``(-1, 0, release-seq)`` dispatch token is pushed to the
dispatch processor *before* each release (paper §6.3's Coordinator load).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.chromosome import PlacedSubgraph
from ..core.simulator import TaskRecord
from .clock import WallClock
from .worker import DISPATCH_TOKEN, Worker


@dataclass
class RequestState:
    request_id: int
    group: int
    networks: List[int]
    submitted: float
    future: Future = field(default_factory=Future)
    remaining: int = 0
    total_tasks: int = 0
    group_request: int = 0            # per-group request index (rid)
    outputs: Dict[Tuple[int, int], Any] = field(default_factory=dict)
    pending_deps: Dict[Tuple[int, int], int] = field(default_factory=dict)
    first_start: Optional[float] = None
    last_finish: float = 0.0
    finish: Optional[float] = None
    task_records: List[Dict] = field(default_factory=list)

    @property
    def done_tasks(self) -> int:
        return self.total_tasks - self.remaining

    @property
    def makespan(self) -> Optional[float]:
        if self.finish is None:
            return None
        return self.finish - self.submitted


class Coordinator:
    """Dependency-resolving dispatcher over per-processor Workers."""

    def __init__(
        self,
        placed: Sequence[Sequence[PlacedSubgraph]],
        workers: Dict[int, Worker],
        executables: Dict[str, Any],
        clock=None,
        virtual: bool = False,
        dispatch_overhead: float = 0.0,
        dispatch_pid: int = 0,
    ):
        self.placed = placed
        self.workers = workers
        self.executables = executables
        self.clock = clock if clock is not None else WallClock()
        self.virtual = virtual
        self.dispatch_overhead = dispatch_overhead
        self.dispatch_pid = dispatch_pid
        self._lock = threading.Lock()
        self._requests: Dict[int, RequestState] = {}
        self._next_id = 0
        self._seq = 0                      # release sequence (queue keys)
        self._group_counts: Dict[int, int] = {}
        self.trace: List[TaskRecord] = []  # all released tasks, release order
        # static dependency structure + engine pre-loading (Initialization)
        self._deps: List[List[List[int]]] = []
        self._succs: List[List[List[int]]] = []
        self._owner: List[Dict[int, int]] = []
        for plist in placed:
            owner: Dict[int, int] = {}
            for k, p in enumerate(plist):
                for lid in p.subgraph.layer_ids:
                    owner[lid] = k
            deps = [sorted({owner[e.src] for e in p.subgraph.in_cut_edges()})
                    for p in plist]
            succs: List[List[int]] = [[] for _ in plist]
            for k, d in enumerate(deps):
                for pr in d:
                    succs[pr].append(k)
            self._deps.append(deps)
            self._succs.append(succs)
            self._owner.append(owner)
        if not virtual:  # virtual mode replays costs; nothing to compile
            for plist in placed:
                for p in plist:
                    w = workers[p.processor]
                    eng = w.engines[p.backend]
                    eng.load(p, executables)

    # -- client API ------------------------------------------------------------
    def submit(self, networks: Sequence[int], group: int = 0) -> RequestState:
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            grid = self._group_counts.get(group, 0)
            self._group_counts[group] = grid + 1
            st = RequestState(
                request_id=rid, group=group, networks=list(networks),
                submitted=self.clock.now(), group_request=grid,
            )
            st.remaining = sum(len(self.placed[n]) for n in networks)
            st.total_tasks = st.remaining
            for n in networks:
                for k, d in enumerate(self._deps[n]):
                    st.pending_deps[(n, k)] = len(d)
            self._requests[rid] = st
        for n in networks:
            for k, d in enumerate(self._deps[n]):
                if not d:
                    self._dispatch(st, n, k)
        return st

    def redispatch(self, payload: Dict) -> int:
        """Re-route an already-released task through the *current* placement.

        The dropout-recovery path: after the runtime rewrites
        ``self.placed`` for a dead processor, tasks drained from that
        worker's queue (or intercepted mid-stall) re-enter here. The task
        keeps its identity — request, record, release timestamp — but its
        backend/dtype/engine key and target worker are re-read from the
        re-placed subgraph. Returns the new processor id.
        """
        net, k = payload["net"], payload["sg"]
        p = self.placed[net][k]
        payload["backend"] = p.backend
        payload["dtype"] = p.dtype
        payload["engine_key"] = p.profile_key()
        payload["record"].processor = p.processor
        with self._lock:
            self._seq += 1
            seq = self._seq
        self.workers[p.processor].submit((0, p.priority, seq), payload)
        return p.processor

    def cancel_pending(self, reason: str = "PuzzleRuntime closed") -> int:
        """Fail every unfinished request's future; returns how many."""
        cancelled = 0
        with self._lock:
            states = list(self._requests.values())
        for st in states:
            if not st.future.done():
                st.future.set_exception(RuntimeError(reason))
                cancelled += 1
        return cancelled

    # -- internal -----------------------------------------------------------
    def _dispatch(self, st: RequestState, net: int, k: int) -> None:
        p = self.placed[net][k]
        inputs = None
        if self._deps[net][k] and not self.virtual:
            inputs = []
            for pk in self._deps[net][k]:
                prod = self.placed[net][pk]
                out = st.outputs[(net, pk)]
                first = out[0] if isinstance(out, tuple) else out
                inputs.append((first, prod.dtype))
            # boundary inputs must match the subgraph arity; replicate the
            # producer output for multi-input boundaries
            model = self.executables[p.subgraph.graph.name]
            _, example = model.build_subgraph_fn(p.subgraph.layer_ids, p.dtype)
            while len(inputs) < len(example):
                inputs.append(inputs[-1])
            inputs = inputs[: len(example)]
        now = self.clock.now()
        rec = TaskRecord(
            group=st.group, request=st.group_request, network=net, sg_index=k,
            processor=p.processor, released=now,
        )
        with self._lock:
            self.trace.append(rec)
            if (self.virtual and self.dispatch_overhead > 0
                    and self.dispatch_pid in self.workers):
                self._seq += 1
                token_key = (-1, 0, self._seq)
            else:
                token_key = None
            self._seq += 1
            seq = self._seq
        if token_key is not None:
            self.workers[self.dispatch_pid].submit(token_key, DISPATCH_TOKEN)
        payload = {
            "request": st.request_id,
            "net": net,
            "sg": k,
            "dtype": p.dtype,
            "backend": p.backend,
            "engine_key": p.profile_key(),
            "inputs": inputs,
            "released": now,
            "record": rec,
        }
        self.workers[p.processor].submit((0, p.priority, seq), payload)

    def on_task_start(self, payload: Dict) -> None:
        """Worker hook at execution start: stamp the record + request."""
        with self._lock:
            st = self._requests[payload["request"]]
            started = payload["started"]
            if st.first_start is None or started < st.first_start:
                st.first_start = started
            rec: TaskRecord = payload["record"]
            rec.started = started
            rec.comm_time = payload.get("comm_s", 0.0)
            rec.quant_time = payload.get("quant_s", 0.0)
            rec.exec_time = payload.get("exec_s", 0.0)

    def on_task_done(self, payload: Dict, result: Any, quant_t: float,
                     exec_t: float) -> None:
        rid, net, k = payload["request"], payload["net"], payload["sg"]
        ready: List[Tuple[RequestState, int, int]] = []
        with self._lock:
            st = self._requests[rid]
            if isinstance(result, Exception):
                if not st.future.done():
                    st.future.set_exception(result)
                return
            now = self.clock.now()
            rec: TaskRecord = payload["record"]
            rec.finished = now
            # real-mode quant time is only known at completion
            rec.quant_time = quant_t
            rec.exec_time = payload.get("exec_s", exec_t)
            st.outputs[(net, k)] = result
            st.remaining -= 1
            if now > st.last_finish:
                st.last_finish = now
            st.task_records.append({
                "net": net, "sg": k, "quant_s": quant_t, "exec_s": exec_t,
                "wait_s": rec.started - payload["released"],
            })
            for s in self._succs[net][k]:
                st.pending_deps[(net, s)] -= 1
                if st.pending_deps[(net, s)] == 0:
                    ready.append((st, net, s))
            done = st.remaining == 0
            if done:
                st.finish = now
        for st2, n2, k2 in ready:
            self._dispatch(st2, n2, k2)
        if done and not st.future.done():
            st.future.set_result(st)
