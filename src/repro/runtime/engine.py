"""Engine: thin abstraction over execution backends (paper §5.1).

Engines hide framework details from Workers — the paper wraps Qualcomm AI
Engine Direct, ORT and TVM; here the backends are XLA-jit (``default``,
fast path), XLA-jit with a second compilation profile (``xnnpack``
analogue), and un-jitted op-by-op eval (``nnapi`` analogue — reliably the
slowest, reproducing Table 2's ordering). New engines register via
``ENGINE_REGISTRY``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Optional, Sequence, Tuple

import jax

from ..core.chromosome import PlacedSubgraph


class Engine:
    """Loads subgraphs once, executes many times (keyed by Merkle hash).

    Every execution is timed (injectable ``timer``, default
    ``time.perf_counter``) and recorded per key in ``exec_times`` — the keys
    *are* Merkle profile keys, so these samples feed straight back into the
    :class:`~repro.core.profiler.ProfileDB` as device-in-the-loop
    measurements (``PuzzleRuntime.measured_costs``). Load-time warm-up runs
    are not recorded, and only the most recent ``MAX_SAMPLES`` per key are
    kept — a long-lived serving runtime must not grow without bound.
    """

    name = "base"
    MAX_SAMPLES = 64

    def __init__(self, timer: Callable[[], float] = time.perf_counter):
        self._handles: Dict[str, Tuple[Callable, Tuple]] = {}
        self._lock = threading.Lock()
        self._timer = timer
        self.exec_times: Dict[str, Deque[float]] = {}

    def load(self, placed: PlacedSubgraph, executables: Dict[str, Any]) -> str:
        key = placed.profile_key()
        with self._lock:
            if key not in self._handles:
                model = executables[placed.subgraph.graph.name]
                fn, example = model.build_subgraph_fn(
                    placed.subgraph.layer_ids, placed.dtype
                )
                self._handles[key] = (self._prepare(fn, example), example)
        return key

    def _prepare(self, fn: Callable, example: Tuple) -> Callable:
        raise NotImplementedError

    def execute(self, key: str, inputs: Optional[Sequence] = None):
        fn, example = self._handles[key]
        args = inputs if inputs is not None else example
        t0 = self._timer()
        out = fn(*args)
        jax.block_until_ready(out)
        samples = self.exec_times.get(key)
        if samples is None:
            samples = self.exec_times[key] = deque(maxlen=self.MAX_SAMPLES)
        samples.append(self._timer() - t0)
        return out


class JitEngine(Engine):
    """XLA-compiled execution (the Qualcomm-SDK/ORT-default analogue)."""

    name = "default"

    def _prepare(self, fn, example):
        jitted = jax.jit(fn)
        jitted(*example)  # warm the cache at load time, like AOT compilation
        return jitted


class FastMathJitEngine(Engine):
    """Second compiled profile (XNNPACK analogue): same semantics, a
    different kernel selection — reduced matmul precision."""

    name = "xnnpack"

    def _prepare(self, fn, example):
        def wrapped(*a):
            with jax.default_matmul_precision("bfloat16"):
                return fn(*a)
        jitted = jax.jit(wrapped)
        jitted(*example)
        return jitted


class EagerEngine(Engine):
    """Un-jitted op-by-op execution — the NNAPI-like slow path."""

    name = "nnapi"

    def _prepare(self, fn, example):
        return fn


ENGINE_REGISTRY: Dict[str, Callable[[], Engine]] = {
    "default": JitEngine,
    "xnnpack": FastMathJitEngine,
    "nnapi": EagerEngine,
}


def make_engine(backend: str) -> Engine:
    return ENGINE_REGISTRY.get(backend, JitEngine)()
