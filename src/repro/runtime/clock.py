"""Injectable clocks + simulator-fed cost source for the Puzzle Runtime.

The Runtime normally measures wall time (``WallClock``) and genuinely
executes subgraphs. For the runtime↔simulator conformance tier it instead
runs in **virtual-clock mode**: a :class:`VirtualClock` owns a
``(time, seq)``-ordered event heap that the Coordinator/Workers drive
cooperatively (single-threaded, no sleeping), and a :class:`SimCostSource`
replays the exact per-subgraph ``(comm, quant, exec)`` costs of a
:class:`~repro.core.fastsim.FastSimSpec` — including the §6.3 lognormal
noise stream and the Coordinator dispatch tokens.

Bit-for-bit parity with :class:`~repro.core.fastsim.FastSimulator` rests on
two invariants this module owns:

* event ordering is ``(time, push-sequence)`` with the sequence assigned at
  push time, exactly like the simulator's heap entries — two events at one
  timestamp process in push order;
* the noise stream is one shared ``random.Random(seed).gauss`` consumed at
  task-delivery time in global delivery order, with the multiplier computed
  through ``math.exp`` (never a SIMD exp), the same draws in the same order
  as every simulator tier.
"""
from __future__ import annotations

import heapq
import math
import random
import time
from typing import Callable, List, Optional, Sequence, Tuple

from ..core.fastsim import FastSimSpec
from ..core.faults import FaultSpec, FaultStream
from ..core.processors import Processor
from ..core.simulator import NoiseModel


class WallClock:
    """Real time (the default): ``now()`` is ``time.perf_counter()``."""

    virtual = False

    def now(self) -> float:
        return time.perf_counter()


class VirtualClock:
    """Deterministic event scheduler: time advances only through events.

    ``schedule(delay, fn)`` pushes ``fn`` at ``now() + delay`` with a
    monotonically increasing sequence number; ``run(until)`` pops and fires
    events while the earliest one is at or before ``until`` (the simulator's
    horizon semantics — events scheduled past the horizon never fire, which
    is how overload scenarios drop requests).
    """

    virtual = True

    def __init__(self) -> None:
        self._now = 0.0
        self._events: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = 0

    def now(self) -> float:
        return self._now

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        # the sum below is the only place the fire time is computed, so a
        # caller passing `arrival - now` reproduces the simulator's
        # `now + (arrival - now)` float expression exactly
        heapq.heappush(self._events, (self._now + delay, self._seq, fn))
        self._seq += 1

    def run(self, until: Optional[float] = None) -> None:
        """Fire events in ``(time, seq)`` order; stop past ``until``."""
        while self._events and (until is None or self._events[0][0] <= until):
            t, _, fn = heapq.heappop(self._events)
            self._now = t
            fn()

    @property
    def pending(self) -> int:
        return len(self._events)


class SimCostSource:
    """Per-subgraph costs + noise for virtual execution, from a FastSimSpec.

    The spec must be the same cost arrays the simulator under comparison
    uses (``StaticAnalyzer.solution_spec`` / ``build_spec``) — conformance
    is about *scheduling* semantics, so both sides replay identical costs.
    """

    def __init__(
        self,
        spec: FastSimSpec,
        processors: Sequence[Processor],
        noise: Optional[NoiseModel] = None,
        dispatch_overhead: float = 0.0,
        faults: Optional[FaultSpec] = None,
    ):
        self.spec = spec
        self.dispatch_overhead = dispatch_overhead
        self.noise = noise
        # fault ensemble realized at delivery time (empty → clean path);
        # one shared stream across all workers, same as the noise stream
        self.faults = None if faults is None or faults.empty else faults
        self.fault_stream = (FaultStream(self.faults)
                             if self.faults is not None else None)
        # same construction as the simulators: seed 0 when no noise, and one
        # shared stream across all workers consumed in delivery order
        self._rng_gauss = random.Random(noise.seed if noise else 0).gauss
        n_pid = max(p.pid for p in processors) + 1
        self._sigma_of = [0.0] * n_pid
        for p in processors:
            self._sigma_of[p.pid] = noise.sigma(p.kind) if noise else 0.0
        # per-flat-subgraph cost overrides, installed by the runtime's
        # dropout recovery: a backup solution shares the partition, so its
        # FastSimSpec rows index identically and can replace the primary's
        # costs for exactly the remapped subgraphs
        self.override: dict = {}

    def costs(self, net: int, k: int) -> Tuple[float, float, float]:
        g = self.spec.offsets[net] + k
        ov = self.override.get(g)
        if ov is not None:
            return ov
        return self.spec.comm[g], self.spec.quant[g], self.spec.exec_[g]

    def noisy_exec(self, pid: int, exec_t: float) -> float:
        """Apply the mean-1 lognormal fluctuation draw (§6.3), bit-identical
        to the simulators' ``exp(gauss(-0.5·σ², σ))`` expression."""
        sigma = self._sigma_of[pid]
        if sigma > 0.0:
            exec_t *= math.exp(self._rng_gauss(-0.5 * sigma * sigma, sigma))
        return exec_t
